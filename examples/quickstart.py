"""Quickstart: the category-aware semantic cache in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's policy set, inserts a few (query → response) pairs,
and walks every Algorithm-1 path: paraphrase hit, threshold miss,
compliance rejection, TTL expiry, and a load-adaptive threshold shift.
"""

from repro.core import SemanticCache, SimClock, PolicyEngine
from repro.core.embedding import FeatureHashEmbedder
from repro.core.policy import AdaptiveController, LoadSignal, paper_policies


def main():
    clock = SimClock()
    controller = AdaptiveController()
    controller.register_model("o1", latency_target_ms=600, queue_target=32)
    policies = PolicyEngine(paper_policies(), controller=controller)
    cache = SemanticCache(policies, capacity=4096, clock=clock,
                          index_kind="hnsw", l1_capacity=64)
    embed = FeatureHashEmbedder()

    # 1. populate
    pairs = [
        ("how do I sort a list in python", "Use sorted(xs) or xs.sort().",
         "code_generation"),
        ("reverse a string in python", "s[::-1]", "code_generation"),
        ("what is the capital of france", "Paris.", "conversational_chat"),
    ]
    for q, r, cat in pairs:
        cache.insert(embed.embed(q), cat, q, r)
    print(f"cached {len(cache)} entries")

    # 2. near-duplicate hit in the tight code category (τ=0.90, §3.1)
    res = cache.lookup(embed.embed("how do I sort a list in python?"),
                       "code_generation")
    print(f"code near-duplicate → hit={res.hit} score={res.score:.3f} "
          f"response={res.response!r}")

    # 2b. looser paraphrase hits in the sparse chat category (τ=0.75)
    res = cache.lookup(embed.embed("what is the capital city of france"),
                       "conversational_chat")
    print(f"chat paraphrase → hit={res.hit} score={res.score:.3f} "
          f"response={res.response!r}")

    # 3. semantically different query → miss in 2 ms, no external access
    res = cache.lookup(embed.embed("delete every file on my disk"),
                       "code_generation")
    print(f"distinct intent → hit={res.hit} reason={res.reason}")

    # 4. compliance category never caches (§6.4)
    res = cache.lookup(embed.embed("patient 1234 lab results"),
                       "phi_medical_records")
    print(f"PHI category → hit={res.hit} reason={res.reason}")

    # 5. TTL enforcement BEFORE external fetch (§5.4)
    cache.insert(embed.embed("AAPL price right now"), "financial_data",
                 "AAPL price right now", "$212.33")
    clock.advance(600)                      # financial TTL = 5 min
    res = cache.lookup(embed.embed("AAPL price right now"), "financial_data")
    print(f"stale quote after 10 min → hit={res.hit} reason={res.reason}")

    # 6. adaptive relaxation under load (§7.5)
    base = policies.effective("code_generation").threshold
    for _ in range(64):
        controller.observe("o1", LoadSignal(latency_ms=2000, queue_depth=128))
    relaxed = policies.effective("code_generation").threshold
    print(f"o1 under 3x load: τ {base:.3f} → {relaxed:.3f}, "
          f"TTL ×{policies.effective('code_generation').ttl / (7 * 86400):.2f}")

    print("\nper-category stats:")
    for cat, st in cache.metrics.snapshot().items():
        print(f"  {cat}: {st}")


if __name__ == "__main__":
    main()
