"""Train a ~100 M-param model for a few hundred steps (deliverable b).

    PYTHONPATH=src python examples/train_small.py [--steps 300]

Exercises the full training substrate on CPU: packed synthetic data,
AdamW, grad accumulation, async checkpointing, preemption-safe loop,
straggler watchdog. Loss should fall from ~ln(V) toward the corpus's
topic-mixture entropy.
"""

import argparse

from repro.configs import get_config
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slower on CPU); default ~20M")
    args = ap.parse_args()

    base = get_config("llama3_2_3b")
    if args.big:
        cfg = base.reduced(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                           head_dim=64, d_ff=2048, vocab_size=32768,
                           loss_chunk=256)
    else:
        cfg = base.reduced(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                           head_dim=64, d_ff=1024, vocab_size=8192,
                           loss_chunk=256)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f} M params, "
          f"{args.steps} steps @ batch {args.batch} × seq {args.seq}")
    res = run_training(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, ckpt_every=100, lr=1e-3)
    print(f"\nloss: {res['first_loss']:.4f} → {res['final_loss']:.4f} "
          f"({res['steps_run']} steps, {res['wall_s']:.0f}s, "
          f"{res['straggler_events']} straggler events)")


if __name__ == "__main__":
    main()
