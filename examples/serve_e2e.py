"""End-to-end serving driver (deliverable b): a real JAX model behind the
category-aware semantic cache, serving batched requests.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 400]

A ~15 M-param llama-style model decodes greedy continuations for cache
misses; repeated/paraphrased requests are served from the cache without
touching the model. The engine feeds latency/queue observations into the
adaptive controller, so sustained miss storms relax thresholds (§7.5).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.cache import SemanticCache
from repro.core.clock import WallClock
from repro.core.policy import (AdaptiveController, PolicyEngine,
                               paper_policies)
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.models import Model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("llama3_2_3b").reduced(
        n_layers=4, d_model=256, d_ff=512, vocab_size=2048)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f} M params)")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))

    controller = AdaptiveController()
    policies = PolicyEngine(paper_policies(), controller=controller)
    cache = SemanticCache(policies, capacity=8192, clock=WallClock(),
                          index_kind="hnsw", l1_capacity=256)
    engine = ServingEngine(model, params, cache, max_batch=args.max_batch,
                           prompt_len=32, max_new_tokens=8,
                           controller=controller)

    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=1e9, seed=0)
    queries = gen.generate(args.requests)
    rng = np.random.default_rng(0)
    t0 = time.time()
    done = 0
    for q in queries:
        toks = rng.integers(2, cfg.vocab_size, size=32)
        engine.submit(q.text, q.category, toks)
        if len(engine.queue) >= args.max_batch:
            done += len(engine.step())
    done += len(engine.drain())
    wall = time.time() - t0

    st = engine.stats
    print(f"\nserved {st.served} requests in {wall:.1f}s "
          f"({st.served / wall:.1f} req/s)")
    print(f"cache hit rate: {st.hit_rate:.3f}")
    print(f"model tokens generated: {st.model_tokens} "
          f"(saved ~{st.cache_hits * 8} by caching)")
    print("\nper-category:")
    for cat, d in cache.metrics.snapshot().items():
        if d["lookups"]:
            print(f"  {cat:22s} lookups={d['lookups']:4d} "
                  f"hit_rate={d['hit_rate']:.3f}")


if __name__ == "__main__":
    main()
