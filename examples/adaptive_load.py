"""Adaptive load-based policies (§7.5) in the discrete-event simulator.

    PYTHONPATH=src python examples/adaptive_load.py

Runs the Table-1 workload three ways through a 3× spike on the o1 model:
fixed policies, adaptive with FP-safety (§7.5.6), and adaptive with the
paper's unconstrained linear assumption — showing the traffic-reduction /
accuracy trade-off the paper's projection leaves open.
"""

from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.serving.simulator import ServingSimulator, SimConfig

N = 5000
SPIKE = [(30.0, 900.0, "o1", 3.0)]


def run(adaptive: bool, fp_limit: float = 0.05):
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=3)
    sim = ServingSimulator(eng, SimConfig(
        architecture="hybrid", cache_capacity=12000, index_kind="flat",
        adaptive=adaptive, fp_rate_limit=fp_limit, load_spikes=SPIKE))
    return sim.run(gen, N)


def main():
    rows = [
        ("fixed policies", run(False)),
        ("adaptive + FP-safety", run(True, 0.05)),
        ("adaptive, unconstrained", run(True, 1.0)),
    ]
    base_calls = rows[0][1].model_calls.get("o1", 1)
    print(f"{'variant':26s} {'o1 calls':>9s} {'reduction':>10s} "
          f"{'code hit':>9s} {'code FPs':>9s} {'mean ms':>8s}")
    for name, res in rows:
        calls = res.model_calls.get("o1", 0)
        code = res.per_category["code_generation"]
        print(f"{name:26s} {calls:9d} {1 - calls / base_calls:10.3f} "
              f"{code['hit_rate']:9.3f} {code['false_positives']:9d} "
              f"{res.mean_latency_ms:8.1f}")
    print("\npaper §7.5.4 projects 9-17% reduction (theoretical, no FP "
          "constraint);\nthe unconstrained run reproduces/exceeds it, the "
          "FP-safe run shows what survives §7.5.6 monitoring.")


if __name__ == "__main__":
    main()
