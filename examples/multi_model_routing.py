"""Multi-model routing with per-model adaptive policies (§7.5.5).

    PYTHONPATH=src python examples/multi_model_routing.py

Model A (o1: expensive, slow) takes a 3× spike while Model B
(gpt-4o-mini) idles. Only A's categories relax; per-hit savings on A are
~10× B's in both latency and cost.
"""

from repro.core.policy import CategoryConfig, PolicyEngine
from repro.serving.router import ModelBackend, ModelRouter


def main():
    policies = PolicyEngine([
        CategoryConfig("complex_code", threshold=0.90, ttl=7 * 86400,
                       quota=0.4, delta_max=0.05, tau_min=0.80,
                       model_name="o1", expected_tllm_ms=500.0),
        CategoryConfig("simple_chat", threshold=0.75, ttl=6 * 3600,
                       quota=0.2, delta_max=0.10, tau_min=0.68,
                       model_name="gpt4o_mini", expected_tllm_ms=150.0),
    ])
    router = ModelRouter(policies, [
        ModelBackend("o1", t_base_ms=500.0, cost_per_call=0.10,
                     latency_target_ms=600, queue_target=32),
        ModelBackend("gpt4o_mini", t_base_ms=150.0, cost_per_call=0.01,
                     latency_target_ms=300, queue_target=32),
    ])

    def show(tag):
        print(f"\n[{tag}]")
        for cat in ("complex_code", "simple_chat"):
            p = router.effective_policy(cat)
            b = router.backend_for(cat)
            print(f"  {cat:13s} → {b.name:11s} λ={router.load_factor(b.name):.2f} "
                  f"τ={p.threshold:.3f} ttl={p.ttl / 86400:.1f}d")

    show("normal load")
    print("\n… o1 takes a 3× traffic spike (1500 ms, deep queues) …")
    for _ in range(64):
        router.observe("o1", latency_ms=1500.0, queue_depth=96)
        router.observe("gpt4o_mini", latency_ms=140.0, queue_depth=1)
    show("o1 spiked")

    save_a = (1500.0 - 7.0, 0.10)
    save_b = (150.0 - 7.0, 0.01)
    print(f"\nper-hit value during spike: o1 saves {save_a[0]:.0f} ms / "
          f"${save_a[1]:.2f}; mini saves {save_b[0]:.0f} ms / ${save_b[1]:.2f}"
          f"  (≈{save_a[0] / save_b[0]:.0f}× latency, "
          f"{save_a[1] / save_b[1]:.0f}× cost)")
    print(f"\nrouter report: {router.report()}")


if __name__ == "__main__":
    main()
