"""Model facade: init / train loss / prefill / decode for every family.

Families share the grouped-scan stack (``transformer.py``); this module owns
embeddings (token / patch-prefix / audio-frontend-stub), the LM head with
sequence-chunked cross-entropy (full (B,S,V) logits never materialize),
whisper's encoder + per-layer cross-K/V, and the cache plumbing.

Vocab is physically padded to a multiple of 2048 so the head shards over
any ``model`` axis (whisper's 51866, granite-moe's 49155); padded rows are
masked to −1e30 before softmax.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.distributed.context import Dist
from repro.models import transformer as tf
from repro.models.config import ArchConfig
from repro.models.layers import dtype_of, rms_norm

VOCAB_PAD_UNIT = 2048


def padded_vocab(v: int) -> int:
    return ((v + VOCAB_PAD_UNIT - 1) // VOCAB_PAD_UNIT) * VOCAB_PAD_UNIT


def _sinusoid_at(positions: jax.Array, dim: int) -> jax.Array:
    """Absolute sinusoidal embeddings at given positions (whisper)."""
    half = dim // 2
    i = jnp.arange(half, dtype=jnp.float32)
    inv = jnp.power(10000.0, -i / half)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


@dataclass
class Model:
    cfg: ArchConfig
    dist: Dist | None = None

    # ------------------------------------------------------------- params
    def init_params(self, key) -> dict:
        cfg = self.cfg
        dt = dtype_of(cfg)
        vp = padded_vocab(cfg.vocab_size)
        keys = jax.random.split(key, 6)
        params = {
            "embed": (jax.random.normal(keys[0], (vp, cfg.d_model), jnp.float32)
                      * cfg.d_model ** -0.5).astype(dt),
            "head": (jax.random.normal(keys[1], (vp, cfg.d_model), jnp.float32)
                     * cfg.d_model ** -0.5).astype(dt),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "stack": tf.init_stack(keys[2], cfg),
        }
        if cfg.family == "encdec":
            enc_cfg = self._enc_cfg()
            params["enc"] = {
                "proj": (jax.random.normal(
                    keys[3], (cfg.enc_dim, cfg.d_model), jnp.float32)
                    * cfg.enc_dim ** -0.5).astype(dt),
                "stack": tf.init_stack(keys[4], enc_cfg),
                "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            }
        return params

    def param_shapes(self) -> dict:
        return jax.eval_shape(lambda: self.init_params(jax.random.key(0)))

    def _enc_cfg(self) -> ArchConfig:
        """Encoder stack config: non-causal dense attention layers."""
        from dataclasses import replace
        return replace(self.cfg, family="dense", n_layers=self.cfg.enc_layers,
                       n_experts=0, moe_top_k=0, sliding_window=None,
                       local_global_alternating=False)

    # ------------------------------------------------------------ embedding
    def _embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.final_softcap is not None:   # gemma-style embed scaling
            x = x * jnp.asarray(self.cfg.d_model ** 0.5, x.dtype)
        return x

    # ------------------------------------------------------------- encoder
    def _encode(self, params, audio):
        """Whisper encoder on precomputed frame embeddings (frontend stub)."""
        cfg = self.cfg
        x = jnp.einsum("bcd,de->bce", audio.astype(dtype_of(cfg)),
                       params["enc"]["proj"].astype(dtype_of(cfg)))
        pos = jnp.arange(x.shape[1])
        x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)
        enc_cfg = self._enc_cfg()
        group, _ = tf.layer_groups(enc_cfg)
        group = [tf.SubLayerSpec(kind="attn", mlp="dense", window=None,
                                 causal=False)] * len(group)
        x, _, _ = tf.stack_apply(x, params["enc"]["stack"], enc_cfg,
                                 self.dist, mode="train",
                                 positions=pos, group=group)
        return rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V, group-stacked for the scan."""
        wk = params["stack"]["sub0"]["cross"]["wk"]      # (G, d, Hkv, dh)
        wv = params["stack"]["sub0"]["cross"]["wv"]
        k = jnp.einsum("bcd,gdhk->gbchk", enc_out, wk.astype(enc_out.dtype))
        v = jnp.einsum("bcd,gdhk->gbchk", enc_out, wv.astype(enc_out.dtype))
        return {"k": k, "v": v}

    def _dec_inputs(self, params, tokens, positions):
        x = self._embed_tokens(params, tokens)
        if self.cfg.family == "encdec":
            x = x + _sinusoid_at(positions, self.cfg.d_model).astype(x.dtype)
        return x

    # ------------------------------------------------------------- training
    def loss_fn(self, params, batch) -> tuple[jax.Array, dict]:
        """batch: tokens (B,S), labels (B,S) int32 (−1 = masked), plus
        family extras: patches (B,P,d) [vlm], audio (B,ctx,enc_dim) [encdec].
        """
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        positions = jnp.arange(tokens.shape[1])
        enc_kv = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["audio"])
            enc_kv = self._cross_kv(params, enc_out)
        x = self._dec_inputs(params, tokens, positions)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            positions = jnp.arange(x.shape[1])
            pad = jnp.full(patches.shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)

        x, _, aux = tf.stack_apply(x, params["stack"], cfg, self.dist,
                                   mode="train", positions=positions,
                                   enc_kv=enc_kv)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        loss, n_tok = self._chunked_xent(params, x, labels)
        total = loss + cfg.router_aux_weight * aux
        return total, {"xent": loss, "aux": aux, "tokens": n_tok}

    def _chunked_xent(self, params, x, labels):
        """Sequence-chunked cross-entropy; (B,S,V) never materializes."""
        cfg = self.cfg
        B, S, d = x.shape
        chunk = min(cfg.loss_chunk, S)
        if S % chunk:
            chunk = S
        nc = S // chunk
        head = params["head"]
        vp = head.shape[0]
        vmask = (jnp.arange(vp) < cfg.vocab_size)

        def body(carry, inp):
            xc, lc = inp                                  # (B,c,d), (B,c)
            logits = jnp.einsum("bcd,vd->bcv", xc.astype(jnp.float32),
                                head.astype(jnp.float32))
            if cfg.final_softcap is not None:
                logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
            logits = jnp.where(vmask[None, None], logits, -1e30)
            logp = jax.nn.log_softmax(logits, axis=-1)
            take = jnp.take_along_axis(
                logp, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            mask = (lc >= 0).astype(jnp.float32)
            return (carry[0] + jnp.sum(-take * mask),
                    carry[1] + jnp.sum(mask)), None

        xs = (x.reshape(B, nc, chunk, d).swapaxes(0, 1),
              labels.reshape(B, nc, chunk).swapaxes(0, 1))
        (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs)
        return nll / jnp.maximum(cnt, 1.0), cnt

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int) -> dict:
        return tf.init_cache(self.cfg, batch, max_len)

    def _logits_last(self, params, x_last):
        cfg = self.cfg
        logits = jnp.einsum("bd,vd->bv", x_last.astype(jnp.float32),
                            params["head"].astype(jnp.float32))
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        vp = params["head"].shape[0]
        return jnp.where(jnp.arange(vp)[None, :] < cfg.vocab_size,
                         logits, -1e30)

    def prefill(self, params, batch, max_len: int):
        """Returns (last-token logits (B, Vp), cache dict, kv_len (B,)).

        ``cache`` = {"stack": ..., "enc_kv": ...?}; chunked at
        ``cfg.prefill_chunk`` (static offsets, unrolled).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc_kv = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["audio"])
            enc_kv = self._cross_kv(params, enc_out)

        positions = jnp.arange(S)
        x = self._dec_inputs(params, tokens, positions)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            S = x.shape[1]
            positions = jnp.arange(S)

        # VLM prefix tokens count toward context: grow the cache if needed.
        cache = tf.init_cache(cfg, B, max(max_len, S))
        chunk = cfg.prefill_chunk or S
        if S % chunk:
            chunk = S
        aux_total = jnp.zeros(())
        for off in range(0, S, chunk):
            xc = jax.lax.slice_in_dim(x, off, off + chunk, axis=1)
            pos = positions[off:off + chunk]
            xc, cache, aux = tf.stack_apply(
                xc, params["stack"], cfg, self.dist, mode="prefill",
                positions=pos, cache=cache, kv_len=None, kv_offset=off,
                enc_kv=enc_kv)
            aux_total = aux_total + aux
        x_last = rms_norm(xc[:, -1], params["final_norm"], cfg.norm_eps)
        logits = self._logits_last(params, x_last)
        out_cache = {"stack": cache}
        if enc_kv is not None:
            out_cache["enc_kv"] = enc_kv
        return logits, out_cache, jnp.full((B,), S, jnp.int32)

    def decode_step(self, params, cache: dict, tokens: jax.Array,
                    kv_len: jax.Array):
        """One token for every sequence. tokens (B,), kv_len (B,).
        Returns (logits (B, Vp), new_cache, kv_len + 1)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._dec_inputs(params, tokens[:, None], kv_len[:, None])
        x, new_stack, _ = tf.stack_apply(
            x, params["stack"], cfg, self.dist, mode="decode",
            positions=kv_len[:, None], cache=cache["stack"], kv_len=kv_len,
            enc_kv=cache.get("enc_kv"))
        x_last = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
        logits = self._logits_last(params, x_last)
        new_cache = dict(cache)
        new_cache["stack"] = new_stack
        return logits, new_cache, kv_len + 1
