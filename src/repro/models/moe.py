"""Mixture-of-Experts FFN with production expert parallelism.

Two implementations sharing one routing function:

``moe_ffn_dense``
    Reference one-hot dispatch (einsum). Exact, O(T·E·C) memory —
    used by smoke tests and as the oracle for the EP path.

``moe_ffn_ep``
    Production path under ``shard_map``: experts are owned by ``data``
    shards (the token axis) and each expert's FFN width is sharded over
    ``model``. Token routing is sort-based and dropping (capacity factor):

        route (outside, replicated math) → per-destination send buffers
        → all_to_all over ``data`` → sort by local expert → ragged_dot
        grouped GEMMs (w_gate/w_up/w_down slices) → psum over ``model``
        (ffn partial sums) → all_to_all back → weighted scatter-combine.

    Buffer bytes per device ≈ n_data·C·d ≈ T_loc·top_k·capacity·d — kept
    small by training with ``grad_accum`` microbatches (configs set this
    for kimi-k2). Experts are zero-padded to a multiple of ``n_data``
    (router logits for padding = −inf, so they never receive tokens).

The paper's technique (semantic caching) sits in front of any of this;
EP here is serving/training substrate the 1T-param assigned arch needs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.context import Dist


@jax.custom_vjp
def _ragged_dot(lhs, rhs, group_sizes):
    """``lax.ragged_dot`` with fp32 accumulation and DTYPE-CORRECT
    cotangents: jax ≤ 0.4.x's ragged_dot transpose returns fp32 cts for
    bf16 operands (it ignores the operand dtype under
    ``preferred_element_type``), which trips the cotangent-addition
    typecheck when the same activation also feeds a bf16 path (residual
    stream + router). The custom bwd reuses the built-in transpose, then
    casts each ct back to its operand dtype."""
    return jax.lax.ragged_dot(lhs, rhs, group_sizes,
                              preferred_element_type=jnp.float32)


def _ragged_dot_fwd(lhs, rhs, group_sizes):
    return _ragged_dot(lhs, rhs, group_sizes), (lhs, rhs, group_sizes)


def _ragged_dot_bwd(res, ct):
    lhs, rhs, group_sizes = res
    _, vjp = jax.vjp(
        lambda l, r: jax.lax.ragged_dot(
            l, r, group_sizes, preferred_element_type=jnp.float32),
        lhs, rhs)
    dl, dr = vjp(ct)
    return dl.astype(lhs.dtype), dr.astype(rhs.dtype), None


_ragged_dot.defvjp(_ragged_dot_fwd, _ragged_dot_bwd)


def padded_experts(n_experts: int, n_data: int) -> int:
    return int(math.ceil(n_experts / n_data) * n_data)


def route(x: jax.Array, router_w: jax.Array, cfg, n_expert_pad: int
          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. x (T, d) → ids (T, k) int32, weights (T, k) fp32,
    aux load-balancing loss (scalar, switch-style)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    E = cfg.n_experts
    if n_expert_pad > E:
        pad = jnp.full((logits.shape[0], n_expert_pad - E), -1e30, jnp.float32)
        logits = jnp.concatenate([logits, pad], axis=1)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    weights = weights / jnp.maximum(weights.sum(axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: E · Σ_e f_e · P_e  (over real experts only).
    f = jnp.zeros((n_expert_pad,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    aux = E * jnp.sum(f[:E] * p_mean[:E])
    return ids.astype(jnp.int32), weights, aux


def moe_ffn_dense_exact(x: jax.Array, p: dict, cfg) -> tuple[jax.Array, jax.Array]:
    """Exact reference: every expert applied to every token, then weighted
    combine. O(T·E) compute — only for tiny test configs."""
    ids, weights, aux = route(x, p["router"], cfg, cfg.n_experts)
    xf = x.astype(jnp.float32)
    g = jnp.einsum("td,edf->etf", xf, p["w_gate"].astype(jnp.float32))
    u = jnp.einsum("td,edf->etf", xf, p["w_up"].astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(jnp.float32))
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)   # (T,k,E)
    combine = (weights[..., None] * onehot).sum(axis=1)              # (T,E)
    y = jnp.einsum("etd,te->td", y_all, combine)
    return y.astype(x.dtype), aux


def _capacity(t_loc: int, top_k: int, n_data: int, factor: float) -> int:
    c = int(math.ceil(t_loc * top_k / n_data * factor))
    return max(8, ((c + 7) // 8) * 8)


def _moe_local(x, ids, weights, w_gate, w_up, w_down, *, cfg, n_data: int,
               e_pad: int, data_axis: str, model_axis: str | None,
               rs_combine: bool = False):
    """Per-device body under shard_map. x (T_loc, d); expert slices
    w_gate/w_up (E_loc, d, ff_loc), w_down (E_loc, ff_loc, d).

    ``rs_combine``: reduce-scatter the down-proj partials over ``model``
    onto the d axis instead of a full psum, return tokens d-sharded, and
    let GSPMD all-gather d once at the residual — cuts the model-axis
    collective ~2× and the return all_to_all ~n_model× (§Perf B iter 2).
    """
    T_loc, d = x.shape
    k = cfg.moe_top_k
    e_loc = e_pad // n_data
    my = jax.lax.axis_index(data_axis)

    flat_ids = ids.reshape(-1)                                  # (N=T_loc·k,)
    flat_w = weights.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)
    dest = flat_ids // e_loc                                    # owner shard
    N = flat_ids.shape[0]
    C = _capacity(T_loc, k, n_data, cfg.capacity_factor)

    # Stable sort by destination; position within each destination group.
    order = jnp.argsort(dest, stable=True)
    s_dest = dest[order]
    s_tok = tok_idx[order]
    s_eid = flat_ids[order]
    starts = jnp.searchsorted(s_dest, jnp.arange(n_data, dtype=s_dest.dtype))
    pos = jnp.arange(N, dtype=jnp.int32) - starts[s_dest].astype(jnp.int32)
    keep = pos < C                                              # drop overflow
    slot = jnp.where(keep, s_dest * C + pos, n_data * C)        # OOB → dropped

    send_tok = jnp.zeros((n_data * C, d), x.dtype).at[slot].set(
        x[s_tok], mode="drop")
    send_eid = jnp.full((n_data * C,), -1, jnp.int32).at[slot].set(
        s_eid, mode="drop")

    # all_to_all over data: shard i's block j → shard j's block i.
    recv_tok = jax.lax.all_to_all(send_tok.reshape(n_data, C, d), data_axis,
                                  split_axis=0, concat_axis=0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid.reshape(n_data, C), data_axis,
                                  split_axis=0, concat_axis=0, tiled=True)

    # Local expert compute: group rows by local expert for ragged GEMMs.
    rows = recv_tok.reshape(-1, d)
    leid = recv_eid.reshape(-1) - my * e_loc
    invalid = (recv_eid.reshape(-1) < 0) | (leid < 0) | (leid >= e_loc)
    leid = jnp.where(invalid, e_loc, leid)                      # sort last
    g_order = jnp.argsort(leid, stable=True)
    rows = rows[g_order]
    gs = jnp.bincount(leid, length=e_loc + 1)[:e_loc]           # valid only

    h = _ragged_dot(rows, w_gate.astype(rows.dtype), gs)
    u = _ragged_dot(rows, w_up.astype(rows.dtype), gs)
    hidden = (jax.nn.silu(h) * u).astype(x.dtype)
    part = _ragged_dot(hidden, w_down.astype(hidden.dtype), gs)  # (M, d)
    d_out = d
    if model_axis is not None:
        if rs_combine:
            # (M, d) partials → (M, d/n_model) summed shard
            part = jax.lax.psum_scatter(part, model_axis,
                                        scatter_dimension=1, tiled=True)
            d_out = part.shape[1]
        else:
            part = jax.lax.psum(part, model_axis)               # ffn partials

    # Unsort, return to senders, weighted combine.
    part = part.astype(x.dtype)
    unsorted = jnp.zeros_like(part).at[g_order].set(part)
    back = jax.lax.all_to_all(unsorted.reshape(n_data, C, d_out), data_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    flat_back = back.reshape(n_data * C, d_out)
    contrib = flat_back[jnp.clip(slot, 0, n_data * C - 1)]      # (N, d_out)
    contrib = jnp.where(keep[:, None], contrib.astype(jnp.float32), 0.0)
    y = jnp.zeros((T_loc, d_out), jnp.float32).at[s_tok].add(
        contrib * flat_w[order][:, None])
    return y.astype(x.dtype)


def moe_ffn_ep(x: jax.Array, p: dict, cfg, dist: Dist,
               token_parallel: bool = False) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE. x (T, d) global. Returns (y (T, d), aux).

    Default layout: tokens sharded over (pod, data), replicated over
    ``model``; each expert's FFN width splits over ``model`` with a psum
    of the down-proj partials.

    ``token_parallel`` (small-expert archs, ffe < 128·n_model): tokens
    shard over (pod, data, **model**) and each shard runs FULL-width
    expert FFNs for its slice — no model-axis psum, 1/n_model the
    per-device routing bytes, MXU-aligned GEMMs (§Perf A iteration 3).
    """
    n_data = dist.n_data
    e_pad = padded_experts(cfg.n_experts, n_data)
    ids, weights, aux = route(x, p["router"], cfg, e_pad)

    batch = dist.batch_axes
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    if e_pad > cfg.n_experts:
        padn = e_pad - cfg.n_experts
        w_gate = jnp.pad(w_gate, ((0, padn), (0, 0), (0, 0)))
        w_up = jnp.pad(w_up, ((0, padn), (0, 0), (0, 0)))
        w_down = jnp.pad(w_down, ((0, padn), (0, 0), (0, 0)))

    if token_parallel and dist.n_model > 1:
        tok_axes = (*batch, dist.model_axis)
        body = functools.partial(_moe_local, cfg=cfg, n_data=n_data,
                                 e_pad=e_pad, data_axis=dist.data_axis,
                                 model_axis=None)
        y = shard_map(
            body, mesh=dist.mesh,
            in_specs=(P(tok_axes, None), P(tok_axes, None),
                      P(tok_axes, None),
                      P(dist.data_axis, None, None),
                      P(dist.data_axis, None, None),
                      P(dist.data_axis, None, None)),
            out_specs=P(tok_axes, None),
            check_rep=False,
        )(x, ids, weights, w_gate, w_up, w_down)
        return y, aux

    rs = dist.n_model > 1 and cfg.d_model % dist.n_model == 0
    body = functools.partial(_moe_local, cfg=cfg, n_data=n_data, e_pad=e_pad,
                             data_axis=dist.data_axis,
                             model_axis=dist.model_axis if dist.n_model > 1 else None,
                             rs_combine=rs)
    y = shard_map(
        body, mesh=dist.mesh,
        in_specs=(P(batch, None), P(batch, None), P(batch, None),
                  P(dist.data_axis, None, dist.model_axis),
                  P(dist.data_axis, None, dist.model_axis),
                  P(dist.data_axis, dist.model_axis, None)),
        out_specs=P(batch, dist.model_axis if rs else None),
        check_rep=False,
    )(x, ids, weights, w_gate, w_up, w_down)
    return y, aux


def moe_apply(x: jax.Array, p: dict, cfg, dist: Dist | None
              ) -> tuple[jax.Array, jax.Array]:
    """Dispatch: EP under a real mesh, exact dense reference otherwise.
    x may be (B, S, d) or (T, d); returns same leading shape."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    # token-parallel for small experts (MXU-aligned full-width FFNs)
    tp = (dist is not None and dist.n_model > 1
          and cfg.d_ff_expert < 128 * dist.n_model)
    tok_shards = dist.n_pod * dist.n_data if dist is not None else 1
    if tp:
        tok_shards *= dist.n_model
    if (dist is not None and dist.mesh is not None and dist.n_data > 1
            and x2.shape[0] % tok_shards == 0):
        y, aux = moe_ffn_ep(x2, p, cfg, dist, token_parallel=tp)
    else:
        # Tiny token counts (batch-1 long-context decode): every device
        # computes its expert shard for all tokens; GSPMD's einsum
        # partitioning handles it without routing buffers.
        y, aux = moe_ffn_dense_exact(x2, p, cfg)
    return y.reshape(shape), aux
