"""Shared layers: norms, embeddings, rotary, MLP, parameter init.

Parameters are plain nested dicts of jnp arrays (no flax): stacked along a
leading layer axis for ``lax.scan``. Initializers take an explicit PRNG key
and return fp32 masters cast to the config dtype by the optimizer/trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms (computed in fp32 regardless of activation dtype).
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, dh); positions (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings (fp32)."""
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# MLP (SwiGLU).
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": _normal(k1, (d_model, d_ff), dtype, s_in),
        "w_up": _normal(k2, (d_model, d_ff), dtype, s_in),
        "w_down": _normal(k3, (d_ff, d_model), dtype, s_out),
    }


def init_attention(key, cfg) -> dict:
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, dh = cfg.d_model, cfg.head_dim
    s = d ** -0.5
    return {
        "wq": _normal(k1, (d, cfg.n_heads, dh), dt, s),
        "wk": _normal(k2, (d, cfg.n_kv_heads, dh), dt, s),
        "wv": _normal(k3, (d, cfg.n_kv_heads, dh), dt, s),
        "wo": _normal(k4, (cfg.n_heads, dh, d), dt, (cfg.n_heads * dh) ** -0.5),
    }


def init_mamba(key, cfg) -> dict:
    dt = dtype_of(cfg)
    d, di, ns = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_d_state
    dt_rank = max(1, d // 16)
    keys = jax.random.split(key, 7)
    A = -jnp.exp(jax.random.uniform(keys[5], (di, ns), jnp.float32,
                                    minval=np.log(0.5), maxval=np.log(16.0)))
    return {
        "w_in": _normal(keys[0], (d, 2 * di), dt, d ** -0.5),       # [x, z]
        "conv_w": _normal(keys[1], (cfg.ssm_d_conv, di), dt, 0.2),
        "conv_b": jnp.zeros((di,), dt),
        "w_x_proj": _normal(keys[2], (di, dt_rank + 2 * ns), dt, di ** -0.5),
        "w_dt": _normal(keys[3], (dt_rank, di), dt, dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(keys[4], (di,), jnp.float32,
                                        minval=1e-3, maxval=1e-1), 1e-4, None))),
        "A_log": jnp.log(-A),                                        # fp32
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _normal(keys[6], (di, d), dt, di ** -0.5),
    }


def init_moe(key, cfg) -> dict:
    dt = dtype_of(cfg)
    d, ffe = cfg.d_model, cfg.d_ff_expert
    E = cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, ffe ** -0.5
    return {
        "router": _normal(k1, (d, E), jnp.float32, s_in),
        "w_gate": _normal(k2, (E, d, ffe), dt, s_in),
        "w_up": _normal(k3, (E, d, ffe), dt, s_in),
        "w_down": _normal(k4, (E, ffe, d), dt, s_out),
    }
