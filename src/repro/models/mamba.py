"""Mamba1 block (falcon-mamba, jamba's SSM layers).

Prefill/train: two-level scan — outer ``lax.scan`` over sequence chunks
carrying the (B, d_inner, N) state, inner ``associative_scan`` within the
chunk. This bounds the materialized (B, chunk, d_inner, N) intermediate
(the reason CUDA mamba needs a fused kernel; our Pallas ``mamba_scan``
kernel is the TPU equivalent, and this jnp path is the portable/HLO-clean
formulation with the same memory behavior).

Decode: single recurrence step; carries {conv window, ssm state}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ssm_params(x: jax.Array, p: dict, cfg):
    """x (B, L, di) → dt (B, L, di), B/C (B, L, N), A (di, N)."""
    dt_rank = p["w_dt"].shape[0]
    N = cfg.ssm_d_state
    proj = jnp.einsum("bld,dk->blk", x, p["w_x_proj"].astype(x.dtype))
    dt_in, Bc, Cc = jnp.split(proj.astype(jnp.float32),
                              [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["w_dt"].astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di, N)
    return dt, Bc, Cc, A


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                   init: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x (B, L, di); w (K, di); init (B, K-1, di)."""
    K = w.shape[0]
    if init is None:
        init = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
              for i in range(K))
    return out + b[None, None, :].astype(x.dtype)


def _chunk_scan(h0: jax.Array, dA: jax.Array, dBx: jax.Array):
    """Associative scan within a chunk. h0 (B, di, N); dA/dBx (B, c, di, N).
    Returns (states (B, c, di, N), h_final)."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2
    A_acc, B_acc = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    states = A_acc * h0[:, None] + B_acc
    return states, states[:, -1]


def mamba_mix(x: jax.Array, p: dict, cfg, h0=None, conv0=None,
              chunk: int = 64):
    """Core SSM mixer. x (B, L, di) (already in_proj'd 'x' half).
    Returns (y (B, L, di), h_final (B, di, N), conv_tail (B, K-1, di))."""
    B, L, di = x.shape
    K = cfg.ssm_d_conv
    xc = _conv1d_causal(x, p["conv_w"], p["conv_b"], conv0)
    conv_tail = jnp.concatenate(
        [conv0 if conv0 is not None else jnp.zeros((B, K - 1, di), x.dtype), x],
        axis=1)[:, -(K - 1):]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bc, Cc, A = _ssm_params(xc, p, cfg)

    dA = jnp.exp(dt[..., None] * A[None, None])                # (B,L,di,N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

    c = min(chunk, L)
    if L % c:
        c = L  # irregular tails fall back to one chunk (smoke-test sizes)
    nchunk = L // c
    h0 = (jnp.zeros((B, di, cfg.ssm_d_state), jnp.float32)
          if h0 is None else h0.astype(jnp.float32))

    def outer(h, inp):
        dA_c, dBx_c, C_c = inp
        states, h_next = _chunk_scan(h, dA_c, dBx_c)
        y_c = jnp.einsum("bldn,bln->bld", states, C_c)
        return h_next, y_c

    dA_ch = dA.reshape(B, nchunk, c, di, -1).swapaxes(0, 1)
    dBx_ch = dBx.reshape(B, nchunk, c, di, -1).swapaxes(0, 1)
    C_ch = Cc.reshape(B, nchunk, c, -1).swapaxes(0, 1)
    h_final, y_ch = jax.lax.scan(outer, h0, (dA_ch, dBx_ch, C_ch))
    y = y_ch.swapaxes(0, 1).reshape(B, L, di)
    y = y + p["D"][None, None, :] * xc.astype(jnp.float32)
    return y.astype(x.dtype), h_final, conv_tail


def mamba_block(x: jax.Array, p: dict, cfg, state: dict | None = None,
                mode: str = "train"):
    """Full Mamba block. x (B, L, d) → (B, L, d), new_state.

    state = {"h": (B, di, N) fp32, "conv": (B, K-1, di)}.
    """
    B, L, d = x.shape
    di = cfg.ssm_d_inner
    xz = jnp.einsum("bld,dk->blk", x, p["w_in"].astype(x.dtype))
    xs, z = jnp.split(xz, 2, axis=-1)
    h0 = state["h"] if state is not None else None
    conv0 = state["conv"] if state is not None else None
    y, h_final, conv_tail = mamba_mix(xs, p, cfg, h0=h0, conv0=conv0)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("blk,kd->bld", y, p["w_out"].astype(y.dtype))
    new_state = {"h": h_final, "conv": conv_tail}
    return out, new_state


def mamba_decode_step(x: jax.Array, p: dict, cfg, state: dict):
    """One-token decode. x (B, 1, d); state carried. Returns (y, state)."""
    return mamba_block(x, p, cfg, state=state, mode="decode")


def init_mamba_state(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    di = cfg.ssm_d_inner
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype),
    }
