"""Architecture configuration schema.

One ``ArchConfig`` describes any assigned architecture; family-specific
fields are ignored by other families. ``reduced()`` produces the smoke-test
variant (same family/topology, tiny dims). Exact assigned configs live in
``repro.configs.<id>``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None       # default d_model // n_heads

    # --- attention flavor ---------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int | None = None        # window for local layers
    local_global_alternating: bool = False   # gemma2: even layers local
    attn_softcap: float | None = None        # gemma2: 50.0
    final_softcap: float | None = None       # gemma2: 30.0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1                # apply MoE on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.5
    router_aux_weight: float = 0.01

    # --- SSM (Mamba1) ----------------------------------------------------------
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2

    # --- hybrid (jamba): within each block of ``hybrid_period`` layers,
    #     layer index ``hybrid_attn_index`` is attention, the rest Mamba.
    hybrid_period: int = 8
    hybrid_attn_index: int = 4

    # --- encoder-decoder (whisper) ----------------------------------------------
    enc_layers: int = 0
    enc_ctx: int = 0                  # precomputed frame embeddings length
    enc_dim: int = 0                  # frontend stub output dim

    # --- VLM (llava) --------------------------------------------------------------
    n_patches: int = 0                # precomputed patch embeddings (anyres)

    # --- execution policy -----------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "none"               # none | dots | full
    grad_accum: int = 1               # training microbatches (MoE memory)
    prefill_chunk: int | None = None  # chunked prefill (vLLM-style)
    logits_fp32: bool = True
    loss_chunk: int = 512             # sequence-chunked cross-entropy
    scan_layers: bool = True          # lax.scan over stacked layer params
    opt_state_dtype: str = "fp32"     # fp32 | bf16 | int8 (Adam moments)

    # --- metadata ----------------------------------------------------------------------
    source: str = ""                  # provenance tag from the assignment
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))
        if self.family == "moe" and (self.n_experts <= 0 or self.moe_top_k <= 0):
            raise ValueError(f"{self.name}: moe family needs experts/top_k")
        if self.family in ("dense", "moe", "vlm") and self.n_heads % max(1, self.n_kv_heads):
            raise ValueError(f"{self.name}: n_heads must divide by n_kv_heads")

    # -- derived ------------------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kinds(self) -> list[str]:
        """Static per-layer structure: 'attn' or 'mamba'."""
        if self.family == "ssm":
            return ["mamba"] * self.n_layers
        if self.family == "hybrid":
            return ["attn" if i % self.hybrid_period == self.hybrid_attn_index
                    else "mamba" for i in range(self.n_layers)]
        return ["attn"] * self.n_layers

    def mlp_kinds(self) -> list[str]:
        """Static per-layer MLP structure: 'dense' or 'moe' ('none' for ssm)."""
        if self.family == "ssm":
            return ["none"] * self.n_layers    # mamba block subsumes the MLP
        if self.n_experts > 0:
            return ["moe" if i % self.moe_every == self.moe_offset else "dense"
                    for i in range(self.n_layers)]
        return ["dense"] * self.n_layers

    def window_for_layer(self, i: int) -> int | None:
        if self.local_global_alternating:
            return self.sliding_window if i % 2 == 0 else None
        return self.sliding_window

    def param_count(self) -> int:
        """Analytic parameter count (drives 6·N·D MODEL_FLOPS)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += V * d * 2                                        # embed + head
        kinds = self.layer_kinds()
        mlps = self.mlp_kinds()
        for i in range(self.n_layers):
            if kinds[i] == "attn":
                n += d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
            else:
                di = self.ssm_d_inner
                ns = self.ssm_d_state
                n += d * 2 * di + di * self.ssm_d_conv + di * (2 * ns + 1) \
                     + di * ns + di + di * d                  # in,conv,proj,A,D,out
            if mlps[i] == "dense":
                n += 3 * d * ff
            elif mlps[i] == "moe":
                n += 3 * d * self.d_ff_expert * self.n_experts + d * self.n_experts
            n += 2 * d                                        # norms
        if self.family == "encdec":
            for _ in range(self.enc_layers):
                n += 4 * d * d + 3 * d * ff + 2 * d           # enc self-attn + mlp
                n += 4 * d * d + d                            # dec cross-attn
            n += self.enc_dim * d                             # frontend projector
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for k in self.mlp_kinds() if k == "moe")
        all_exp = 3 * self.d_model * self.d_ff_expert * self.n_experts * moe_layers
        act_exp = 3 * self.d_model * self.d_ff_expert * self.moe_top_k * moe_layers
        return full - all_exp + act_exp

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims."""
        kv = max(1, min(self.n_kv_heads, 2))
        heads = max(kv, 4)
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, self.hybrid_period if self.family == "hybrid" else 4),
            d_model=128, n_heads=heads, n_kv_heads=kv, head_dim=32,
            d_ff=256, vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            d_ff_expert=64 if self.n_experts else 0,
            sliding_window=64 if self.sliding_window else None,
            enc_layers=min(self.enc_layers, 2),
            enc_ctx=16 if self.family == "encdec" else 0,
            enc_dim=48 if self.family == "encdec" else 0,
            n_patches=8 if self.family == "vlm" else 0,
            ssm_d_state=8, ssm_expand=2,
            grad_accum=1, prefill_chunk=None, loss_chunk=64,
        )
        kw.update(overrides)
        return replace(self, **kw)
