"""GQA attention: prefill and decode paths.

Pure-jnp formulation (clean HLO for the dry-run roofline; XLA fuses the
softmax chain). On real TPUs, ``use_pallas=True`` at the model level routes
through ``repro.kernels.ops.flash_attention`` / ``decode_attention`` instead.

Supports: grouped KV heads, sliding-window + causal masks with absolute
positions (``kv_offset`` for chunked prefill), attention logit softcap
(gemma2), ragged decode lengths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope

NEG_INF = -1e30


def qkv_project(x: jax.Array, p: dict, positions: jax.Array,
                rope_theta: float | None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (B, S, d) → q (B, S, H, dh), k/v (B, S, Hkv, dh), roped."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attend_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int | None = None,
                   softcap: float | None = None, kv_offset: int | jax.Array = 0,
                   kv_chunk: int = 1024) -> jax.Array:
    """q (B, Sq, H, dh); k/v (B, Skv, Hkv, dh) → (B, Sq, H, dh).

    Online-softmax over KV chunks (flash structure in jnp): logits exist
    only as (B, Hkv, g, Sq, kv_chunk) tiles inside the scan, never at
    (…, Sq, Skv) scale — the XLA-space analogue of
    ``kernels/flash_attention`` (§Perf A iteration 1).

    Query i sits at absolute position i + kv_offset; kv j at position j.
    """
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh).astype(jnp.float32) * (dh ** -0.5)
    qpos = jnp.arange(Sq)[:, None] + kv_offset                 # (Sq, 1)

    kc = min(kv_chunk, Skv)
    if Skv % kc:
        kc = Skv  # irregular sizes (whisper 1500): single chunk
    nk = Skv // kc
    ks = k.reshape(B, nk, kc, Hkv, dh).swapaxes(0, 1)
    vs = v.reshape(B, nk, kc, Hkv, dh).swapaxes(0, 1)

    def body(carry, inp):
        m, l, acc = carry
        kcnk, vcnk, j = inp
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                            kcnk.astype(jnp.float32))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        kpos = j * kc + jnp.arange(kc)[None, :]                # (1, kc)
        mask = jnp.ones((Sq, kc), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        # bf16 probabilities (max error ~4e-3 on p∈[0,1]), fp32 accumulate —
        # halves the dominant tile traffic (§Perf B iteration 1).
        acc = alpha[..., None] * acc + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(jnp.bfloat16), vcnk,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, g, Sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (ks, vs, jnp.arange(nk, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]               # (B,Hkv,g,Sq,dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dh)
    return out.astype(q.dtype)


def attend_prefill_dynwin(q, k, v, *, window: jax.Array,
                          softcap: float | None = None,
                          kv_offset: int | jax.Array = 0) -> jax.Array:
    """Like attend_prefill but ``window`` is a traced scalar (gemma2's
    alternating local/global layers inside one scanned stack: window is a
    per-layer value; a huge window ≡ global attention)."""
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (dh ** -0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None] + kv_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def attend_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  kv_len: jax.Array, *, window: int | jax.Array | None = None,
                  softcap: float | None = None) -> jax.Array:
    """One-token decode. q (B, H, dh); caches (B, S, Hkv, dh); kv_len (B,).

    The new token sits at absolute position kv_len − 1 (already appended).

    NOTE (§Perf E, refuted): slicing the cache read to the sliding window
    (gemma2 local layers: 4 k of 32 k) was tried and made the cell 6×
    WORSE — the per-row dynamic_slice fights the KV **sequence** sharding
    (kv_heads < model axis ⇒ seq@model), forcing GSPMD to all-gather the
    whole cache (collective 0.78 ms → 699 ms). The masked full read below
    is optimal under this layout; window slicing needs a ring-buffer /
    paged-KV layout instead (future work, `kernels/decode_attention`
    handles it with ragged kv_len on real TPU).
    """
    B, H, dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, dh)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * (dh ** -0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(S)[None, :]
    mask = kpos < kv_len[:, None]                              # (B, S)
    if window is not None:
        mask &= kpos > (kv_len[:, None] - 1) - window
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, H, dh).astype(q.dtype)


def out_project(attn: jax.Array, p: dict) -> jax.Array:
    """attn (..., H, dh) @ wo (H, dh, d) → (..., d)."""
    return jnp.einsum("...hk,hkd->...d", attn, p["wo"].astype(attn.dtype))
