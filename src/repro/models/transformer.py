"""Unified decoder stack for all assigned families.

The stack is a ``lax.scan`` over *layer groups*: the smallest repeating
pattern of statically-typed sublayers (dense: 1 attn layer; gemma2:
[local, global]; jamba: 8-layer [mamba×4, attn, mamba×3] block with
alternating dense/MoE FFNs; falcon-mamba: 1 mamba layer). Group params are
stacked on a leading axis so HLO size is O(group), not O(depth) — a
95-layer deepseek compiles the same HLO as a 1-layer model.

Attention is internally q-chunked (``lax.scan`` over query blocks) so full
(Sq × Skv) logits never materialize: 32 k-token prefill peaks at
(B, H, q_chunk, Skv) per layer. Sliding windows are *static* per sublayer
(group unrolling makes gemma2's alternation static), letting local layers
slice their KV range instead of masking the full sequence.

Modes:
    train    — full sequence, no cache
    prefill  — writes the KV/SSM cache; optionally chunked at the model
               level (static chunk offsets; kimi-k2 memory)
    decode   — one token against the cache (kv_len-ragged)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.distributed.context import Dist
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models.layers import (dtype_of, init_attention, init_mamba,
                                 init_mlp, init_moe, rms_norm, swiglu)

BIG_WINDOW = 1 << 30


@dataclass(frozen=True)
class SubLayerSpec:
    kind: str                 # "attn" | "mamba"
    mlp: str                  # "dense" | "moe" | "none"
    window: int | None = None
    causal: bool = True
    cross: bool = False       # whisper decoder cross-attention


def layer_pattern(cfg) -> list[SubLayerSpec]:
    kinds = cfg.layer_kinds()
    mlps = cfg.mlp_kinds()
    return [SubLayerSpec(kind=kinds[i], mlp=mlps[i],
                         window=cfg.window_for_layer(i),
                         cross=(cfg.family == "encdec"))
            for i in range(cfg.n_layers)]


def layer_groups(cfg) -> tuple[list[SubLayerSpec], int]:
    """Minimal repeating group and its count."""
    pat = layer_pattern(cfg)
    L = len(pat)
    for p in range(1, L + 1):
        if L % p == 0 and all(pat[i] == pat[i % p] for i in range(L)):
            return pat[:p], L // p
    return pat, 1


# ---------------------------------------------------------------------------
# Parameter initialization (stacked over groups).
# ---------------------------------------------------------------------------

def init_sublayer(key, cfg, spec: SubLayerSpec) -> dict:
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln_mix": jnp.zeros((d,), jnp.float32)}
    if spec.kind == "attn":
        p["mix"] = init_attention(keys[0], cfg)
    else:
        p["mix"] = init_mamba(keys[0], cfg)
    if spec.cross:
        p["ln_cross"] = jnp.zeros((d,), jnp.float32)
        p["cross"] = init_attention(keys[3], cfg)
    if spec.mlp == "dense":
        p["ln_mlp"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = init_mlp(keys[1], d, cfg.d_ff, dt)
    elif spec.mlp == "moe":
        p["ln_mlp"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = init_moe(keys[2], cfg)
    return p


def init_stack(key, cfg) -> dict:
    """Group-stacked params: leaf shapes (n_groups, ...)."""
    group, n_groups = layer_groups(cfg)
    keys = jax.random.split(key, n_groups)

    def one_group(k):
        sub = jax.random.split(k, len(group))
        return {f"sub{i}": init_sublayer(sub[i], cfg, spec)
                for i, spec in enumerate(group)}

    per_group = [one_group(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    """Group-stacked cache pytree (zeros; kv_len tracks validity)."""
    dt = dtype or dtype_of(cfg)
    group, n_groups = layer_groups(cfg)

    def one(spec: SubLayerSpec) -> dict:
        if spec.kind == "attn":
            return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dt),
                    "v": jnp.zeros((batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dt)}
        return mam.init_mamba_state(cfg, batch, dt)

    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), tree)

    return {f"sub{i}": stack(one(spec)) for i, spec in enumerate(group)}


# ---------------------------------------------------------------------------
# Attention sublayer (train / prefill / decode).
# ---------------------------------------------------------------------------

def _q_chunked_attend(q, k, v, *, causal, window, softcap, kv_offset,
                      q_chunk: int):
    """Scan over query chunks so (Sq×Skv) logits never materialize."""
    B, Sq, H, dh = q.shape
    if Sq <= q_chunk:
        return attn.attend_prefill(q, k, v, causal=causal, window=window,
                                   softcap=softcap, kv_offset=kv_offset)
    if Sq % q_chunk:
        # largest divisor of Sq ≤ q_chunk (whisper's 1500-frame encoder)
        q_chunk = next(c for c in range(q_chunk, 0, -1) if Sq % c == 0)
    nc = Sq // q_chunk
    qs = q.reshape(B, nc, q_chunk, H, dh).swapaxes(0, 1)   # (nc,B,qc,H,dh)

    def body(_, inp):
        qc, i = inp
        out = attn.attend_prefill(qc, k, v, causal=causal, window=window,
                                  softcap=softcap,
                                  kv_offset=kv_offset + i * q_chunk)
        return None, out

    _, outs = jax.lax.scan(body, None,
                           (qs, jnp.arange(nc, dtype=jnp.int32)))
    return outs.swapaxes(0, 1).reshape(B, Sq, H, dh)


def _seq_shard(arr, dist, cfg):
    """Context parallelism fallback: when heads don't divide the model
    axis, shard the QUERY SEQUENCE over it instead (otherwise attention
    compute replicates 16× across model shards — §Perf A iteration 2)."""
    if (dist is None or dist.mesh is None
            or cfg.n_heads % max(1, dist.n_model) == 0
            or arr.shape[1] % max(1, dist.n_model) != 0):
        return arr
    return jax.lax.with_sharding_constraint(
        arr, dist.sharding(dist.batch_axes, dist.model_axis, None, None))


def attn_sublayer(x, sp, cfg, spec: SubLayerSpec, *, mode: str,
                  positions, cache=None, kv_len=None, kv_offset: int = 0,
                  q_chunk: int = 256, dist=None):
    """Returns (out (same shape as x), new_cache)."""
    h = rms_norm(x, sp["ln_mix"], cfg.norm_eps)
    theta = cfg.rope_theta if cfg.family != "encdec" else None
    window = spec.window

    if mode == "decode":
        # x (B, 1, d); cache (B, S, Hkv, dh); write at kv_len, read ≤ kv_len.
        q, k, v = attn.qkv_project(h, sp["mix"], positions, theta)
        B = x.shape[0]
        bidx = jnp.arange(B)
        new_k = cache["k"].at[bidx, kv_len].set(k[:, 0].astype(cache["k"].dtype))
        new_v = cache["v"].at[bidx, kv_len].set(v[:, 0].astype(cache["v"].dtype))
        out = attn.attend_decode(q[:, 0], new_k, new_v, kv_len + 1,
                                 window=window, softcap=cfg.attn_softcap)
        out = attn.out_project(out, sp["mix"])[:, None, :]
        return x + out.astype(x.dtype), {"k": new_k, "v": new_v}

    q, k, v = attn.qkv_project(h, sp["mix"], positions, theta)
    new_cache = cache
    if mode == "prefill":
        S = x.shape[1]
        new_k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, kv_offset, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, kv_offset, 0, 0))
        new_cache = {"k": new_k, "v": new_v}
        if kv_offset > 0:
            # Chunked prefill: attend against everything cached so far.
            hist = kv_offset + S
            k_att = jax.lax.slice_in_dim(new_k, 0, hist, axis=1).astype(q.dtype)
            v_att = jax.lax.slice_in_dim(new_v, 0, hist, axis=1).astype(q.dtype)
            out = _q_chunked_attend(q, k_att, v_att, causal=spec.causal,
                                    window=window, softcap=cfg.attn_softcap,
                                    kv_offset=kv_offset, q_chunk=q_chunk)
            out = attn.out_project(out, sp["mix"])
            return x + out.astype(x.dtype), new_cache

    q = _seq_shard(q, dist, cfg)
    out = _q_chunked_attend(q, k, v, causal=spec.causal, window=window,
                            softcap=cfg.attn_softcap, kv_offset=0,
                            q_chunk=q_chunk)
    out = _seq_shard(out, dist, cfg)
    out = attn.out_project(out, sp["mix"])
    return x + out.astype(x.dtype), new_cache


def cross_sublayer(x, sp, cfg, enc_kv):
    """Whisper decoder cross-attention (enc K/V precomputed)."""
    h = rms_norm(x, sp["ln_cross"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, sp["cross"]["wq"].astype(h.dtype))
    out = attn.attend_prefill(q, enc_kv["k"], enc_kv["v"], causal=False,
                              window=None, softcap=None)
    out = attn.out_project(out, sp["cross"])
    return x + out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Full sublayer + group application.
# ---------------------------------------------------------------------------

def sublayer_apply(x, sp, cfg, spec: SubLayerSpec, dist: Dist | None, *,
                   mode: str, positions, cache, kv_len, kv_offset,
                   enc_kv=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.kind == "attn":
        x, new_cache = attn_sublayer(x, sp, cfg, spec, mode=mode,
                                     positions=positions, cache=cache,
                                     kv_len=kv_len, kv_offset=kv_offset,
                                     dist=dist)
    else:
        h = rms_norm(x, sp["ln_mix"], cfg.norm_eps)
        if mode == "train":
            out, new_cache = mam.mamba_block(h, sp["mix"], cfg, state=None)
        else:
            out, new_cache = mam.mamba_block(h, sp["mix"], cfg, state=cache)
        x = x + out.astype(x.dtype)

    if spec.cross and enc_kv is not None:
        x = cross_sublayer(x, sp, cfg, enc_kv)

    if spec.mlp == "dense":
        h = rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
        x = x + swiglu(h, sp["mlp"]["w_gate"], sp["mlp"]["w_up"],
                       sp["mlp"]["w_down"]).astype(x.dtype)
    elif spec.mlp == "moe":
        h = rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
        y, aux = moe_mod.moe_apply(h, sp["mlp"], cfg, dist)
        x = x + y.astype(x.dtype)
    return x, new_cache, aux


def _constrain(x, dist: Dist | None):
    if dist is not None and dist.mesh is not None:
        x = jax.lax.with_sharding_constraint(
            x, dist.sharding(dist.batch_axes, None, None))
    return x


def stack_apply(x, stack_params, cfg, dist: Dist | None, *, mode: str,
                positions, cache=None, kv_len=None, kv_offset: int = 0,
                enc_kv=None, group=None):
    """Scan the group-stacked params over the input.

    Returns (x, new_cache, total_aux). ``cache``/new_cache are group-stacked
    pytrees (or None in train mode).
    """
    if group is None:
        group, _ = layer_groups(cfg)

    def group_body(x, gp, gcache, genc):
        new_caches = {}
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(group):
            sub_cache = gcache[f"sub{i}"] if gcache is not None else None
            x, nc, aux = sublayer_apply(
                x, gp[f"sub{i}"], cfg, spec, dist, mode=mode,
                positions=positions, cache=sub_cache, kv_len=kv_len,
                kv_offset=kv_offset, enc_kv=genc)
            new_caches[f"sub{i}"] = nc
            aux_total = aux_total + aux
        x = _constrain(x, dist)
        return x, new_caches, aux_total

    if cfg.remat == "dots":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.checkpoint_dots)
    elif cfg.remat == "full":
        group_body = jax.checkpoint(group_body)

    xs = {"p": stack_params}
    if cache is not None:
        xs["c"] = cache
    if enc_kv is not None:
        xs["e"] = enc_kv                     # group-stacked cross K/V

    def scan_body(x, inp):
        x, new_cache, aux = group_body(x, inp["p"], inp.get("c"),
                                       inp.get("e"))
        return x, (new_cache if cache is not None else 0, aux)

    x, (new_cache, auxs) = jax.lax.scan(scan_body, x, xs)
    return x, (new_cache if cache is not None else None), jnp.sum(auxs)
