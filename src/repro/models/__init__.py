"""LLM substrate: composable model definitions for all assigned families.

    config       — ArchConfig (dense / moe / hybrid / ssm / encdec / vlm)
    layers       — norms, embeddings, rotary, MLP, inits
    attention    — GQA attention (prefill + decode, window/softcap)
    moe          — expert-parallel MoE (sort + all_to_all + ragged_dot)
    mamba        — Mamba1 block (associative-scan prefill, stepwise decode)
    transformer  — scanned decoder stack with heterogeneous layer patterns
    model        — Model facade: init, loss_fn, prefill_step, decode_step
"""

from repro.models.config import ArchConfig  # noqa: F401
from repro.models.model import Model  # noqa: F401
