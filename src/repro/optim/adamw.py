"""AdamW from scratch, with optionally int8-blockwise-quantized moments.

State layout per parameter leaf:
    fp32  — m, v in fp32 (default; exact Adam)
    bf16  — m, v in bf16 (half-memory, negligible quality delta)
    int8  — m, v int8 with fp32 scales per 128-wide block of the last axis
            (bitsandbytes-style). This is the distributed-optimization trick
            that lets the 1T-param kimi-k2 config fit HBM: moments cost
            2 B/param instead of 8 B/param. Requires last_dim % 128 == 0
            (all kimi leaves satisfy this; checked at init).

Because parameters are sharded 2-D/3-D by GSPMD (FSDP×TP; DESIGN.md §4),
moments inherit the same sharding — the update is fully local (ZeRO-3-like
without explicit machinery). Gradient clipping uses a global-norm psum that
GSPMD derives from the shardings.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

QBLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    state_dtype: str = "fp32"          # fp32 | bf16 | int8
    warmup_steps: int = 100
    schedule: str = "cosine"           # cosine | constant
    total_steps: int = 10000

    def lr_at(self, step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1.0) / max(1, self.warmup_steps))
        if self.schedule == "constant":
            return self.lr * warm
        frac = jnp.clip((s - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return self.lr * warm * (0.1 + 0.9 * cos)


# -- int8 blockwise quantization ------------------------------------------------
#
# m (signed, smooth): linear symmetric per-block quant.
# v (non-negative, 10^4+ dynamic range): LINEAR quant zeroes small entries
# and 1/sqrt(v̂) then explodes — so v is quantized in log2 domain with
# per-block (lo, span) scales; relative error ≤ ~6 % in v ⇒ ≤3 % in the
# Adam denominator. Scales cost 2×4 B per 128 block ≈ 0.06 B/param.

_V_FLOOR = 1e-24


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp32 (..., D) → (int8 (..., D), fp32 scales (..., D/QBLOCK))."""
    D = x.shape[-1]
    assert D % QBLOCK == 0, f"int8 state needs last dim % {QBLOCK} == 0, got {D}"
    xb = x.reshape(*x.shape[:-1], D // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    q = jnp.round(xb / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    return q.reshape(x.shape), scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    D = q.shape[-1]
    qb = q.reshape(*q.shape[:-1], D // QBLOCK, QBLOCK).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(q.shape)


def _quantize_log(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Non-negative fp32 → int8 in log2 domain, scales (..., blocks, 2)."""
    D = x.shape[-1]
    assert D % QBLOCK == 0
    xb = jnp.log2(x.reshape(*x.shape[:-1], D // QBLOCK, QBLOCK) + _V_FLOOR)
    lo = jnp.min(xb, axis=-1)
    span = jnp.maximum(jnp.max(xb, axis=-1) - lo, 1e-6)
    q = jnp.round((xb - lo[..., None]) / span[..., None] * 254.0 - 127.0)
    q = q.astype(jnp.int8)
    return q.reshape(x.shape), jnp.stack([lo, span], axis=-1)


def _dequantize_log(q: jax.Array, scale: jax.Array) -> jax.Array:
    D = q.shape[-1]
    qb = q.reshape(*q.shape[:-1], D // QBLOCK, QBLOCK).astype(jnp.float32)
    lo, span = scale[..., 0], scale[..., 1]
    x = jnp.exp2(lo[..., None] + (qb + 127.0) / 254.0 * span[..., None])
    return jnp.maximum(x - _V_FLOOR, 0.0).reshape(q.shape)


# -- state ------------------------------------------------------------------------

def _moment_init(p: jax.Array, state_dtype: str, kind: str):
    if state_dtype == "int8":
        D = p.shape[-1] if p.ndim else 0
        if p.ndim == 0 or D % QBLOCK:
            # scalars/norm vectors stay fp32 (tiny)
            return {"q": jnp.zeros_like(p, jnp.float32), "s": None}
        blocks = (*p.shape[:-1], D // QBLOCK)
        if kind == "v":   # log-domain: scales are (lo, span) pairs
            return {"q": jnp.full(p.shape, -127, jnp.int8),
                    "s": jnp.stack([jnp.full(blocks, jnp.log2(_V_FLOOR)),
                                    jnp.full(blocks, 1e-6)], axis=-1)}
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(blocks, jnp.float32)}
    dt = jnp.bfloat16 if state_dtype == "bf16" else jnp.float32
    return {"q": jnp.zeros(p.shape, dt), "s": None}


def _is_log_scale(q: jax.Array, s: jax.Array) -> bool:
    return s.ndim == q.ndim + 1


def _moment_read(mo: dict) -> jax.Array:
    s = mo.get("s")
    if s is None:
        return mo["q"].astype(jnp.float32)
    if _is_log_scale(mo["q"], s):
        return _dequantize_log(mo["q"], s)
    return _dequantize(mo["q"], s)


def _moment_write(mo: dict, val: jax.Array) -> dict:
    s = mo.get("s")
    if s is None:
        return {"q": val.astype(mo["q"].dtype), "s": None}
    if _is_log_scale(mo["q"], s):
        q, s = _quantize_log(val)
    else:
        q, s = _quantize(val)
    return {"q": q, "s": s}


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    is_arr = lambda x: isinstance(x, jax.Array)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _moment_init(p, cfg.state_dtype, "m"),
                          params, is_leaf=is_arr),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg.state_dtype, "v"),
                          params, is_leaf=is_arr),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_adamw(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_mo = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

    def update(p, g, mo, vo):
        g = g.astype(jnp.float32)
        m = cfg.b1 * _moment_read(mo) + (1 - cfg.b1) * g
        v = cfg.b2 * _moment_read(vo) + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # no decay on norms/biases
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, _moment_write(mo, m), _moment_write(vo, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [update(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
