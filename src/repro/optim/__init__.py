"""Optimizer substrate (no optax): AdamW with quantized-state option."""

from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    init_opt_state,
    apply_adamw,
    global_norm,
)
