"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. Experts are zero-padded to
a multiple of the data-axis size for EP (40 → 48 on a 16-wide axis).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    d_ff_expert=512,
    n_experts=40,
    moe_top_k=8,
    moe_every=1,
    vocab_size=49155,
    rope_theta=10000.0,
    capacity_factor=1.5,
    remat="dots",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
