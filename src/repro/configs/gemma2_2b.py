"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]. head_dim=256 (gemma2 uses wide heads: 8×256=2048).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    rope_theta=10000.0,
    sliding_window=4096,
    local_global_alternating=True,   # even layers local(4096), odd global
    attn_softcap=50.0,
    final_softcap=30.0,
    remat="dots",
    source="arXiv:2408.00118; hf",
    notes="26 layers alternate local/global; 26%2==0 so the scan group is "
          "[local, global]×13. Embeddings gemma-scaled by sqrt(d_model).",
)
