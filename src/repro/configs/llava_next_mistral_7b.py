"""llava-next-mistral-7b [vlm] — anyres tiling, mistral-7b backbone.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. The vision frontend is
a STUB: ``input_specs()`` provides precomputed anyres patch embeddings
(B, 2880, d_model) = 5 tiles × 576 patches, prepended to the text tokens.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    n_patches=2880,
    remat="dots",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
