"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed.

32L d_model=1280 20H (kv=20 ⇒ plain MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356; unverified]. 32 encoder + 32 decoder layers; the conv
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
(B, 1500, 1280). Sinusoidal absolute positions (no RoPE).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,             # decoder layers
    enc_layers=32,
    enc_ctx=1500,
    enc_dim=1280,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    remat="dots",
    source="arXiv:2212.04356; unverified",
    notes="decode shapes exercise the decoder self-attn KV cache at the "
          "assigned lengths (mechanical; real whisper caps at 448 tokens).",
)
