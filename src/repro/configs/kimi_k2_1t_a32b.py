"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8
[arXiv:2501.kimi2; unverified]. All layers MoE with expert d_ff=2048;
expert-parallel over the data axis, expert-ffn over the model axis.
grad_accum=8 keeps the routing buffers ≲1.5 GB/device at train_4k;
prefill_32k is chunked (vLLM-style) for the same reason.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,               # unused (all layers MoE); kept for reporting
    d_ff_expert=2048,
    n_experts=384,
    moe_top_k=8,
    moe_every=1,
    vocab_size=163840,
    rope_theta=50000.0,
    capacity_factor=1.25,
    remat="full",
    grad_accum=8,
    prefill_chunk=4096,
    opt_state_dtype="int8",   # 2 B/param moments: 1T params fit 512×16 GB

    source="arXiv:2501.kimi2; unverified",
)
