"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]. Each 8-layer block: attention at index 4, Mamba
elsewhere; MoE FFN on odd layers (16 of 32), dense d_ff=14336 on even.
Runs long_500k (sub-quadratic: 4 of 32 layers are attention; those use a
4096-token sliding window at 500 k with KV-sequence sharding).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    d_ff_expert=14336,
    n_experts=16,
    moe_top_k=2,
    moe_every=2,
    moe_offset=1,
    vocab_size=65536,
    rope_theta=10000.0,
    hybrid_period=8,
    hybrid_attn_index=4,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    capacity_factor=1.5,
    remat="dots",
    grad_accum=2,
    source="arXiv:2403.19887; hf",
)
