"""falcon-mamba-7b [ssm] — mamba1 architecture, attention-free.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified]. d_inner = 2·d_model = 8192; runs
long_500k (state-space decode is O(1) per token in context length).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65024,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    remat="dots",
    source="arXiv:2410.05355; unverified",
)
