"""Assigned architecture configs (+ shapes).

``get_config(arch_id)`` returns the exact assigned ``ArchConfig``;
``SHAPES`` maps shape ids to (seq_len, global_batch, step kind);
``runnable_cells()`` enumerates the dry-run matrix with documented skips
(DESIGN.md §5).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.config import ArchConfig

ARCH_IDS = [
    "gemma2_2b",
    "deepseek_67b",
    "llama3_2_3b",
    "granite_8b",
    "whisper_large_v3",
    "kimi_k2_1t_a32b",
    "granite_moe_3b_a800m",
    "jamba_v0_1_52b",
    "llava_next_mistral_7b",
    "falcon_mamba_7b",
]

# Canonical hyphenated ids from the assignment → module names.
ALIASES = {
    "gemma2-2b": "gemma2_2b",
    "deepseek-67b": "deepseek_67b",
    "llama3.2-3b": "llama3_2_3b",
    "granite-8b": "granite_8b",
    "whisper-large-v3": "whisper_large_v3",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention (DESIGN.md §5): run only for the
# SSM/hybrid archs; everything else is recorded as an explicit skip.
LONG_CONTEXT_ARCHS = {"jamba_v0_1_52b", "falcon_mamba_7b"}


def get_config(arch_id: str) -> ArchConfig:
    mod_name = ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def runnable_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            cells.append((arch, shape))
        if arch in LONG_CONTEXT_ARCHS:
            cells.append((arch, "long_500k"))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    return [(arch, "long_500k", "quadratic-attention")
            for arch in ARCH_IDS if arch not in LONG_CONTEXT_ARCHS]
