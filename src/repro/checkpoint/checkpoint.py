"""Sharded, atomic, async checkpointing with elastic reshard-on-restore.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json      — tree structure, leaf dtypes/shapes, extras
        arrays.npz         — flat {leaf_path: ndarray}; fp32/bf16/int8 kept
    ckpt_dir/step_000123.tmp…  → atomically renamed when complete

Restore is **elastic**: arrays are loaded as host numpy and re-placed with
``jax.device_put`` under the *restoring* mesh's shardings — a checkpoint
written on the 16×16 mesh restores onto 2×16×16 (or a single CPU device)
unchanged (DESIGN.md §4 fault tolerance). bf16 leaves round-trip via a
uint16 view (npz has no bf16).

``AsyncCheckpointer`` runs saves on a writer thread (training continues),
keeps the newest K checkpoints, and ``wait()`` joins at shutdown /
preemption (SIGTERM handler in ``repro.distributed.fault``).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(x)
    if arr.dtype == jax.numpy.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_numpy(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return arr.view(jax.numpy.bfloat16)
    return arr


def save_checkpoint(ckpt_dir: str, step: int, tree: dict,
                    extras: dict | None = None, keep: int = 3) -> str:
    """Atomic checkpoint write. ``extras`` = JSON-serializable state
    (data-pipeline cursor, RNG, config fingerprint)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}, "extras": extras or {}}
    for path, leaf in flat.items():
        arr, dtype = _to_numpy(leaf)
        arrays[path] = arr
        manifest["leaves"][path] = {"dtype": dtype,
                                    "shape": list(arr.shape)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.isdir(final):         # same-step overwrite (emergency save)
        shutil.rmtree(final)
    os.replace(tmp, final)           # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        (d for d in os.listdir(ckpt_dir) if re.fullmatch(r"step_\d+", d)))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if re.fullmatch(r"step_\d+", d)]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int | None = None,
                       shardings: dict | None = None,
                       ) -> tuple[dict, dict, int]:
    """Returns (tree, extras, step). ``shardings``: optional pytree of
    NamedSharding for elastic re-placement onto the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for leaf_path, meta in manifest["leaves"].items():
        flat[leaf_path] = _from_numpy(npz[leaf_path], meta["dtype"])
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        def place(path, arr):
            sh = flat_sh.get(path)
            return jax.device_put(arr, sh) if sh is not None else arr
        tree = _unflatten({p: place(p, a) for p, a in _flatten(tree).items()})
    return tree, manifest["extras"], step


class AsyncCheckpointer:
    """Writer-thread checkpointing: ``save`` snapshots to host immediately
    (so training can donate/overwrite buffers) and persists in background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: dict, extras: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def run():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extras,
                                self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
