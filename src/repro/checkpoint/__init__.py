"""Fault-tolerant checkpointing with elastic resharding."""

from repro.checkpoint.checkpoint import (  # noqa: F401
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    AsyncCheckpointer,
)
