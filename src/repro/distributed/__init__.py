"""Distributed runtime: mesh context, fault tolerance, elasticity."""

from repro.distributed.context import Dist  # noqa: F401
