"""Fault tolerance: preemption handling, straggler watchdog, retry.

``PreemptionHandler`` — SIGTERM/SIGINT → set a flag; the training loop
checkpoints and exits cleanly at the next step boundary (emergency save).

``StepWatchdog`` — detects stragglers/hangs: if a step exceeds
``timeout_factor ×`` the trailing-median step time, a callback fires
(alert / skip / abort). On a real multi-host deployment the callback wires
to the cluster manager to evict the slow host and trigger elastic restart;
here it is exercised by tests and the training driver's logging.

``retry_step`` — bounded retry with re-randomized donation buffers for
transient device errors (the restart path of checkpoint/restart is covered
by ``repro.checkpoint``). Backoff between attempts is charged to an
injectable ``repro.core.clock.Clock`` — a ``SimClock`` makes retry timing
deterministic and testable, a ``WallClock`` really sleeps; the default
``backoff_s=0`` keeps the historical retry-immediately behavior.
"""

from __future__ import annotations

import signal
import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = False
        self._installed = False
        self._signals = signals
        self._prev = {}

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._on_signal)
        self._installed = True
        return self

    def uninstall(self) -> None:
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        self._installed = False

    def _on_signal(self, signum, frame) -> None:
        self._flag = True

    @property
    def preempted(self) -> bool:
        return self._flag

    def trigger_for_test(self) -> None:
        self._flag = True


@dataclass
class StepWatchdog:
    timeout_factor: float = 3.0
    min_history: int = 5
    window: int = 32
    on_straggler: Callable[[float, float], None] | None = None
    _times: deque = field(default_factory=lambda: deque(maxlen=32))
    _start: float | None = None
    straggler_events: int = 0

    def step_start(self) -> None:
        self._start = time.monotonic()

    def step_end(self) -> float:
        assert self._start is not None, "step_end without step_start"
        dt = time.monotonic() - self._start
        self._start = None
        if len(self._times) >= self.min_history:
            med = statistics.median(self._times)
            if dt > self.timeout_factor * med:
                self.straggler_events += 1
                if self.on_straggler is not None:
                    self.on_straggler(dt, med)
        self._times.append(dt)
        return dt

    def observe_for_test(self, dt: float) -> None:
        """Inject a synthetic step time (unit tests)."""
        if len(self._times) >= self.min_history:
            med = statistics.median(self._times)
            if dt > self.timeout_factor * med:
                self.straggler_events += 1
                if self.on_straggler is not None:
                    self.on_straggler(dt, med)
        self._times.append(dt)


try:
    from jax.errors import JaxRuntimeError as _JAX_ERR
except Exception:                                 # pragma: no cover
    _JAX_ERR = RuntimeError


def retry_step(fn: Callable, *args, retries: int = 2,
               on_retry: Callable[[int, BaseException], None] | None = None,
               backoff_s: float = 0.0, clock=None):
    """Call ``fn(*args)``, retrying device/transient errors up to
    ``retries`` times. With ``backoff_s > 0`` the k-th retry waits
    ``backoff_s · 2^k`` seconds first, charged via ``clock.advance`` —
    pass a ``SimClock`` for deterministic tests, default is a real
    sleep."""
    last: BaseException | None = None
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except (RuntimeError, _JAX_ERR) as e:     # device/transient errors
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff_s > 0.0 and attempt < retries:
                if clock is None:
                    from repro.core.clock import WallClock
                    clock = WallClock()
                clock.advance(backoff_s * (2.0 ** attempt))
    raise last
