"""Mesh/axis context threaded through model code.

``Dist`` names the mesh axes and carries the sizes model code needs for
static shape math (MoE capacities, padding). ``Dist.single()`` is the
1-device stand-in used by smoke tests and examples — model code never
branches on "is distributed", only on axis sizes.

Axis convention (DESIGN.md §4):
    pod    — outer data parallelism (slow inter-pod links); optional
    data   — intra-pod data parallelism / FSDP / MoE expert ownership
    model  — tensor parallelism (heads, ffn, vocab) / MoE ffn sharding
"""

from __future__ import annotations

from dataclasses import dataclass

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Dist:
    mesh: Mesh | None = None
    pod_axis: str | None = None
    data_axis: str = "data"
    model_axis: str = "model"

    @classmethod
    def single(cls) -> "Dist":
        return cls(mesh=None)

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "Dist":
        names = mesh.axis_names
        return cls(mesh=mesh,
                   pod_axis="pod" if "pod" in names else None,
                   data_axis="data", model_axis="model")

    # -- sizes -----------------------------------------------------------
    def axis_size(self, name: str | None) -> int:
        if self.mesh is None or name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def n_pod(self) -> int:
        return self.axis_size(self.pod_axis)

    @property
    def n_data(self) -> int:
        return self.axis_size(self.data_axis) if self.mesh is not None else 1

    @property
    def n_model(self) -> int:
        return self.axis_size(self.model_axis) if self.mesh is not None else 1

    @property
    def n_devices(self) -> int:
        return self.n_pod * self.n_data * self.n_model

    # -- batch/token axes --------------------------------------------------
    @property
    def batch_axes(self):
        """Mesh axes that shard the batch/token dimension."""
        if self.mesh is None:
            return None
        return ((self.pod_axis, self.data_axis) if self.pod_axis
                else (self.data_axis,))

    def spec(self, *axes) -> P:
        """PartitionSpec helper; None entries pass through."""
        return P(*axes)

    def sharding(self, *axes) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*axes))
