"""repro: category-aware semantic caching for heterogeneous LLM workloads.

Production-grade JAX framework implementing Wang et al., "Category-Aware
Semantic Caching for Heterogeneous LLM Workloads" (CS.DB 2025):

- ``repro.core``     — the paper's contribution: category policy engine,
                       hybrid HNSW-in-memory / external-document cache,
                       break-even economics, adaptive load-based policies.
- ``repro.models``   — LLM substrate (dense / MoE / SSM / hybrid / enc-dec).
- ``repro.kernels``  — Pallas TPU kernels for the cache + attention hot spots.
- ``repro.serving``  — batched serving engine, multi-model router, simulator.
- ``repro.launch``   — production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"
