"""Break-even economics (paper §4.4, §5.5, §7.5.1) + residency capacity.

All latencies in milliseconds. The cost model generalizes eqs (1)–(6):

    L_cached = search_ms + h·fetch_ms + (1−h)·T_llm          (1)/(4)
    net benefit  ⇔  h > search_ms / (T_llm − fetch_ms)        (3)/(5)

Vector-DB:  search ≈ 30 ms (network 10–30 + server HNSW 10–15), fetch 5 ms.
Hybrid:     search ≈ 2 ms (local, in-memory), fetch 5 ms.
Under load: T_load = α·T_base  (§7.5.1, eq (6)).

``ResidencyModel`` prices the OTHER side of the ledger: how many entries
a byte budget holds in the compact in-memory tier, as a function of the
resident embedding dtype (§5.1 bytes-per-entry accounting). The paper's
per-category quota is a *fraction of capacity*; capacity itself is a
function of bytes/entry, so quantizing the resident tier to int8
(~4x smaller embedding component) multiplies the entries every category
quota can hold out of the same memory budget.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CostModel:
    """Latency cost model for one cache architecture."""

    name: str
    search_ms: float          # charged on EVERY query (hit or miss)
    hit_fetch_ms: float       # document fetch charged on hits only
    insert_ms: float = 1.0    # charged on miss-path insertion

    def expected_latency_ms(self, hit_rate: float, t_llm_ms: float) -> float:
        """Eq (1)/(4): expected per-query latency with this cache."""
        h = min(1.0, max(0.0, hit_rate))
        return self.search_ms + h * self.hit_fetch_ms + (1.0 - h) * t_llm_ms

    def break_even_hit_rate(self, t_llm_ms: float) -> float:
        """Eq (3)/(5): minimum hit rate for net benefit vs no cache."""
        denom = t_llm_ms - self.hit_fetch_ms
        if denom <= 0:
            return float("inf")  # model faster than the fetch: never viable
        return self.search_ms / denom

    def viable(self, hit_rate: float, t_llm_ms: float) -> bool:
        return hit_rate > self.break_even_hit_rate(t_llm_ms)

    def speedup(self, hit_rate: float, t_llm_ms: float) -> float:
        """T_llm / expected latency — >1 means the cache pays off."""
        return t_llm_ms / self.expected_latency_ms(hit_rate, t_llm_ms)


# The paper's calibrated constants.
VDB_COSTS = CostModel(name="vector_db", search_ms=30.0, hit_fetch_ms=5.0)
HYBRID_COSTS = CostModel(name="hybrid", search_ms=2.0, hit_fetch_ms=5.0)
# §7.6 document-caching extension: hot docs in memory → hit latency 2 ms.
HYBRID_HOT_COSTS = CostModel(name="hybrid_hotdocs", search_ms=2.0, hit_fetch_ms=0.0)


def expected_latency(hit_rate: float, t_llm_ms: float,
                     model: CostModel = HYBRID_COSTS) -> float:
    return model.expected_latency_ms(hit_rate, t_llm_ms)


def break_even_hit_rate(t_llm_ms: float, model: CostModel = HYBRID_COSTS) -> float:
    return model.break_even_hit_rate(t_llm_ms)


def break_even_under_load(t_base_ms: float, alpha: float,
                          model: CostModel = HYBRID_COSTS) -> float:
    """§7.5.1 eq (6): break-even with loaded model latency T_load = α·T_base."""
    return model.break_even_hit_rate(alpha * t_base_ms)


def traffic_reduction(h0: float, delta_h: float) -> float:
    """§7.5.2: load reduction factor Δh / (1 − h0).

    A category at hit rate h0 sends (1−h0) of traffic to the model; raising
    the hit rate by Δh cuts model traffic by Δh/(1−h0).
    """
    if not (0.0 <= h0 < 1.0):
        raise ValueError("h0 must be in [0,1)")
    return delta_h / (1.0 - h0)


def hit_rate_gain_linear(delta_threshold: float, sensitivity_k: float) -> float:
    """§7.5.4 linear model: Δh = k·δ  (k per unit threshold; the paper quotes
    k=0.5–2.0 per 0.01 of threshold, i.e. 50–200 per unit)."""
    return sensitivity_k * delta_threshold


# ---------------------------------------------------------------------------
# Residency capacity: entries per byte budget as a function of emb dtype.
# ---------------------------------------------------------------------------

# Embedding payload per resident row: fp32 rows, or int8 rows + one fp32
# symmetric dequant scale (matches DeviceResidentIndex.emb_row_nbytes).
EMB_TIER_BYTES = {
    "float32": lambda dim: dim * 4,
    "int8": lambda dim: dim + 4,
}


@dataclass(frozen=True)
class ResidencyModel:
    """Bytes-per-entry model of the compact in-memory tier (§5.1)."""

    dim: int = 384
    emb_dtype: str = "float32"     # resident embedding dtype
    graph_degree: int = 32         # level-0 neighbors per node, int32
    metadata_bytes: int = 112      # §5.1: id map + category + statistics

    def emb_bytes(self) -> int:
        try:
            return EMB_TIER_BYTES[self.emb_dtype](self.dim)
        except KeyError:
            raise ValueError(f"unknown emb_dtype {self.emb_dtype!r}")

    def bytes_per_entry(self) -> int:
        """Embedding tier + level-0 graph row + per-slot metadata."""
        return self.emb_bytes() + self.graph_degree * 4 + self.metadata_bytes

    def entries_per_mb(self) -> int:
        return int(1e6 // self.bytes_per_entry())

    def quota_entries(self, quota_fraction: float, budget_mb: float) -> int:
        """§5.4 quota math in byte terms: the entries a category's quota
        fraction holds out of a memory budget under this residency."""
        if not (0.0 <= quota_fraction <= 1.0):
            raise ValueError("quota_fraction must be in [0,1]")
        return int(quota_fraction * budget_mb * 1e6
                   // self.bytes_per_entry())

    def quota_bytes(self, quota_fraction: float, capacity_entries: int) -> int:
        """The inverse direction of ``quota_entries``: the resident bytes a
        category's quota ceiling pins out of an entry capacity — the unit
        the shard placement planner bin-packs (core/shard.py). A category
        entitled to ``int(quota · capacity)`` entries owns that many rows
        of the resident tier, priced at this residency's bytes/entry."""
        if not (0.0 <= quota_fraction <= 1.0):
            raise ValueError("quota_fraction must be in [0,1]")
        return int(quota_fraction * capacity_entries) * self.bytes_per_entry()


def entry_value_density(expected_hits_per_s, t_llm_ms, bytes_per_entry):
    """Economic eviction score: expected model-ms saved per second of
    residency, per byte pinned (core/admission.CostAwareEvictionScorer).

    ``density = E[hits/s] × T_llm / bytes_per_entry`` — an entry that
    re-hits often, fronts an expensive model, and costs few resident
    bytes is the last to evict; maximizing this over resident slots
    maximizes hit-rate-per-resident-byte, the unit ``bench_admission``
    gates on. Accepts scalars or numpy arrays (broadcasting)."""
    return expected_hits_per_s * t_llm_ms / bytes_per_entry


def residency_capacity_table(budget_mb: float, quotas: dict[str, float],
                             dim: int = 384, graph_degree: int = 32,
                             dtypes: tuple[str, ...] = ("float32", "int8")
                             ) -> dict:
    """Per-dtype capacity table: bytes/entry, entries/MB, and each
    category quota's entry ceiling under the budget — the quantized
    counterpart of Table 1's viability rows."""
    out: dict = {"budget_mb": budget_mb, "dim": dim, "dtypes": {}}
    for dt in dtypes:
        m = ResidencyModel(dim=dim, emb_dtype=dt, graph_degree=graph_degree)
        out["dtypes"][dt] = {
            "bytes_per_entry": m.bytes_per_entry(),
            "emb_bytes": m.emb_bytes(),
            "entries_per_mb": m.entries_per_mb(),
            "quota_entries": {c: m.quota_entries(qf, budget_mb)
                              for c, qf in quotas.items()},
        }
    return out


@dataclass(frozen=True)
class CategoryEconomics:
    """Economic report row for one category (feeds Table 1 viability)."""

    category: str
    traffic_share: float
    hit_rate: float
    t_llm_ms: float
    vdb_break_even: float
    hybrid_break_even: float
    vdb_viable: bool
    hybrid_viable: bool
    vdb_latency_ms: float
    hybrid_latency_ms: float
    uncached_latency_ms: float


def category_economics(category: str, traffic_share: float, hit_rate: float,
                       t_llm_ms: float,
                       vdb: CostModel = VDB_COSTS,
                       hybrid: CostModel = HYBRID_COSTS) -> CategoryEconomics:
    return CategoryEconomics(
        category=category,
        traffic_share=traffic_share,
        hit_rate=hit_rate,
        t_llm_ms=t_llm_ms,
        vdb_break_even=vdb.break_even_hit_rate(t_llm_ms),
        hybrid_break_even=hybrid.break_even_hit_rate(t_llm_ms),
        vdb_viable=vdb.viable(hit_rate, t_llm_ms),
        hybrid_viable=hybrid.viable(hit_rate, t_llm_ms),
        vdb_latency_ms=vdb.expected_latency_ms(hit_rate, t_llm_ms),
        hybrid_latency_ms=hybrid.expected_latency_ms(hit_rate, t_llm_ms),
        uncached_latency_ms=t_llm_ms,
    )


def workload_report(rows: list[CategoryEconomics]) -> dict:
    """Aggregate: coverage (traffic share cacheable) + mean latency under
    each architecture, weighting categories by traffic share. Non-viable
    categories bypass the cache (excluded) for their architecture."""
    total = sum(r.traffic_share for r in rows)
    cov_vdb = sum(r.traffic_share for r in rows if r.vdb_viable) / total
    cov_hyb = sum(r.traffic_share for r in rows if r.hybrid_viable) / total

    def mean_latency(which: str) -> float:
        acc = 0.0
        for r in rows:
            if which == "vdb":
                lat = r.vdb_latency_ms if r.vdb_viable else r.uncached_latency_ms
            elif which == "hybrid":
                lat = r.hybrid_latency_ms if r.hybrid_viable else r.uncached_latency_ms
            else:
                lat = r.uncached_latency_ms
            acc += r.traffic_share * lat
        return acc / total

    return {
        "coverage_vdb": cov_vdb,
        "coverage_hybrid": cov_hyb,
        "mean_latency_none_ms": mean_latency("none"),
        "mean_latency_vdb_ms": mean_latency("vdb"),
        "mean_latency_hybrid_ms": mean_latency("hybrid"),
    }
