"""Sharded cache tier: category-aware placement, fan-out, live migration.

The paper's §7.4 scaling note — beyond ~10 M entries, shard by category —
meets the ROADMAP north star here: one `SemanticCache` data plane tops
out at one device's HBM no matter how fast the fused lookup loop is, so
the resident tier must spread category quotas across N device-resident
shards WITHOUT giving up the masked-search or delta-sync guarantees.
Three pieces:

* ``ShardPlanner`` — places categories on shards by **quota bytes**
  (``economics.ResidencyModel.quota_bytes``: a category's entry ceiling
  × bytes/entry under the resident dtype), greedy longest-processing-time
  bin-packing instead of the crc32-mod hash that piles head categories
  onto one shard (``CRC32Planner`` keeps that baseline as the no-planner
  fallback and the benchmark contrast — on the Table-1 quotas, crc32 %2
  lands 83 % of quota bytes on one shard).
* ``ShardedSemanticCache`` — the existing ``SemanticCache`` read/write
  API over N shards. ``lookup_batch`` partitions the query batch per
  shard by category, fans out to each shard's device-resident index
  (each shard reuses the bucketed batch shapes and the fused
  ``frontier_hop``/``cache_topk`` data plane unchanged), and merges the
  classified {hit, expired, miss} results — plus the pre-threshold
  re-rank candidates the int8 tier needs — back into request order.
  ``insert_batch``/``sweep_expired`` route writes through each shard's
  dirty-log delta sync; ``sync_stats``/``last_lookup_stats`` aggregate
  across shards with a per-shard breakdown. Because search is
  category-masked and quotas are per-category fractions of the GLOBAL
  capacity (each shard gets ``quota_capacity = total``), a sharded cache
  is behaviorally identical to a single cache on the same workload —
  property-tested bit-identical for shard counts {1, 2, 4}
  (tests/test_shard.py).
* ``CategoryMigration`` — live category movement (quota reassignment or
  an ``AdaptiveController``-driven ``rebalance``): COPY-THEN-CUTOVER.
  The drain exports the source rows (``index.export_rows``: fp32 rows +
  inserted timestamps + the int8/scale mirror) batch by batch into the
  target via ``adopt_entries`` — timestamps, hit counts and doc payloads
  preserved; requantization is deterministic, so the target's int8+scale
  rows come out bit-identical — while the OLD shard keeps serving every
  read and write until cutover. Cutover runs catch-up passes (entries
  written mid-drain), reconciles copies whose source entry was evicted
  during the drain, flips the planner's routing, then purges the source.
  At no point does a read see a missing or doubly-served entry. The
  cutover is journaled (fence → catchup → reconcile → flip → purge →
  unfence) with crash points between steps: an injected crash at ANY
  step index leaves exactly one authoritative owner — source until the
  journaled flip, target after — and ``recover()`` finishes or rolls
  back from whatever prefix the journal records.

Degraded mode (``core/faults.FaultInjector`` wired via ``faults=``):
a lookup routed to a shard inside a scheduled outage window resolves as
a counted ``degraded_miss`` — never an exception, never a hit-rate
denominator entry — and a write to a down shard lands in a bounded
per-shard write-behind queue that replays item by item through the
front door once the shard recovers (``crash_point("wb_replay")`` sites
bracket each item: an acknowledged write is applied exactly once no
matter where a crash lands — the ``_wb_applied`` id set deduplicates a
crash between apply and dequeue). Enqueued writes are ACKNOWLEDGED (the
caller got a normal INVALID-slot return); the zero-acknowledged-write-
loss property tests in tests/test_faults.py pin that replay preserves
them all. An absent/inert injector leaves every hook a no-op, so the
no-fault path is bit-identical to the pre-fault-injection code.

Replication (``replication=`` — an explicit ``{category: k}`` map or a
quota-mass threshold float: quota ≥ θ ⇒ 2 replicas): head categories
are resident on a replica SET instead of exactly one shard. The planner
places the primary by LPT as always, then adds k−1 replicas on the
lightest shards not already holding the category (replica byte weight
counts toward the bins, so total placed bytes stay balanced). The front
door fans every write to all live replicas in the same batched round
(each replica's dirty-log delta sync stays O(batch)); lookups route
deterministically round-robin across the replica set, failing over to
the next live replica inside an outage window (counted
``failover_reads``) — a down shard with a live replica serves hits, not
degraded_misses. Replicas answer bit-identically: identical per-
category insert streams + name-seeded admission give identical entry
sets, and serving-replica hit counts are echoed to the siblings through
a doc-correspondence registry so eviction scores stay in step; any
observed drift (a hit whose sibling copy is gone while the sibling is
live) increments ``replica_divergence`` and prunes the mapping.
Replicated categories are pinned — they never migrate; their outage
story IS the replica set.

Self-healing (``rebalance_after_s=``): an outage that persists past the
threshold triggers ``OutageRebalance`` for each UNREPLICATED category
homed on the dead shard — the resident set is rebuilt from the shard's
(separately durable) document store into a live target, routing flips,
and the dead shard's write-behind queue drains into the new owner,
journaled with ``crash_point("outage_rebalance")`` sites between steps
(rebuild → flip → wb_drain → done; pre-flip crashes leave the dead
shard nominally authoritative and recovery re-runs or aborts, post-flip
crashes finish forward with the same exactly-once wb dedup). When the
original shard recovers, its stale copies are demoted (purged) and the
category re-absorbs to its planned home through a normal live
``CategoryMigration``.

Clock semantics: shards are constructed with ``search_ms = insert_ms =
0`` and the sharded front door advances the SHARED clock exactly once
per fan-out round — a lookup across 3 shards costs one ``search_ms``
(the fan-out is parallel on real hardware), and the ``now`` every shard
classifies TTLs against is the same instant a single cache would use.
All shards also share the cache-relative time origin ``_t0``, so
``inserted`` timestamps transfer across shards unrebased.
"""

from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.cache import CacheResult, SemanticCache
from repro.core.clock import Clock, SimClock
from repro.core.economics import ResidencyModel
from repro.core.faults import FaultInjector
from repro.core.hnsw import INVALID
from repro.core.metrics import CategoryStats, overall_row
from repro.core.policy import PolicyEngine
from repro.obs.trace import NULL_SPAN


def crc32_shard(category: str, n_shards: int) -> int:
    """The quota-blind hash placement: crc32(name) mod N. Kept as the
    no-planner fallback (serving/router.py) and the baseline the
    placement benchmark beats."""
    return zlib.crc32(category.encode()) % max(1, n_shards)


class CRC32Planner:
    """Hash placement behind the planner interface — the degenerate
    baseline: ignores quota bytes entirely, so head categories collide
    (benchmarks/bench_shard.py measures the resulting imbalance).
    ``assign`` still honors migrations via an override table."""

    def __init__(self, n_shards: int):
        self.n_shards = max(1, n_shards)
        self._overrides: dict[str, int] = {}

    def shard_of(self, category: str) -> int:
        ov = self._overrides.get(category)
        return crc32_shard(category, self.n_shards) if ov is None else ov

    def replica_set(self, category: str) -> list[int]:
        """Hash placement is single-home: every category has exactly one
        replica (the planner interface the front door routes by)."""
        return [self.shard_of(category)]

    def assign(self, category: str, shard: int, nbytes: int = 0) -> None:
        self._overrides[category] = int(shard)


class ShardPlanner:
    """Assigns categories to shards by quota-byte budgets.

    A category's placement weight is the resident bytes its quota
    ceiling pins: ``int(quota · capacity) × bytes/entry`` under the
    active ``ResidencyModel`` (so int8 residency shrinks every weight
    ~4x but keeps the RELATIVE packing identical). ``plan`` runs greedy
    LPT bin-packing — categories sorted by weight descending, each
    dropped on the currently lightest shard — which is deterministic
    (ties break by name, then by shard id) and within 4/3 of optimal.
    Categories first seen after planning (``shard_of`` on an unknown
    name) are placed on the lightest shard at their policy's quota
    weight.

    ``replication`` adds a replication pass after primary placement:
    an explicit ``{category: k}`` map, or a float quota-mass threshold
    (categories with quota ≥ θ get 2 replicas). Each extra replica goes
    on the lightest shard not already holding the category and its byte
    weight counts toward that bin, so LPT keeps balancing TOTAL placed
    bytes, copies included. ``assignments`` still names the PRIMARY
    (what ``shard_of`` returns); the full set is ``replica_set``.
    """

    def __init__(self, n_shards: int, capacity: int,
                 residency: ResidencyModel | None = None,
                 policies: PolicyEngine | None = None,
                 replication: dict[str, int] | float | None = None):
        self.n_shards = max(1, n_shards)
        self.capacity = capacity
        self.residency = residency or ResidencyModel()
        self.policies = policies
        self.replication = replication
        self.assignments: dict[str, int] = {}
        self._bytes: dict[str, int] = {}
        self.shard_bytes: list[int] = [0] * self.n_shards
        # category -> [primary, replica, ...]; only k >= 2 entries live
        # here — single-home categories resolve through shard_of.
        self.replica_sets: dict[str, list[int]] = {}

    @classmethod
    def from_policies(cls, policies: PolicyEngine, n_shards: int,
                      capacity: int, dim: int = 384,
                      emb_dtype: str = "float32",
                      graph_degree: int = 32,
                      replication: dict[str, int] | float | None = None,
                      ) -> "ShardPlanner":
        """Plan every registered category from its policy quota; the
        residency model prices bytes/entry for the resident dtype."""
        planner = cls(n_shards, capacity,
                      residency=ResidencyModel(dim=dim, emb_dtype=emb_dtype,
                                               graph_degree=graph_degree),
                      policies=policies, replication=replication)
        cachable = {n: policies.get(n).quota for n in policies.categories()
                    if policies.get(n).allow_caching
                    and policies.get(n).quota > 0}
        planner.plan(cachable)
        # Compliance-blocked / zero-quota categories still need a stable
        # home for their (rejected) traffic: zero placement weight.
        for name in sorted(policies.categories()):
            if name not in planner.assignments:
                planner._place(name, 0)
        return planner

    # -- placement -------------------------------------------------------------
    def quota_bytes(self, quota_fraction: float) -> int:
        return self.residency.quota_bytes(quota_fraction, self.capacity)

    def replica_count(self, name: str, quota: float) -> int:
        """Replicas the spec asks for, capped at the shard count."""
        spec = self.replication
        if spec is None:
            return 1
        if isinstance(spec, dict):
            k = int(spec.get(name, 1))
        else:
            k = 2 if quota >= float(spec) else 1
        return max(1, min(k, self.n_shards))

    def plan(self, quotas: dict[str, float]) -> dict[str, int]:
        """(Re)pack ``quotas`` from scratch; returns the assignment."""
        self.assignments.clear()
        self._bytes.clear()
        self.shard_bytes = [0] * self.n_shards
        self.replica_sets.clear()
        order = sorted(quotas, key=lambda c: (-self.quota_bytes(quotas[c]), c))
        for name in order:
            self._place(name, self.quota_bytes(quotas[name]))
        # Replication pass: heaviest categories first (same order), each
        # extra copy on the lightest shard that doesn't hold the
        # category yet — copies add real byte weight to the bins.
        for name in order:
            k = self.replica_count(name, quotas[name])
            if k <= 1:
                continue
            reps = [self.assignments[name]]
            w = self.quota_bytes(quotas[name])
            while len(reps) < k:
                cands = [i for i in range(self.n_shards) if i not in reps]
                if not cands:
                    break
                s = min(cands, key=lambda i: (self.shard_bytes[i], i))
                reps.append(s)
                self.shard_bytes[s] += w
            if len(reps) > 1:
                self.replica_sets[name] = reps
        return dict(self.assignments)

    def _place(self, category: str, nbytes: int) -> int:
        shard = min(range(self.n_shards),
                    key=lambda i: (self.shard_bytes[i], i))
        self.assignments[category] = shard
        self._bytes[category] = nbytes
        self.shard_bytes[shard] += nbytes
        return shard

    def shard_of(self, category: str) -> int:
        if category not in self.assignments:
            quota = (self.policies.get(category).quota
                     if self.policies is not None else 0.0)
            return self._place(category, self.quota_bytes(quota))
        return self.assignments[category]

    def replica_set(self, category: str) -> list[int]:
        """Every shard holding the category, primary first. Single-home
        categories (the common case) are just ``[shard_of]``."""
        reps = self.replica_sets.get(category)
        return list(reps) if reps else [self.shard_of(category)]

    def assign(self, category: str, shard: int,
               nbytes: int | None = None) -> None:
        """Pin a category to a shard (migration cutover / manual
        placement), moving its byte weight between bins."""
        old = self.assignments.get(category)
        weight = self._bytes.get(category, 0) if nbytes is None else nbytes
        if old is not None:
            self.shard_bytes[old] -= self._bytes.get(category, 0)
        self.assignments[category] = int(shard)
        self._bytes[category] = weight
        self.shard_bytes[shard] += weight

    # -- reporting -------------------------------------------------------------
    def imbalance(self) -> float:
        """max/mean planned shard bytes — 1.0 is a perfect spread (the
        placement gate bench_shard tracks against the crc32 baseline)."""
        mean = sum(self.shard_bytes) / self.n_shards
        return max(self.shard_bytes) / mean if mean > 0 else 1.0

    def report(self) -> dict:
        return {"n_shards": self.n_shards,
                "emb_dtype": self.residency.emb_dtype,
                "shard_bytes": list(self.shard_bytes),
                "imbalance": round(self.imbalance(), 4),
                "assignments": dict(self.assignments),
                "replica_sets": {c: list(r)
                                 for c, r in sorted(self.replica_sets.items())}}


class ShardedMetrics:
    """``MetricsRegistry`` view over the shards. ``cat(name)`` resolves
    to the category's serving shard (so simulator/engine counter writes
    land where the category lives); the merged views sum counters across
    shards — a migrated category's pre-move history stays on its old
    shard's registry and the merge reunifies it."""

    def __init__(self, parent: "ShardedSemanticCache"):
        self._parent = parent

    def cat(self, name: str) -> CategoryStats:
        shard = self._parent.shards[self._parent.shard_of(name)]
        return shard.metrics.cat(name)

    @property
    def per_category(self) -> dict[str, CategoryStats]:
        merged: dict[str, CategoryStats] = {}
        for shard in self._parent.shards:
            for name, st in shard.metrics.per_category.items():
                acc = merged.setdefault(name, CategoryStats())
                for f in CategoryStats.__dataclass_fields__:
                    setattr(acc, f, getattr(acc, f) + getattr(st, f))
        return merged

    def overall_hit_rate(self) -> float:
        per = self.per_category.values()
        lookups = sum(s.lookups for s in per)
        hits = sum(s.hits for s in per)
        return hits / lookups if lookups else 0.0

    def snapshot(self) -> dict:
        """Per-category rows plus the ``"_overall"`` aggregate row
        (same contract as ``MetricsRegistry.snapshot``)."""
        per = self.per_category
        snap = {k: v.to_dict() for k, v in sorted(per.items())}
        snap["_overall"] = overall_row(per)
        return snap

    def slo_report(self) -> dict:
        """Per-category availability SLO view: the degraded fraction of
        lookups plus the OBSERVED degraded window (``degraded_seconds``
        accrued by the front door between ops — no re-deriving overlap
        from the fault schedule) and the replica count that bounds it."""
        out = {}
        for name, st in sorted(self.per_category.items()):
            out[name] = {
                "availability": round(st.availability, 4),
                "lookups": st.lookups,
                "degraded_misses": st.degraded_misses,
                "degraded_seconds": round(st.degraded_seconds, 3),
                "replicas": len(self._parent.replica_set(name)),
            }
        return out


@dataclass
class _WbItem:
    """One acknowledged write parked in a shard's write-behind queue.

    ``wb_id`` is the exactly-once replay token: replay applies an item,
    records the id in the front door's ``_wb_applied`` set, THEN
    dequeues — a crash between apply and dequeue leaves the item queued
    but marked, so the retry skips the apply and never double-inserts.
    ``mode`` routes the replay: "front" re-enters through the front door
    (the category may have migrated while queued), "replica" catches a
    recovered replica up DIRECTLY (its live siblings already applied the
    write during the outage — fanning it out again would double-apply),
    back-dating ``slot_inserted`` to the acknowledgment time ``t_enq``
    so ages — and therefore TTL expiry and eviction scores — converge
    bit-identically with the siblings'.
    """

    wb_id: int
    mode: str                   # "front" | "replica"
    uid: int                    # replica-registry uid ("replica" mode)
    emb: np.ndarray
    category: str
    request: str
    response: str
    meta: dict | None
    t_enq: float                # absolute clock time at acknowledgment


class CategoryMigration:
    """Live category movement between shards: copy-then-cutover.

    Protocol (single-writer; steps interleave freely with serving):

    1. **Drain** (``step``): copy up to ``batch_size`` not-yet-copied
       live entries source → target via ``export_rows`` →
       ``adopt_entries`` (fp32 rows, preserved ``inserted`` timestamps
       and hit counts, doc payloads re-minted under the target's doc-id
       sequence; deterministic requantization reproduces the int8+scale
       rows bit-identically). The source keeps serving ALL reads and
       writes for the category — copies on the target are invisible to
       its traffic because search is category-masked and routing still
       points at the source.
    2. **Cutover** (``cutover``): a write fence goes up for the
       category; catch-up passes copy entries inserted during the drain
       (and re-copy any whose target copy was lost); reconciliation
       drops target copies whose source entry was evicted mid-drain and
       refreshes drained-while-serving hit counts; the planner's routing
       flips (the point of no return); the source purges its copies; the
       fence drops and queued writes replay into the new owner. Reads
       are correct at every intermediate point: before the flip the
       source holds (and serves) the authoritative set, after it the
       target does.

    Crash safety: each completed cutover step appends to ``journal``,
    and ``faults.crash_point("migration")`` sites sit between steps (and
    inside the drain's adopt→registry window, the one place a copy can
    exist that the registry doesn't know about). ``recover()`` reads the
    journal: pre-flip the source never lost authority, so the migration
    aborts (or, with ``mode="resume"``, sweeps orphan target copies and
    re-runs — every pre-flip step is idempotent); post-flip the target
    owns the category and recovery finishes the purge + fence replay.
    Either way exactly one shard serves the category afterwards, and
    every fenced (acknowledged) write survives into the final owner.
    """

    def __init__(self, parent: "ShardedSemanticCache", category: str,
                 src_id: int, dst_id: int, batch_size: int = 64):
        self.parent = parent
        self.category = category
        self.src_id = src_id
        self.dst_id = dst_id
        self.batch_size = batch_size
        self.moved = 0
        self.done = False
        # src doc_id -> (target slot, target doc_id): the copy registry
        # reconciliation audits at cutover.
        self._copied: dict[int, tuple[int, int]] = {}
        # Completed protocol steps, in order. In-process it is just a
        # list; it stands in for the persisted step journal a multi-
        # process deployment would fsync — recover() trusts it alone.
        self.journal: list[str] = []
        # Write fence: while up, front-door writes for the category
        # queue here (bounded by parent.write_behind_capacity) instead
        # of racing the flip; _drain_fence replays them to the owner.
        self.fenced = False
        self.fence_queue: deque = deque()

    # -- helpers ---------------------------------------------------------------
    def _ends(self) -> tuple[SemanticCache, SemanticCache]:
        return (self.parent.shards[self.src_id],
                self.parent.shards[self.dst_id])

    def _pending(self) -> np.ndarray:
        """Source slots still to copy: live, this category, not in the
        copy registry (covers both fresh writes and dropped copies)."""
        src, _ = self._ends()
        slots = src.category_slots(self.category)
        todo = [s for s in slots
                if int(src.slot_doc[s]) not in self._copied]
        return np.asarray(todo, np.int64)

    def _owns(self, slot: int, doc_id: int) -> bool:
        _, dst = self._ends()
        return bool(dst.slot_valid[slot]) and int(dst.slot_doc[slot]) == doc_id

    def _journal(self, entry: str) -> None:
        if entry not in self.journal:
            self.journal.append(entry)
            self.parent._event("migration_step", category=self.category,
                               step=entry, src=self.src_id, dst=self.dst_id)

    def _cp(self) -> None:
        faults = getattr(self.parent, "faults", None)
        if faults is not None:
            faults.crash_point("migration")

    @property
    def flipped(self) -> bool:
        """Past the point of no return? The journaled flip is the single
        bit authority pivots on."""
        return "flip" in self.journal

    @property
    def owner_id(self) -> int:
        """The shard currently authoritative for the category — what
        ``ShardedSemanticCache.shard_of`` routes by at every protocol
        point, crashed or not."""
        return self.dst_id if self.flipped else self.src_id

    # -- protocol --------------------------------------------------------------
    def step(self, max_entries: int | None = None) -> int:
        """Copy one batch; returns entries moved (0 = drained)."""
        if self.done:
            return 0
        self._cp()      # a drain-batch boundary
        # Span "migration_copy": one drain batch — the source store gets
        # plus the target's adopt (store put_many) charge inside it.
        with self.parent._span("migration_copy", category=self.category,
                               src=self.src_id, dst=self.dst_id):
            src, dst = self._ends()
            slots = self._pending()[:max_entries or self.batch_size]
            if slots.size == 0:
                return 0
            docs, keep = [], []
            for s in slots:
                doc = src.store.get(int(src.slot_doc[s]))
                if doc is None:  # store lost the doc: drop at the source too
                    src._evict_slot(int(s), reason="missing_doc")
                    continue
                docs.append(doc)
                keep.append(int(s))
            if not keep:
                return 0
            slots = np.asarray(keep, np.int64)
            rows = src.index.export_rows(slots)
            try:
                adopted = dst.adopt_entries(rows["emb"],
                                            [self.category] * len(keep),
                                            rows["inserted"],
                                            src.slot_hits[slots], docs)
            except RuntimeError:
                # Target out of physical slots (adopt_entries checks before
                # mutating anything): undo the drain so the source stays
                # authoritative and the migration is retryable after space
                # frees up or with a bigger shard_capacity.
                self.abort()
                raise
            # The adopt→registry window: a crash HERE leaves copies on the
            # target that _copied doesn't know about (orphans). Pre-flip
            # they are invisible to traffic (routing still points at the
            # source); recover() sweeps or purges them.
            self._cp()
            for s, (dst_slot, dst_doc) in zip(slots, adopted):
                self._copied[int(src.slot_doc[s])] = (dst_slot, dst_doc)
            self.moved += len(keep)
            return len(keep)

    def remaining(self) -> int:
        return int(self._pending().size)

    def abort(self) -> None:
        """Cancel before the flip: drop every target copy — registry-
        known AND orphans a crash in the adopt→registry window left
        behind (pre-flip the target never serves the category, so its
        category slots are exactly the copies) — keep the source (which
        served throughout) authoritative, unregister the migration so it
        can be retried, and replay any fenced writes to the source."""
        if self.done:
            return
        if self.flipped:
            raise RuntimeError(
                "cannot abort after the routing flip — the target owns "
                f"{self.category!r}; recover()/resume instead")
        _, dst = self._ends()
        for s in dst.category_slots(self.category):
            dst._evict_slot(int(s), reason="migration_abort")
        self._copied.clear()
        self.parent._migrations.pop(self.category, None)
        self.done = True
        self._journal("abort")
        self._drain_fence()

    def cutover(self) -> None:
        """Final catch-up + reconcile behind a write fence, then flip
        routing, purge the source, and replay fenced writes into the new
        owner. Journaled step by step with a crash point between steps;
        every pre-flip step is idempotent, so ``recover(mode="resume")``
        can re-run from the top after a crash at any index."""
        if self.done:
            return
        src, dst = self._ends()
        self._cp()
        # Fence first: from here to the flip, front-door writes for the
        # category queue on the migration instead of landing on either
        # end — a late write can no longer race the routing flip, and
        # the catch-up fixpoint below sees a quiescent source.
        self.fenced = True
        self._journal("fence")
        self._cp()
        # Catch-up until a fixpoint: no pending entries AND every live
        # source entry's copy still exists on the target (a copy lost to
        # target-side eviction while the source entry lives re-copies).
        while True:
            if self.step(self.batch_size):
                continue
            live = {int(src.slot_doc[s])
                    for s in src.category_slots(self.category)}
            lost = [d for d in self._copied
                    if d in live and not self._owns(*self._copied[d])]
            if not lost:
                break
            for d in lost:
                del self._copied[d]
        self._journal("catchup")
        self._cp()
        # Reconcile: source evictions during the drain win (no
        # resurrection), and hits accrued while the source served
        # transfer so eviction scores stay continuous.
        live_slots = {int(src.slot_doc[s]): int(s)
                      for s in src.category_slots(self.category)}
        for src_doc, (dst_slot, dst_doc) in self._copied.items():
            if not self._owns(dst_slot, dst_doc):
                continue
            if src_doc not in live_slots:
                dst._evict_slot(dst_slot, reason="migration_reconcile")
            else:
                dst.slot_hits[dst_slot] = src.slot_hits[live_slots[src_doc]]
        self._journal("reconcile")
        self._cp()
        # Flip routing — the point of no return. The category's
        # admission sketch moves with it: both ends derive the tracker
        # from the category NAME, so the counts transfer verbatim and
        # repetition history (admit-on-kth-touch progress) survives the
        # migration instead of resetting mid-stream.
        self.parent.planner.assign(self.category, self.dst_id)
        dst.admission.adopt_state(self.category,
                                  src.admission.export_state(self.category))
        self._journal("flip")
        self._cp()
        self._finish_post_flip()

    def _finish_post_flip(self) -> None:
        """Purge the source's copies and drop the fence — the post-flip
        tail, shared by the success path and post-flip recovery. Both
        steps are idempotent."""
        src, _ = self._ends()
        for s in src.category_slots(self.category):
            src._evict_slot(int(s), reason="migrated")
        self._journal("purge")
        self._cp()
        self.parent._migrations.pop(self.category, None)
        self.done = True
        self._journal("unfence")
        self._drain_fence()

    def _drain_fence(self) -> None:
        """Replay fenced (acknowledged) writes through the front door.
        Runs after the migration is unregistered, so routing points at
        the final owner and the replay takes the normal write path —
        admission, quota, and (if that owner is down) the write-behind
        queue all apply."""
        self.fenced = False
        if not self.fence_queue:
            return
        items = list(self.fence_queue)
        self.fence_queue.clear()
        embs = np.stack([it[0] for it in items])
        self.parent.insert_batch(embs, [self.category] * len(items),
                                 [it[1] for it in items],
                                 [it[2] for it in items],
                                 [it[3] for it in items])
        self.parent.fault_stats["fence_replayed"] += len(items)

    def recover(self, mode: str = "auto") -> str:
        """Resume-or-abort after a crash left the protocol mid-flight.

        Post-flip the journal names the target as owner, so the only
        legal move — whatever ``mode`` says — is to finish (idempotent
        purge + fence replay). Pre-flip the source never lost authority:
        ``"abort"`` (the ``"auto"`` default — cheapest path back to a
        steady state) rolls the copies back; ``"resume"`` sweeps orphan
        target copies from the adopt→registry window, then re-runs the
        drain + cutover from the top. Returns the action taken
        (``"resumed"`` | ``"aborted"`` | ``"noop"``)."""
        if self.done:
            return "noop"
        if self.flipped:
            self._finish_post_flip()
            return "resumed"
        if mode == "resume":
            _, dst = self._ends()
            known = {doc for (_, doc) in self._copied.values()}
            for s in dst.category_slots(self.category):
                if int(dst.slot_doc[s]) not in known:
                    dst._evict_slot(int(s), reason="migration_recover")
            self.run()
            return "resumed"
        self.abort()
        return "aborted"

    def run(self) -> int:
        """Drain to completion and cut over; returns entries moved."""
        while self.step():
            pass
        self.cutover()
        return self.moved


class OutageRebalance:
    """Evacuate an UNREPLICATED category off a DEAD shard.

    ``CategoryMigration`` cannot run here: its drain reads the source's
    index, and the source is unreachable. Instead the resident set is
    REBUILT on the target from the two places the data still exists —
    the source shard's document store (separately durable; shards
    persist fp32 embeddings per doc whenever a fault stack is wired) and
    the dead shard's write-behind queue (acknowledged writes the store
    never saw). Protocol, journaled with
    ``faults.crash_point("outage_rebalance")`` between steps:

    1. **rebuild** — sweep any partial target copies from a prior
       crashed attempt, then ``store.scan(category)`` → ``adopt_entries``
       in batches: original ``inserted`` timestamps reconstructed from
       each doc's absolute ``created_at``, hit counts start at zero (the
       source's in-memory hit counters died with it — an explicit,
       deterministic choice).
    2. **flip** — routing pivots to the target (point of no return).
    3. **wb_drain** — the dead shard's queued writes for the category
       replay into the NEW owner through the front door, with the same
       ``_wb_applied`` exactly-once dedup as normal wb replay. Draining
       strictly AFTER the journaled flip is what makes a crash safe: a
       pre-flip crash leaves every acknowledged write either in the
       still-intact queue or in the store, and recovery's rebuild sweep
       never touches the queue.
    4. **done** — unregister; the moved category is recorded in the
       parent's ``_moved_by_outage`` ledger so the source's eventual
       recovery can demote its stale copies and re-absorb the category.

    ``recover()``: post-flip crashes finish forward (idempotent drain +
    done); pre-flip crashes either re-run (``resume`` — the rebuild
    sweep makes step 1 idempotent) or abort back to the dead shard
    (``abort``: nothing was authoritative on the target yet).
    Duck-types the ``CategoryMigration`` surface the front door routes
    by (``owner_id``/``flipped``/``done``/``fenced``/``fence_queue``),
    so routing through ``_migrations`` works unchanged mid-protocol.
    """

    def __init__(self, parent: "ShardedSemanticCache", category: str,
                 src_id: int, dst_id: int, batch_size: int = 64):
        self.parent = parent
        self.category = category
        self.src_id = src_id
        self.dst_id = dst_id
        self.batch_size = batch_size
        self.moved = 0
        self.done = False
        self.journal: list[str] = []
        # Never fences: the source is down, so front-door writes already
        # divert to the write-behind queue; post-flip they route to the
        # target directly. Present for _migrations duck-typing only.
        self.fenced = False
        self.fence_queue: deque = deque()

    def _journal(self, entry: str) -> None:
        if entry not in self.journal:
            self.journal.append(entry)
            self.parent._event("rebalance_step", category=self.category,
                               step=entry, src=self.src_id, dst=self.dst_id)

    def _cp(self) -> None:
        faults = getattr(self.parent, "faults", None)
        if faults is not None:
            faults.crash_point("outage_rebalance")

    @property
    def flipped(self) -> bool:
        return "flip" in self.journal

    @property
    def owner_id(self) -> int:
        return self.dst_id if self.flipped else self.src_id

    # -- protocol --------------------------------------------------------------
    def _rebuild(self) -> None:
        """Sweep partial copies from a crashed prior attempt, then adopt
        the category's store-resident docs onto the target in batches.
        Docs without a persisted embedding cannot be rebuilt (fp32 runs
        before the fault stack wires ``durable_embeddings``) and are
        skipped — the entry is lost to the outage, not corrupted."""
        src, dst = (self.parent.shards[self.src_id],
                    self.parent.shards[self.dst_id])
        # Span "rebalance_rebuild": the store scan + adopt batches — the
        # only store charges the rebuild can incur land inside it.
        with self.parent._span("rebalance_rebuild", category=self.category,
                               src=self.src_id, dst=self.dst_id):
            for s in dst.category_slots(self.category):
                dst._evict_slot(int(s), reason="outage_rebuild_sweep")
            self._cp()
            docs = [d for d in src.store.scan(self.category)
                    if d.embedding is not None]
            t0 = self.parent._t0
            for lo in range(0, len(docs), self.batch_size):
                chunk = docs[lo:lo + self.batch_size]
                embs = np.stack([d.embedding_array() for d in chunk])
                inserted = np.asarray([d.created_at - t0 for d in chunk],
                                      np.float64)
                hits = np.zeros(len(chunk), np.int64)
                dst.adopt_entries(embs, [self.category] * len(chunk),
                                  inserted, hits, chunk)
                self.moved += len(chunk)
                self._cp()
        self._journal("rebuild")

    def _wb_drain(self) -> None:
        """Replay the dead shard's queued writes for this category into
        the new owner, exactly-once (``_wb_applied``), with a crash
        point bracketing each item like normal wb replay."""
        p = self.parent
        q = p._write_behind[self.src_id]
        mine = [it for it in q if it.category == self.category]
        for it in mine:
            self._cp()
            if it.wb_id not in p._wb_applied:
                p._wb_applied.add(it.wb_id)
                p._wb_apply(it)
            self._cp()
            q.remove(it)
            p.fault_stats["wb_replayed"] += 1
        self._journal("wb_drain")

    def _finish(self) -> None:
        self.parent._migrations.pop(self.category, None)
        self.done = True
        self._journal("done")
        self.parent.fault_stats["outage_rebalances"] += 1

    def run(self) -> int:
        if self.done:
            return 0
        self._cp()
        self._rebuild()
        self._cp()
        # Flip routing to the rebuilt copy — point of no return. The
        # admission sketch needs no transfer: trackers are seeded from
        # the category NAME, so the target derives identical state.
        self.parent.planner.assign(self.category, self.dst_id)
        self._journal("flip")
        self._cp()
        self._wb_drain()
        self._cp()
        self._finish()
        # The ledger entry lets the source's recovery demote its stale
        # copies and re-absorb the category to its planned home.
        self.parent._moved_by_outage[self.category] = (self.src_id,
                                                       self.dst_id)
        return self.moved

    def abort(self) -> None:
        """Pre-flip cancel: drop the partial target copies; the (dead)
        source keeps nominal authority and its store keeps the data."""
        if self.done:
            return
        if self.flipped:
            raise RuntimeError(
                "cannot abort after the routing flip — the target owns "
                f"{self.category!r}; recover()/resume instead")
        dst = self.parent.shards[self.dst_id]
        for s in dst.category_slots(self.category):
            dst._evict_slot(int(s), reason="outage_rebalance_abort")
        self.parent._migrations.pop(self.category, None)
        self.done = True
        self._journal("abort")

    def recover(self, mode: str = "auto") -> str:
        """Post-flip: finish forward (idempotent wb drain + done).
        Pre-flip: ``"resume"`` (the ``"auto"`` default — the store still
        holds the data and the rebuild sweep is idempotent, so finishing
        is both safe and cheap) re-runs; ``"abort"`` rolls back to the
        dead shard."""
        if self.done:
            return "noop"
        if self.flipped:
            self._wb_drain()
            self._finish()
            self.parent._moved_by_outage[self.category] = (self.src_id,
                                                           self.dst_id)
            return "resumed"
        if mode == "abort":
            self.abort()
            return "aborted"
        self.run()
        return "resumed"


class ShardedSemanticCache:
    """N category-sharded ``SemanticCache``s behind the single-cache API.

    ``capacity`` is the GLOBAL entry capacity: quota ceilings resolve
    against it on every shard (``quota_capacity``), so a category's
    entry budget is identical to the unsharded cache's. Each shard
    preallocates ``shard_capacity`` physical slots (default: the global
    capacity, the always-safe choice; size it from
    ``planner.shard_bytes`` when per-device HBM is the constraint —
    with quotas summing ≤ 1 a shard never holds more than its
    categories' ceilings). Returned slot ids are globally encoded as
    ``shard · shard_capacity + local`` — decode with ``doc_id_of`` /
    ``shard_of_slot`` rather than indexing shard tables directly.
    """

    def __init__(self, policies: PolicyEngine, dim: int = 384,
                 capacity: int = 65536, n_shards: int = 2,
                 clock: Clock | None = None, index_kind: str = "hnsw",
                 use_device: bool = False, search_ms: float = 2.0,
                 insert_ms: float = 1.0, l1_capacity: int = 0,
                 seed: int = 0, emb_dtype: str = "float32",
                 planner=None, shard_capacity: int | None = None,
                 store_factory=None, eviction: str = "static",
                 faults: FaultInjector | None = None,
                 write_behind_capacity: int = 1024,
                 replication: dict[str, int] | float | None = None,
                 rebalance_after_s: float | None = None,
                 obs=None):
        self.policies = policies
        # Observability (repro.obs.TraceRecorder or None): the front
        # door records with shard=-1, each shard with its own id; all
        # shards share this recorder so shard spans nest inside the
        # front door's root span.
        self.obs = obs
        self._obs_shard = -1
        # Fault wiring: an absent (or inert — empty schedule) injector
        # makes every degraded-mode hook a no-op, keeping this cache
        # bit-identical to the pre-fault-injection behavior.
        self.faults = faults
        self.write_behind_capacity = write_behind_capacity
        self.replication = replication
        self.rebalance_after_s = rebalance_after_s
        self.dim = dim
        self.capacity = capacity
        self.n_shards = max(1, n_shards)
        self.index_kind = index_kind
        self.use_device = use_device
        self.emb_dtype = emb_dtype
        self.clock = clock or SimClock()
        self.search_ms = search_ms
        self.insert_ms = insert_ms
        self.eviction = eviction
        self.planner = planner if planner is not None else \
            ShardPlanner.from_policies(policies, self.n_shards, capacity,
                                       dim=dim, emb_dtype=emb_dtype,
                                       replication=replication)
        self.shard_capacity = shard_capacity or capacity
        self.shards = [
            SemanticCache(policies, dim=dim, capacity=self.shard_capacity,
                          store=(store_factory(i) if store_factory else None),
                          clock=self.clock, index_kind=index_kind,
                          use_device=use_device,
                          # the front door owns the clock charges — one
                          # advance per fan-out round, not one per shard
                          search_ms=0.0, insert_ms=0.0,
                          l1_capacity=l1_capacity, seed=seed + i,
                          emb_dtype=emb_dtype, quota_capacity=capacity,
                          doc_id_start=i, doc_id_step=self.n_shards,
                          # Admission state is seeded per category NAME
                          # (not this seed+i), so every shard reaches the
                          # single cache's admission decisions.
                          eviction=eviction,
                          # With a fault stack wired, persist fp32
                          # embeddings per doc so OutageRebalance can
                          # rebuild a dead shard's resident set from the
                          # store alone.
                          durable_embeddings=(faults is not None),
                          obs=obs, obs_shard=i)
            for i in range(self.n_shards)]
        # One shared cache-relative time origin: inserted timestamps are
        # directly transferable between shards (migration preserves them).
        self._t0 = self.shards[0]._t0
        for s in self.shards:
            s._t0 = self._t0
        self.metrics = ShardedMetrics(self)
        self.last_lookup_stats: dict = {}
        self.last_insert_stats: dict = {}
        self._migrations: dict[str, CategoryMigration] = {}
        # Bounded per-shard write-behind queues (writes acknowledged
        # while a shard is down; FIFO-replayed on recovery) plus the
        # degraded-serving counters bench_faults gates on.
        self._write_behind: list[deque] = [deque()
                                           for _ in range(self.n_shards)]
        self._replaying = False
        self.fault_stats = {"degraded_misses": 0, "wb_enqueued": 0,
                            "wb_replayed": 0, "wb_dropped": 0,
                            "fenced_writes": 0, "fence_replayed": 0,
                            "fence_dropped": 0, "failover_reads": 0,
                            "replica_divergence": 0, "outage_rebalances": 0,
                            "reabsorbed_categories": 0}
        # -- replication state ------------------------------------------
        # Deterministic round-robin read cursor per replicated category.
        self._rr: dict[str, int] = {}
        # Doc-correspondence registry: uid -> {shard: (local_slot,
        # doc_id)} plus the back-map (shard, doc_id) -> uid. Hit echo
        # walks it to mirror slot_hits onto live siblings (keeping
        # eviction scores in step); a hit whose sibling copy vanished
        # while the sibling is LIVE is counted replica_divergence.
        self._rep_registry: dict[int, dict[int, tuple[int, int]]] = {}
        self._rep_uid_of: dict[tuple[int, int], int] = {}
        self._next_uid = 0
        # Exactly-once wb replay: ids already applied (survives a crash
        # between apply and dequeue — in-process state is NOT rolled
        # back on an injected crash, mirroring a durable applied-log).
        self._wb_applied: set[int] = set()
        self._next_wb_id = 0
        # Degraded-window accrual (_degraded_since: category -> clock
        # time its last live replica went dark) and outage bookkeeping
        # (_down_since: shard -> clock time first observed down;
        # _moved_by_outage: category -> (src, dst) moved off a dead
        # shard, pending demote + re-absorb on its recovery).
        self._degraded_since: dict[str, float] = {}
        self._down_since: dict[int, float] = {}
        self._moved_by_outage: dict[str, tuple[int, int]] = {}
        self._in_fault_hooks = False
        # Last lookup's read routing: request index -> serving shard
        # (INVALID when degraded) — the determinism property tests
        # compare this byte-for-byte across runs.
        self.last_read_shards: list[int] = []

    # ------------------------------------------------------------------ tracing
    def _span(self, stage: str, **attrs):
        """Front-door span (shard=-1) when a recorder is attached; the
        shared no-op otherwise (empty-recorder parity)."""
        if self.obs is None:
            return NULL_SPAN
        return self.obs.span(stage, shard=self._obs_shard, **attrs)

    def _event(self, name: str, **fields) -> None:
        if self.obs is not None:
            self.obs.event(name, **fields)

    # ------------------------------------------------------------------ routing
    def shard_of(self, category: str) -> int:
        """The category's SERVING shard: its planned home, or — while a
        migration is in flight — whichever end the migration's journal
        says is authoritative (source until the cutover's flip, target
        after; a crashed cutover parks here until ``recover()``)."""
        mig = self._migrations.get(category)
        return mig.owner_id if mig is not None else \
            self.planner.shard_of(category)

    def replica_set(self, category: str) -> list[int]:
        """Every shard serving the category, primary first. A mid-flight
        migration pins the set to the single authoritative end (moving
        categories are never replicated — replicated ones are pinned)."""
        mig = self._migrations.get(category)
        if mig is not None:
            return [mig.owner_id]
        rs = getattr(self.planner, "replica_set", None)
        return rs(category) if rs is not None else \
            [self.planner.shard_of(category)]

    # -------------------------------------------------------------- degradation
    def _shard_down(self, shard: int) -> bool:
        return self.faults is not None and self.faults.shard_down(shard)

    @property
    def wb_pending(self) -> int:
        """Writes acknowledged during outages, not yet replayed."""
        return sum(len(q) for q in self._write_behind)

    def _fault_hooks(self) -> None:
        """Fault-layer bookkeeping at the top of every public lookup /
        insert: accrue per-category degraded_seconds, run outage
        detection (rebalance triggers + demote/re-absorb on recovery),
        then drain recovered write-behind queues. Everything is a no-op
        without an ACTIVE injector, keeping the no-fault path
        bit-identical to the pre-fault-injection code."""
        if self.faults is not None and self.faults.active \
                and not self._in_fault_hooks:
            self._in_fault_hooks = True
            try:
                self._accrue_degraded()
                self._check_outages()
            finally:
                self._in_fault_hooks = False
        self._maybe_replay()

    def _accrue_degraded(self) -> None:
        """Incrementally charge degraded wall-time to every category
        with NO live replica — the observed window ``slo_report`` and
        the availability curves read, accrued between ops so nothing
        downstream re-derives schedule overlap."""
        now = self.clock.now()
        for name in self.policies.categories():
            down = all(self._shard_down(s) for s in self.replica_set(name))
            since = self._degraded_since.get(name)
            if down:
                if since is None:
                    self._degraded_since[name] = now
                elif now > since:
                    self.metrics.cat(name).degraded_seconds += now - since
                    self._degraded_since[name] = now
                    self._event("degraded_accrue", category=name,
                                seconds=now - since)
            elif since is not None:
                del self._degraded_since[name]
                if now > since:
                    self.metrics.cat(name).degraded_seconds += now - since
                    self._event("degraded_accrue", category=name,
                                seconds=now - since)

    def _check_outages(self) -> None:
        """Outage lifecycle: track when each shard was first observed
        down; once an outage persists past ``rebalance_after_s``,
        evacuate its unreplicated categories (``OutageRebalance``); once
        a previously-evacuated shard recovers, demote its stale copies
        and re-absorb each moved category to its original home through a
        normal live migration."""
        now = self.clock.now()
        for si in range(self.n_shards):
            if self._shard_down(si):
                if si not in self._down_since:
                    self._down_since[si] = now
                    self._event("shard_down_observed", shard=si)
            elif self._down_since.pop(si, None) is not None:
                self._event("shard_up_observed", shard=si)
        if self.rebalance_after_s is not None:
            for si, since in sorted(self._down_since.items()):
                if now - since >= self.rebalance_after_s:
                    self._outage_rebalance(si)
        # Demote + re-absorb: scanned on EVERY call (not just the
        # down→up transition op) so a crash recovered out-of-band still
        # converges the next time any traffic arrives.
        for cat in sorted(self._moved_by_outage):
            src, dst = self._moved_by_outage[cat]
            if cat in self._migrations or self._shard_down(src):
                continue
            stale = self.shards[src]
            for s in stale.category_slots(cat):
                # Demote: the recovered shard's copies predate the
                # outage moves — the evacuated owner is authoritative.
                stale._evict_slot(int(s), reason="outage_stale")
            del self._moved_by_outage[cat]
            if self.shard_of(cat) != src:
                self.migrate_category(cat, src)
            self.fault_stats["reabsorbed_categories"] += 1

    def _outage_rebalance(self, si: int) -> None:
        """Evacuate every unreplicated cacheable category homed on the
        (dead) shard ``si`` to the lightest live shard. Runs to
        completion per category; an injected crash mid-protocol parks
        the ``OutageRebalance`` in ``_migrations`` for ``recover``."""
        stranded = sorted(
            c for c in self.policies.categories()
            if self.policies.get(c).allow_caching
            and self.policies.get(c).quota > 0
            and c not in self._migrations
            and self.replica_set(c) == [si])
        if not stranded:
            return
        live = [s for s in range(self.n_shards) if not self._shard_down(s)]
        if not live:
            return
        weights = getattr(self.planner, "shard_bytes", None)
        for cat in stranded:
            dst = min(live, key=(lambda s: (weights[s], s)) if weights
                      else (lambda s: s))
            reb = OutageRebalance(self, cat, si, dst)
            self._migrations[cat] = reb
            with self._span("outage_rebalance", category=cat,
                            src=si, dst=dst):
                reb.run()

    def _maybe_replay(self) -> None:
        """FIFO-replay each recovered shard's write-behind queue, item
        by item, through the write path (front-door for single-home
        items — categories may have migrated while queued, and a
        still-down target just re-enqueues; direct catch-up for
        replica-mode items whose siblings already applied the write).
        ``crash_point("wb_replay")`` brackets every item and the
        ``_wb_applied`` id set deduplicates a crash between apply and
        dequeue: each acknowledged write is applied exactly once. Runs
        at the top of every public lookup/insert, so recovery drains on
        the first post-outage operation — no background thread."""
        if self.faults is None or self._replaying:
            return
        todo = [si for si in range(self.n_shards)
                if self._write_behind[si] and not self._shard_down(si)]
        if not todo:
            return
        self._replaying = True
        try:
            for si in todo:
                q = self._write_behind[si]
                while q:
                    it = q[0]
                    self.faults.crash_point("wb_replay")
                    if it.wb_id not in self._wb_applied:
                        self._wb_applied.add(it.wb_id)
                        self._wb_apply(it, shard=si)
                    self.faults.crash_point("wb_replay")
                    q.popleft()
                    self.fault_stats["wb_replayed"] += 1
                    self._event("wb_replay", shard=si, wb_id=it.wb_id,
                                category=it.category, mode=it.mode)
        finally:
            self._replaying = False

    def _wb_apply(self, item: _WbItem, shard: int | None = None) -> None:
        """Apply one write-behind item. Front-mode re-enters the front
        door (normal routing / admission / fences; a still-down owner
        re-enqueues under a fresh id, which carries the acknowledgment
        forward). Replica-mode catches the recovered replica up
        DIRECTLY: its live siblings applied the write during the outage,
        so fanning out again would double-apply — and the fresh copy is
        back-dated to the acknowledgment instant and synced to a live
        sibling's hit count so TTL ages and eviction scores converge
        bit-identically across the replica set."""
        if item.mode == "replica" and shard is not None:
            sh = self.shards[shard]
            local = int(sh.insert_batch(
                item.emb[None, :], [item.category], [item.request],
                [item.response], [item.meta])[0])
            if local == INVALID:
                # Name-seeded admission replays the identical decision
                # stream, so a skip here matches the siblings' skip.
                return
            # The row is already dirty from the insert's add_batch, so
            # the back-dated timestamp rides the same delta flush.
            sh.slot_inserted[local] = np.float32(item.t_enq - self._t0)  # mirror-ok
            for sj, (oslot, odoc) in sorted(
                    self._rep_registry.get(item.uid, {}).items()):
                if sj == shard or self._shard_down(sj):
                    continue
                osh = self.shards[sj]
                if osh.slot_valid[oslot] and int(osh.slot_doc[oslot]) == odoc:
                    sh.slot_hits[local] = int(osh.slot_hits[oslot])
                    break
            self._rep_register(item.uid, shard, local, sh.doc_id_of(local))
            return
        self.insert_batch(item.emb[None, :], [item.category],
                          [item.request], [item.response], [item.meta])

    def _wb_enqueue(self, si: int, emb: np.ndarray, category: str,
                    request: str, response: str, meta: dict | None,
                    mode: str = "front", uid: int = -1) -> bool:
        """Acknowledge a write into shard ``si``'s bounded write-behind
        queue; a full queue DROPS (counted, unacknowledged-by-
        construction — only enqueued writes carry the zero-loss replay
        guarantee)."""
        q = self._write_behind[si]
        if len(q) >= self.write_behind_capacity:
            self.fault_stats["wb_dropped"] += 1
            self._event("wb_drop", shard=si, category=category)
            return False
        self._next_wb_id += 1
        q.append(_WbItem(self._next_wb_id, mode, uid, emb.copy(), category,
                         request, response, meta, self.clock.now()))
        self.fault_stats["wb_enqueued"] += 1
        self._event("wb_enqueue", shard=si, category=category,
                    wb_id=self._next_wb_id, mode=mode)
        return True

    # ------------------------------------------------------------- replication
    def _mint_uid(self) -> int:
        """Fresh doc-correspondence uid; piggybacks a periodic registry
        prune so the maps stay bounded by the LIVE replicated set."""
        uid = self._next_uid
        self._next_uid += 1
        if uid and uid % 4096 == 0:
            self._prune_registry()
        return uid

    def _rep_register(self, uid: int, shard: int, local: int,
                      doc_id: int) -> None:
        if uid < 0 or local == INVALID or doc_id == INVALID:
            return
        self._rep_registry.setdefault(uid, {})[shard] = (int(local),
                                                         int(doc_id))
        self._rep_uid_of[(shard, int(doc_id))] = uid

    def _prune_registry(self) -> None:
        """Drop uids with no surviving copy (evicted/expired everywhere)
        plus their back-map keys."""
        dead = []
        for uid, ent in self._rep_registry.items():
            for sj, (oslot, odoc) in ent.items():
                osh = self.shards[sj]
                if osh.slot_valid[oslot] and int(osh.slot_doc[oslot]) == odoc:
                    break
            else:
                dead.append(uid)
        for uid in dead:
            for sj, (_, odoc) in self._rep_registry.pop(uid).items():
                self._rep_uid_of.pop((sj, odoc), None)

    def _echo_hit(self, si: int, local_slot: int) -> None:
        """Mirror the serving replica's hit count onto live siblings so
        eviction scores stay in lockstep across the replica set. A live
        sibling whose copy is GONE while the serving copy took a hit is
        observed drift: counted ``replica_divergence`` and pruned."""
        sh = self.shards[si]
        doc_id = int(sh.slot_doc[local_slot])
        uid = self._rep_uid_of.get((si, doc_id))
        if uid is None:
            return
        ent = self._rep_registry.get(uid, {})
        h = int(sh.slot_hits[local_slot])
        for sj in sorted(ent):
            if sj == si:
                continue
            oslot, odoc = ent[sj]
            osh = self.shards[sj]
            if osh.slot_valid[oslot] and int(osh.slot_doc[oslot]) == odoc:
                osh.slot_hits[oslot] = h
            elif not self._shard_down(sj):
                self.fault_stats["replica_divergence"] += 1
                self._event("replica_divergence", shard=sj, uid=uid)
                del ent[sj]
                self._rep_uid_of.pop((sj, odoc), None)

    def replica_doc_ids(self, slot: int) -> list[int]:
        """Every replica's doc id behind a (global) slot, serving copy
        first — the simulator records ground truth under ALL of them so
        a failover read is judged against the same truth as a primary
        read."""
        shard, local = self.shard_of_slot(slot)
        if shard == INVALID:
            return []
        d = self.shards[shard].doc_id_of(local)
        if d == INVALID:
            return []
        out = [d]
        uid = self._rep_uid_of.get((shard, d))
        if uid is not None:
            for sj in sorted(self._rep_registry.get(uid, {})):
                if sj == shard:
                    continue
                odoc = self._rep_registry[uid][sj][1]
                if odoc not in out:
                    out.append(odoc)
        return out

    def recover_migrations(self, mode: str = "auto") -> dict[str, str]:
        """Run ``recover`` on every in-flight (crashed) migration or
        outage rebalance; returns {category: action taken}."""
        out = {}
        for cat in sorted(self._migrations):
            mig = self._migrations.get(cat)
            if mig is not None:
                out[cat] = mig.recover(mode)
        return out

    def shard_of_slot(self, slot: int) -> tuple[int, int]:
        """Decode a globally-encoded slot id to (shard, local slot);
        INVALID decodes to (INVALID, INVALID), never to a real shard."""
        if slot < 0:
            return INVALID, INVALID
        return divmod(slot, self.shard_capacity)

    def _global_slot(self, shard: int, local: int) -> int:
        return shard * self.shard_capacity + local if local != INVALID \
            else INVALID

    def doc_id_of(self, slot: int) -> int:
        shard, local = self.shard_of_slot(slot)
        return self.shards[shard].doc_id_of(local) if shard != INVALID \
            else INVALID

    # ------------------------------------------------------------------ reads
    def lookup(self, embedding: np.ndarray, category: str) -> CacheResult:
        return self.lookup_batch(embedding[None, :], [category])[0]

    def lookup_batch(self, embeddings: np.ndarray,
                     categories: Sequence[str]) -> list[CacheResult]:
        """Fan-out masked search: partition the batch per serving shard,
        run each shard's (device-resident) search, merge back into
        request order. One ``search_ms`` clock charge for the whole
        round — the shards search in parallel on real hardware — and the
        TTL ``now`` every shard classifies against is the same instant a
        single cache would use. Replicated categories route
        deterministically round-robin across the replica set, failing
        over to the next live replica inside an outage window (counted
        ``failover_reads``); a lookup is degraded only when NO replica
        is live."""
        embeddings = np.atleast_2d(np.asarray(embeddings, np.float32))
        # Fault hooks run BEFORE the root span opens: write-behind
        # replay and outage rebalancing re-enter the write path and
        # record their own root spans, not children of this lookup.
        self._fault_hooks()
        with self._span("lookup", batch=int(embeddings.shape[0])):
            return self._lookup_batch_impl(embeddings, categories)

    def _lookup_batch_impl(self, embeddings: np.ndarray,
                           categories: Sequence[str]) -> list[CacheResult]:
        B = embeddings.shape[0]
        assert len(categories) == B
        results: list[CacheResult] = [None] * B  # type: ignore[list-item]
        read_shards = [INVALID] * B
        per_shard: dict[int, list[int]] = {}
        degraded: dict[int, list[int]] = {}
        replicated: set[int] = set()
        # Span "route": shard routing + replica pick/failover for the
        # whole batch (no clock charge — routing is control-plane).
        with self._span("route", batch=B) as rsp:
            failovers = 0
            for i, c in enumerate(categories):
                reps = self.replica_set(c)
                if len(reps) == 1:
                    s0 = reps[0]
                    if self._shard_down(s0):
                        degraded.setdefault(s0, []).append(i)
                    else:
                        per_shard.setdefault(s0, []).append(i)
                        read_shards[i] = s0
                    continue
                # Deterministic round-robin read routing: the per-category
                # cursor advances on EVERY lookup (served or not), so the
                # assignment stream is a pure function of the request
                # stream + schedule — the determinism property tests
                # compare it byte-for-byte across runs.
                rr = self._rr.get(c, 0)
                self._rr[c] = rr + 1
                k = rr % len(reps)
                order = reps[k:] + reps[:k]
                si = next((s for s in order if not self._shard_down(s)), None)
                if si is None:
                    degraded.setdefault(reps[0], []).append(i)
                    continue
                if si != order[0]:
                    self.fault_stats["failover_reads"] += 1
                    failovers += 1
                    self._event("failover_read", category=c,
                                primary=order[0], served_by=si)
                replicated.add(i)
                read_shards[i] = si
                per_shard.setdefault(si, []).append(i)
            rsp.set(failovers=failovers,
                    degraded=sum(len(v) for v in degraded.values()))
        agg = {"batch": 0, "hops": 0, "rows_gathered": 0,
               "gathered_bytes": 0, "reranks": 0, "degraded": 0,
               "per_shard": {}}
        any_active = False
        for si in sorted(set(per_shard) | set(degraded)):
            # Degraded mode: no live replica holds the category, so
            # every cacheable lookup routed here resolves as a counted
            # degraded_miss — the caller serves from the model, exactly
            # like a miss, and the hit-rate denominator never sees it
            # (metrics.CategoryStats). Compliance-blocked traffic
            # classifies as usual: that decision is policy-side and
            # needs no index.
            for i in degraded.get(si, []):
                c = categories[i]
                st = self.metrics.cat(c)
                st.lookups += 1
                if not self.policies.effective(c).allow_caching:
                    st.compliance_rejects += 1
                    st.misses += 1
                    results[i] = CacheResult(False, category=c,
                                             reason="compliance")
                    continue
                st.degraded_misses += 1
                self.fault_stats["degraded_misses"] += 1
                self._event("degraded_miss", category=c, shard=si)
                agg["degraded"] += 1
                any_active = True
                results[i] = CacheResult(False, category=c,
                                         reason="degraded",
                                         latency_ms=self.search_ms)
            idxs = per_shard.get(si)
            if not idxs:
                continue
            sub = self.shards[si].lookup_batch(
                embeddings[idxs], [categories[i] for i in idxs])
            ls = self.shards[si].last_lookup_stats
            if ls:
                agg["per_shard"][si] = dict(ls)
                for k in ("batch", "hops", "rows_gathered",
                          "gathered_bytes", "reranks"):
                    agg[k] += ls.get(k, 0)
            for i, r in zip(idxs, sub):
                if r.reason != "compliance":
                    any_active = True
                    r.latency_ms = self.search_ms
                if r.slot != INVALID:
                    if r.hit and i in replicated:
                        # Echo the serving replica's hit count to live
                        # siblings BEFORE globalizing the slot id.
                        self._echo_hit(si, r.slot)
                    r.slot = self._global_slot(si, r.slot)
                results[i] = r
        self.last_read_shards = read_shards
        # Mirrors the single cache: a batch that is 100 % compliance-
        # rejected never reaches the index and costs no search time.
        if any_active:
            # The front door owns the ONE fan-out search charge (shards
            # run with search_ms=0); span "search" at shard=-1 carries it.
            with self._span("search", batch=B):
                self.clock.advance(self.search_ms / 1e3)
        self.last_lookup_stats = agg if any_active else {}
        return results

    # ------------------------------------------------------------------ writes
    def insert(self, embedding: np.ndarray, category: str, request: str,
               response: str, meta: dict | None = None) -> int:
        return self.insert_batch(np.asarray(embedding)[None, :], [category],
                                 [request], [response], [meta])[0]

    def insert_batch(self, embeddings: np.ndarray,
                     categories: Sequence[str], requests: Sequence[str],
                     responses: Sequence[str],
                     metas: Sequence[dict | None] | None = None) -> list[int]:
        """Partition the write batch per serving shard; each sub-batch
        pays the shard's single eviction-scoring/store/index pass and
        its touched rows land in that shard's dirty log (one delta flush
        per shard on its next search). Slot ids come back globally
        encoded; INVALID for rejected items, as in the single cache."""
        embeddings = np.atleast_2d(np.asarray(embeddings, np.float32))
        B = embeddings.shape[0]
        metas = list(metas) if metas is not None else [None] * B
        if not (len(categories) == len(requests) == len(responses)
                == len(metas) == B):
            raise ValueError("insert_batch: ragged batch")
        # Fault hooks run BEFORE the root span opens (see lookup_batch).
        self._fault_hooks()
        with self._span("insert", batch=B):
            return self._insert_batch_impl(embeddings, categories,
                                           requests, responses, metas)

    def _insert_batch_impl(self, embeddings, categories, requests,
                           responses, metas) -> list[int]:
        B = embeddings.shape[0]
        slots_out = [INVALID] * B
        agg = {"batch": B, "admitted": 0, "admission_skips": 0,
               "insert_rejects": 0, "per_shard": {}}
        per_shard: dict[int, list[int]] = {}
        rep_batches: dict[int, list[tuple[int, int]]] = {}  # si -> [(i, uid)]
        rep_primary: dict[int, int] = {}                    # i  -> primary
        # Span "route": the one write-round charge plus fence/replica/
        # write-behind partitioning of the batch.
        with self._span("route", batch=B):
            # One write-round clock charge iff anything is admissible —
            # matching the single cache, whose advance sits behind the
            # compliance gate.
            eff = {c: self.policies.effective(c)
                   for c in dict.fromkeys(categories)}
            if any(eff[c].allow_caching and eff[c].quota > 0.0
                   for c in categories):
                self.clock.advance(self.insert_ms / 1e3)
            for i, c in enumerate(categories):
                mig = self._migrations.get(c)
                if mig is not None and mig.fenced:
                    # Cutover write fence: the write queues on the migration
                    # (acknowledged — INVALID slot, like any deferred write)
                    # and replays to whichever shard owns the category once
                    # the fence drops. Non-cacheable traffic short-circuits
                    # as usual; the fence only defers writes that would land.
                    e = eff[c]
                    if not e.allow_caching or e.quota <= 0.0:
                        self.metrics.cat(c).insert_rejects += 1
                        agg["insert_rejects"] += 1
                        continue
                    if len(mig.fence_queue) >= self.write_behind_capacity:
                        self.fault_stats["fence_dropped"] += 1
                        self._event("fence_drop", category=c)
                        continue
                    mig.fence_queue.append((embeddings[i].copy(),
                                            requests[i], responses[i],
                                            metas[i]))
                    self.fault_stats["fenced_writes"] += 1
                    self._event("fenced_write", category=c)
                    continue
                reps = self.replica_set(c)
                if len(reps) == 1:
                    per_shard.setdefault(reps[0], []).append(i)
                    continue
                # Replicated write fan-out: compliance is decided ONCE at
                # the front door (the per-shard path would count the reject
                # on every replica), then every LIVE replica gets the write
                # in this same batched round; down replicas get a replica-
                # mode write-behind item that catches them up directly on
                # recovery (their siblings already applied the write).
                e = eff[c]
                if not e.allow_caching or e.quota <= 0.0:
                    self.metrics.cat(c).insert_rejects += 1
                    agg["insert_rejects"] += 1
                    continue
                uid = self._mint_uid()
                rep_primary[i] = reps[0]
                for sj in reps:
                    if self._shard_down(sj):
                        self._wb_enqueue(sj, embeddings[i], c, requests[i],
                                         responses[i], metas[i],
                                         mode="replica", uid=uid)
                    else:
                        rep_batches.setdefault(sj, []).append((i, uid))
        for si in sorted(per_shard):
            idxs = per_shard[si]
            if self._shard_down(si):
                # Shard outage: acknowledge the write into the bounded
                # write-behind queue (replayed FIFO on recovery by
                # _maybe_replay). A full queue DROPS — the drop is
                # counted and unacknowledged-by-construction: only
                # enqueued writes carry the zero-loss replay guarantee.
                for i in idxs:
                    c = categories[i]
                    e = eff[c]
                    if not e.allow_caching or e.quota <= 0.0:
                        self.metrics.cat(c).insert_rejects += 1
                        agg["insert_rejects"] += 1
                        continue
                    self._wb_enqueue(si, embeddings[i], c, requests[i],
                                     responses[i], metas[i])
                continue
            sub = self.shards[si].insert_batch(
                embeddings[idxs], [categories[i] for i in idxs],
                [requests[i] for i in idxs], [responses[i] for i in idxs],
                [metas[i] for i in idxs])
            self._merge_insert_stats(agg, si,
                                     self.shards[si].last_insert_stats)
            for i, local in zip(idxs, sub):
                slots_out[i] = self._global_slot(si, int(local))
        # Replicated fan-out: one sub-batch per live replica in the same
        # write round (each replica's dirty-log delta sync stays
        # O(batch)); the PRIMARY's slot is the caller-visible one.
        for sj in sorted(rep_batches):
            pairs = rep_batches[sj]
            idxs = [i for i, _ in pairs]
            sub = self.shards[sj].insert_batch(
                embeddings[idxs], [categories[i] for i in idxs],
                [requests[i] for i in idxs], [responses[i] for i in idxs],
                [metas[i] for i in idxs])
            self._merge_insert_stats(agg, sj,
                                     self.shards[sj].last_insert_stats)
            for (i, uid), local in zip(pairs, sub):
                local = int(local)
                if local == INVALID:
                    continue
                self._rep_register(uid, sj, local,
                                   self.shards[sj].doc_id_of(local))
                if rep_primary.get(i) == sj:
                    slots_out[i] = self._global_slot(sj, local)
        self.last_insert_stats = agg
        return slots_out

    @staticmethod
    def _merge_insert_stats(agg: dict, si: int, ins: dict) -> None:
        """Fold one shard sub-batch's insert stats into the round's
        aggregate; a shard can serve BOTH a single-home and a replicated
        sub-batch in one round, so per-shard entries sum-merge."""
        if not ins:
            return
        prev = agg["per_shard"].get(si)
        if prev is None:
            agg["per_shard"][si] = dict(ins)
        else:
            for k, v in ins.items():
                if isinstance(v, (int, float)):
                    prev[k] = prev.get(k, 0) + v
        for k in ("admitted", "admission_skips", "insert_rejects"):
            agg[k] += ins.get(k, 0)

    def sweep_expired(self) -> int:
        return sum(s.sweep_expired() for s in self.shards)

    # ---------------------------------------------------------------- migration
    def migrate_category(self, category: str, target: int,
                         batch_size: int = 64,
                         stepwise: bool = False) -> CategoryMigration | None:
        """Move a category to ``target``. Default: drain + cutover in
        one call. ``stepwise=True`` returns the live ``CategoryMigration``
        so the caller interleaves ``step()`` with serving traffic and
        invokes ``cutover()`` itself (reads stay on the source, and
        correct, throughout). The target must have physical headroom for
        the category: a drain step that finds the target full aborts the
        whole migration atomically (target copies dropped, source still
        authoritative, retryable) and re-raises."""
        src = self.shard_of(category)
        if target == src or not (0 <= target < self.n_shards):
            return None
        if category in self._migrations:
            raise RuntimeError(f"migration of {category!r} already active")
        if len(self.replica_set(category)) > 1:
            raise RuntimeError(
                f"{category!r} is replicated — replicated categories are "
                "pinned (their outage story is the replica set, not "
                "migration)")
        mig = CategoryMigration(self, category, src, target, batch_size)
        self._migrations[category] = mig
        if not stepwise:
            with self._span("migration", category=category,
                            src=src, dst=target):
                mig.run()
        return mig

    def rebalance(self, quotas: dict[str, float] | None = None) -> dict:
        """Re-plan placement (quota reassignment, an AdaptiveController
        retune, …) and live-migrate every category whose planned shard
        moved. Returns {category: (src, dst)} for the moves made.
        Requires a quota-byte ``ShardPlanner`` — the crc32 fallback has
        no byte bookkeeping to re-plan against."""
        if not isinstance(self.planner, ShardPlanner):
            raise TypeError(
                "rebalance() needs a ShardPlanner; this cache routes via "
                f"{type(self.planner).__name__} (the quota-blind "
                "fallback) — migrate_category() still works")
        if quotas is None:
            quotas = {n: self.policies.get(n).quota
                      for n in self.policies.categories()
                      if self.policies.get(n).allow_caching
                      and self.policies.get(n).quota > 0}
        scratch = ShardPlanner(self.n_shards, self.capacity,
                               residency=self.planner.residency,
                               policies=self.policies,
                               replication=self.planner.replication)
        target = scratch.plan(quotas)
        moves: dict[str, tuple[int, int]] = {}
        for cat, dst in target.items():
            if len(self.planner.replica_set(cat)) > 1:
                # Pinned: replicated categories keep their replica set
                # across re-plans — failover, not migration, covers them.
                continue
            src = self.planner.shard_of(cat)
            if src != dst:
                self.migrate_category(cat, dst)
                moves[cat] = (src, dst)
            # refresh the byte bookkeeping at the NEW quota weight (the
            # cutover's assign reuses the stored pre-change weight)
            self.planner.assign(cat, self.planner.shard_of(cat),
                                nbytes=self.planner.quota_bytes(quotas[cat]))
        return moves

    # ---------------------------------------------------------------- reporting
    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def category_count(self, name: str) -> int:
        return sum(s.category_count(name) for s in self.shards)

    @property
    def sync_stats(self) -> dict:
        """Delta-sync accounting summed across shards, with the
        per-shard breakdown under ``per_shard`` (what ``launch/serve``'s
        topology report prints)."""
        agg: dict = {"full_uploads": 0, "delta_updates": 0,
                     "rows_synced": 0, "bytes_synced": 0,
                     "emb_bytes_synced": 0}
        per = []
        for s in self.shards:
            st = dict(s.index.sync_stats)
            per.append(st)
            for k in agg:
                agg[k] += st.get(k, 0)
        agg["per_shard"] = per
        return agg

    def shard_report(self) -> list[dict]:
        """Per-shard residency: entries, resident bytes (entries × the
        resident tier's bytes/entry), categories served, sync counters —
        the spread the placement benchmark gates on."""
        out = []
        for si, s in enumerate(self.shards):
            rep = s.memory_report()
            cats = sorted(c for c, sid in self.planner.assignments.items()
                          if sid == si) if hasattr(self.planner,
                                                   "assignments") else []
            out.append({
                "shard": si,
                "entries": rep["entries"],
                "resident_bytes": rep["entries"]
                * rep["in_memory_bytes_per_entry"],
                "categories": cats,
                "replicated": sorted(
                    c for c, rs in getattr(self.planner, "replica_sets",
                                           {}).items() if si in rs),
                "sync_stats": dict(s.index.sync_stats),
                "search_stats": dict(s.index.search_stats),
            })
        return out
