"""Cost-aware admission & eviction control plane (ROADMAP: beyond
static heuristics; "Rethinking Caching for LLM Serving Systems" +
SCALM's cluster-level repetition ranking, PAPERS.md).

The seed repro admitted every miss unconditionally and evicted with the
fixed §5.4 formula. That churns quota on uniform-tail categories (Table
1: conversational repetition is uniform — most queries never recur, yet
each one used to claim a resident row until eviction reclaimed it). Two
host-control-plane pieces fix both sides of the ledger:

``AdmissionController``
    A deterministic per-category repetition tracker, consulted by
    ``SemanticCache.insert_batch`` when a category's policy sets
    ``admit_after > 1``: a miss is only cached on its k-th observation,
    so the never-repeating uniform tail stops occupying quota while
    repeated intents are admitted on their second touch. Three layers
    per category (``CategoryTracker``):

    * ``QueryFingerprinter`` — SimHash (sign bits of fixed random
      projections) mints a stable 64-bit key per query embedding.
    * a similarity ring buffer canonicalizes paraphrases: a query whose
      cosine against a bounded window of recent representatives clears
      the category's own threshold τ inherits that REPRESENTATIVE's
      key. This matters because paraphrase noise is of the same order
      as inter-intent spacing under any fixed random projection
      (measured on the Table-1 chat space: raw 16-bit SimHash keeps
      only ~5 % of true repeats on one key while colliding ~40 % of
      distinct intents) — the only reliable repetition test here is the
      same exact-similarity test the cache itself uses for hits.
    * ``FrequencySketch`` — a conservative-update count-min sketch with
      periodic halving decay counts key occurrences: cheap, bounded
      over-count, mergeable (migration), sliding-window via decay.

    All state is keyed per category and seeded from the CATEGORY NAME —
    never from the owning cache's seed — so N shards each tracking their
    own categories reproduce the single cache's decisions bit-for-bit
    (tests/test_shard.py), and a live migration hands the tracker to the
    target shard at cutover.

``StaticEvictionScorer`` / ``CostAwareEvictionScorer``
    Pluggable victim scoring for ``SemanticCache`` (``eviction=``).
    Static is the paper's §5.4 ``priority × 1/age × hitRate`` formula
    (the default — bit-identical to the seed behavior). Cost-aware
    prices an entry by what its residency actually buys:

        score = expected_hits_per_s × miss_cost_ms / bytes_per_entry

    expected hits from the observed hit intensity ``(hits+1)/age``
    (fresh entries inherit the admission sketch's repetition count as
    their prior), miss cost from the category's ``expected_tllm_ms``
    (the model time a hit avoids), and bytes/entry from
    ``economics.ResidencyModel`` under the active resident dtype — so
    the evictor maximizes hit-rate-per-resident-byte, the metric
    ``bench_admission`` gates on, instead of a hand-tuned priority.

Everything here is plain numpy on the host control plane: no device
state, no wall clock, deterministic at fixed seed. Per gated category
the tracker holds ``buffer_size × dim`` fp32 (~1.5 MB at the defaults)
plus the ``depth × width`` uint32 sketch (~32 KB).
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.core.economics import ResidencyModel, entry_value_density

# Sketch hashing: multiply-shift with fixed odd 64-bit constants per
# row; the shift keeps the high (well-mixed) product bits.
_HASH_SHIFT = np.uint64(17)


class QueryFingerprinter:
    """SimHash fingerprint: sign bits of ``n_bits`` fixed random
    projections, packed into one uint64 key.

    The projection matrix is seeded deterministically, so fingerprints
    are stable across processes and shards, and at 64 bits distinct
    intents essentially never collide. What SimHash alone can NOT
    deliver on realistic paraphrase noise is keeping two paraphrases of
    one intent on one key (every near-zero projection margin flips) —
    that is the similarity ring buffer's job in ``CategoryTracker``.
    """

    def __init__(self, dim: int, n_bits: int = 64, seed: int = 0):
        if not (1 <= n_bits <= 64):
            raise ValueError("n_bits must be in [1, 64]")
        self.dim = dim
        self.n_bits = n_bits
        rng = np.random.default_rng(seed)
        self._proj = rng.standard_normal((dim, n_bits)).astype(np.float32)
        self._weights = (np.uint64(1) << np.arange(n_bits, dtype=np.uint64))

    def key(self, embedding: np.ndarray) -> int:
        emb = np.asarray(embedding, np.float32).reshape(-1)
        bits = (emb @ self._proj) >= 0.0
        return int((bits.astype(np.uint64) * self._weights).sum())


class FrequencySketch:
    """Conservative-update count-min sketch with periodic halving decay.

    ``observe(key)`` increments only the cells at the current minimum
    (conservative update — strictly less over-count than plain CMS) and
    returns the post-update estimate. Guarantees, property-tested in
    tests/test_admission.py:

        * never undercounts: ``estimate(k) ≥ true_count(k)`` (no decay)
        * bounded by traffic: ``estimate(k) ≤ total observations``
        * deterministic: same seed + same stream → identical state
        * ``decay()`` halves every estimate exactly (integer floor);
          auto-triggered every ``decay_every`` observations so the
          sketch tracks a sliding window, not all of history
        * ``merge`` adds cell-wise (same seed required): the merged
          sketch never undercounts the combined stream
    """

    def __init__(self, width: int = 2048, depth: int = 4, seed: int = 0,
                 decay_every: int = 8192):
        if width < 1 or depth < 1:
            raise ValueError("width and depth must be positive")
        self.width = width
        self.depth = depth
        self.seed = seed
        self.decay_every = decay_every
        rng = np.random.default_rng(seed)
        self._a = rng.integers(1, 2**63, size=depth, dtype=np.uint64) \
            | np.uint64(1)                       # odd multipliers
        self._b = rng.integers(0, 2**63, size=depth, dtype=np.uint64)
        self._rows = np.arange(depth)
        self.counts = np.zeros((depth, width), np.uint32)
        self.observations = 0
        self._since_decay = 0

    def _cells(self, key: int) -> np.ndarray:
        k = np.uint64(key)
        h = (self._a * k + self._b) >> _HASH_SHIFT   # uint64 wrap is fine
        return (h % np.uint64(self.width)).astype(np.int64)

    def estimate(self, key: int) -> int:
        return int(self.counts[self._rows, self._cells(key)].min())

    def observe(self, key: int) -> int:
        """Count one occurrence; returns the post-update estimate."""
        cells = self._cells(key)
        cur = self.counts[self._rows, cells]
        new = np.uint32(int(cur.min()) + 1)
        self.counts[self._rows, cells] = np.maximum(cur, new)
        self.observations += 1
        self._since_decay += 1
        if self.decay_every and self._since_decay >= self.decay_every:
            self.decay()
        return int(new)

    def decay(self) -> None:
        """Halve every cell (sliding-window aging, TinyLFU-style)."""
        self.counts >>= np.uint32(1)
        self._since_decay = 0

    def merge(self, other: "FrequencySketch") -> None:
        """Cell-wise add ``other`` into this sketch (same seed/shape)."""
        if (self.width, self.depth, self.seed) != \
                (other.width, other.depth, other.seed):
            raise ValueError("merge: incompatible sketch parameters")
        self.counts = self.counts + other.counts
        self.observations += other.observations


class CategoryTracker:
    """One category's repetition state: representative ring buffer +
    fingerprinter + frequency sketch.

    ``observe(emb, tau)`` resolves the query to a canonical key — the
    nearest ring-buffer representative's key when its cosine clears
    ``tau`` (pass the category's own hit threshold), else a freshly
    minted SimHash key with the query enrolled as a new representative —
    then counts the key in the sketch and returns the post-update
    repetition estimate. Everything is a deterministic function of the
    observation order (argmax ties break to the lowest buffer slot), so
    identical per-category streams give identical decisions on any
    shard. Tail queries that never repeat each occupy one ring slot and
    age out; an intent re-queried within the window inherits its
    representative's key and crosses the admission bar.
    """

    def __init__(self, dim: int, tau: float = 0.80,
                 buffer_size: int = 1024, n_bits: int = 64,
                 width: int = 2048, depth: int = 4, seed: int = 0,
                 decay_every: int = 8192):
        self.tau = tau
        self.fingerprinter = QueryFingerprinter(dim, n_bits, seed=seed)
        self.sketch = FrequencySketch(width, depth,
                                      seed=seed ^ 0x9E3779B9,
                                      decay_every=decay_every)
        self._buf_emb = np.zeros((buffer_size, dim), np.float32)
        self._buf_key = np.zeros(buffer_size, np.uint64)
        self._buf_n = 0
        self._buf_pos = 0

    @property
    def representatives(self) -> int:
        return self._buf_n

    def key_of(self, embedding: np.ndarray, tau: float | None = None) -> int:
        """Canonical repetition key; enrolls unseen queries as
        representatives but adds no count."""
        t = self.tau if tau is None else tau
        emb = np.asarray(embedding, np.float32).reshape(-1)
        if self._buf_n:
            sims = self._buf_emb[:self._buf_n] @ emb
            j = int(np.argmax(sims))
            if float(sims[j]) >= t:
                return int(self._buf_key[j])
        key = self.fingerprinter.key(emb)
        self._buf_emb[self._buf_pos] = emb
        self._buf_key[self._buf_pos] = np.uint64(key)
        self._buf_pos = (self._buf_pos + 1) % len(self._buf_key)
        self._buf_n = min(self._buf_n + 1, len(self._buf_key))
        return key

    def observe(self, embedding: np.ndarray,
                tau: float | None = None) -> int:
        return self.sketch.observe(self.key_of(embedding, tau))

    def observe_batch(self, embeddings: np.ndarray,
                      tau: float | None = None) -> np.ndarray:
        """Sequential-equivalent batched observe: ONE ``(n_reps, B)``
        matmul scores the whole batch against the pre-batch ring buffer
        instead of an O(buffer·dim) host matvec per item, then the
        items resolve IN ORDER so intra-batch enrollments (an item
        minting a new representative that canonicalizes a later item)
        behave exactly like B sequential ``observe`` calls: slots
        (re)written within the batch are re-scored with a per-slot dot
        (at most B of them), everything else reads the snapshot column.
        Tie-breaking (argmax → lowest slot) matches the sequential
        path. B == 1 routes through ``observe`` itself, so single-item
        streams — the simulator's per-miss inserts — are bit-identical
        to the pre-batching behavior.
        """
        t = self.tau if tau is None else tau
        embs = np.atleast_2d(np.asarray(embeddings, np.float32))
        B = embs.shape[0]
        if B == 1:
            return np.asarray([self.observe(embs[0], t)], np.int64)
        base_n = self._buf_n
        snap = (self._buf_emb[:base_n] @ embs.T if base_n
                else np.zeros((0, B), np.float32))
        touched: set[int] = set()      # ring slots written by this batch
        out = np.empty(B, np.int64)
        for i in range(B):
            n = self._buf_n
            if n:
                sims = np.full(n, -np.inf, np.float32)
                m = min(base_n, n)
                sims[:m] = snap[:m, i]
                for j in touched:
                    sims[j] = self._buf_emb[j] @ embs[i]
                j = int(np.argmax(sims))
                if float(sims[j]) >= t:
                    out[i] = self.sketch.observe(int(self._buf_key[j]))
                    continue
            key = self.fingerprinter.key(embs[i])
            self._buf_emb[self._buf_pos] = embs[i]
            self._buf_key[self._buf_pos] = np.uint64(key)
            touched.add(self._buf_pos)
            self._buf_pos = (self._buf_pos + 1) % len(self._buf_key)
            self._buf_n = min(self._buf_n + 1, len(self._buf_key))
            out[i] = self.sketch.observe(key)
        return out

    def estimate(self, embedding: np.ndarray,
                 tau: float | None = None) -> int:
        return self.sketch.estimate(self.key_of(embedding, tau))

    def merge(self, other: "CategoryTracker") -> None:
        """Fold another shard's tracker in at migration: sketch counts
        add cell-wise; this side's representatives win. Keys are minted
        by the shared name-seeded fingerprinter, so counts from both
        sides keep referring to the same embeddings."""
        self.sketch.merge(other.sketch)


class AdmissionController:
    """Per-category repetition tracking for admission decisions.

    Trackers are created lazily per category and seeded from
    ``crc32(category name)`` — NOT from the owning cache's seed — so
    every shard of a sharded cache derives the identical tracker for
    the categories it serves, and the single-vs-sharded parity property
    holds with admission enabled. ``export_state`` / ``adopt_state``
    hand a category's tracker across shards at migration cutover so
    repetition history survives the move.
    """

    def __init__(self, dim: int, buffer_size: int = 1024,
                 n_bits: int = 64, width: int = 2048, depth: int = 4,
                 decay_every: int = 8192):
        self.dim = dim
        self.buffer_size = buffer_size
        self.n_bits = n_bits
        self.width = width
        self.depth = depth
        self.decay_every = decay_every
        self._trackers: dict[str, CategoryTracker] = {}

    def tracker(self, category: str) -> CategoryTracker:
        if category not in self._trackers:
            self._trackers[category] = CategoryTracker(
                self.dim, buffer_size=self.buffer_size,
                n_bits=self.n_bits, width=self.width, depth=self.depth,
                seed=zlib.crc32(category.encode()),
                decay_every=self.decay_every)
        return self._trackers[category]

    def observe(self, category: str, embedding: np.ndarray,
                tau: float | None = None) -> int:
        """Count one occurrence of the query's canonical key; returns
        the post-update repetition estimate (1 = first sighting)."""
        return self.tracker(category).observe(embedding, tau)

    def observe_batch(self, category: str, embeddings: np.ndarray,
                      tau: float | None = None) -> np.ndarray:
        """Batched ``observe`` over one category's items (in stream
        order): one ring-buffer matmul for the batch instead of a host
        matvec per item, with sequential-equivalent enrollment."""
        return self.tracker(category).observe_batch(embeddings, tau)

    def estimate(self, category: str, embedding: np.ndarray,
                 tau: float | None = None) -> int:
        if category not in self._trackers:
            return 0
        return self.tracker(category).estimate(embedding, tau)

    # -- migration ---------------------------------------------------------
    def export_state(self, category: str) -> CategoryTracker | None:
        """Detach and return the category's tracker (None if untracked)."""
        return self._trackers.pop(category, None)

    def adopt_state(self, category: str,
                    state: CategoryTracker | None) -> None:
        if state is None:
            return
        if category in self._trackers:
            self._trackers[category].merge(state)
        else:
            self._trackers[category] = state

    def stats(self) -> dict:
        return {c: {"observations": t.sketch.observations,
                    "representatives": t.representatives}
                for c, t in sorted(self._trackers.items())}


# ---------------------------------------------------------------------------
# Eviction scorers (SemanticCache ``eviction=``). Higher = more valuable.
# ---------------------------------------------------------------------------

class StaticEvictionScorer:
    """§5.4: score = priority × 1/age × (hits + 1). The seed formula and
    the default — existing eviction behavior is bit-identical."""

    name = "static"

    def score(self, cache, slots: np.ndarray) -> np.ndarray:
        now = cache._now()
        age = np.maximum(now - cache.slot_inserted[slots], 1e-3)
        _, pri_by_cid = cache._per_category_arrays()
        pri = pri_by_cid[cache.slot_category[slots]]
        return pri * (1.0 / age) * (cache.slot_hits[slots] + 1)

    def fresh_score(self, cache, cid: int, freq: int = 1) -> float:
        """A just-inserted entry: hits = 0, age clamped to 1e-3 — the
        sequential-path pending score (repetition count ignored)."""
        name = cache._cat_names.get(cid, "__default__")
        return float(cache.policies.effective(name).priority) * 1e3


class CostAwareEvictionScorer:
    """Economic scoring: expected-hits × miss-cost per resident byte.

    ``score = (hits + 1)/age × expected_tllm_ms / bytes_per_entry`` —
    the ms of downstream model time a slot's residency saves per second,
    per byte it pins (``economics.entry_value_density``). Bytes/entry
    come from ``ResidencyModel`` under the cache's resident dtype, so
    int8 residency uniformly re-prices the denominator; miss cost from
    the category's ``expected_tllm_ms``, so a code_generation entry
    (500 ms model) outranks an equally-hit chat entry (200 ms) instead
    of leaning on the hand-tuned ``priority``. Fresh entries use the
    admission sketch's repetition count as their expected-hits prior —
    SCALM's cluster-level repetition ranking at insert time.
    """

    name = "cost_aware"

    def _tables(self, cache) -> tuple[np.ndarray, float]:
        """cid → miss-cost table + bytes/entry under the residency."""
        n = (max(cache._cat_names) + 1) if cache._cat_names else 0
        cost = np.full(n, 500.0, np.float64)
        for cid, name in cache._cat_names.items():
            cost[cid] = cache.policies.get(name).expected_tllm_ms
        bpe = ResidencyModel(dim=cache.dim,
                             emb_dtype=cache.index.emb_dtype).bytes_per_entry()
        return cost, float(bpe)

    def score(self, cache, slots: np.ndarray) -> np.ndarray:
        now = cache._now()
        age = np.maximum(now - cache.slot_inserted[slots], 1e-3)
        cost_by_cid, bpe = self._tables(cache)
        cost = cost_by_cid[cache.slot_category[slots]]
        rate = (cache.slot_hits[slots] + 1) / age
        return entry_value_density(rate, cost, bpe)

    def fresh_score(self, cache, cid: int, freq: int = 1) -> float:
        name = cache._cat_names.get(cid, "__default__")
        cost = cache.policies.get(name).expected_tllm_ms
        bpe = ResidencyModel(dim=cache.dim,
                             emb_dtype=cache.index.emb_dtype).bytes_per_entry()
        # freq = the admission sketch's repetition count (1 when the
        # category admits unconditionally): observed pre-admission
        # frequency is the expected-hits prior, age clamps at 1e-3
        # exactly like score() on a zero-age slot.
        return float(entry_value_density(max(1, freq) / 1e-3, cost, bpe))


_SCORERS = {
    "static": StaticEvictionScorer,
    "cost_aware": CostAwareEvictionScorer,
}


def make_eviction_scorer(name: str):
    try:
        return _SCORERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {name!r} (have {sorted(_SCORERS)})")
