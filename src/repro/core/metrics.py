"""Per-category cache statistics (feeds Table 1 + adaptive feedback)."""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class CategoryStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    compliance_rejects: int = 0
    insert_rejects: int = 0
    admission_skips: int = 0       # misses not cached by the admission gate
    degraded_misses: int = 0       # lookups served-from-model because the
                                   # category's shard was down (availability
                                   # accounting: hits + misses + degraded ==
                                   # lookups; degraded never enters the
                                   # hit-rate denominator)
    store_timeouts: int = 0        # would-be hits degraded to misses by an
                                   # exhausted store retry budget (these DO
                                   # count in misses — the entry stays
                                   # resident, the lookup still missed)
    degraded_seconds: float = 0.0  # observed wall (sim) time with NO live
                                   # replica for the category — accrued
                                   # incrementally by the sharded front door
                                   # between ops, so availability-vs-outage
                                   # SLO curves never re-derive window
                                   # overlap from the fault schedule
    ttl_evictions: int = 0
    quota_evictions: int = 0
    capacity_evictions: int = 0
    inserts: int = 0
    reranks: int = 0               # fp32 re-scores of borderline int8 hits
    rerank_flips: int = 0          # decisions the exact re-score changed
    stale_served: int = 0          # ground-truth staleness (simulator only)
    false_positives: int = 0       # ground-truth wrong-intent hits (sim only)
    true_positives: int = 0
    latency_ms_sum: float = 0.0

    @property
    def hit_rate(self) -> float:
        """hits / lookups the cache actually SERVED: degraded lookups
        (shard down — the cache never searched) are excluded from the
        denominator, like ``admission_skips`` on the insert side, so an
        outage window degrades availability, not the measured hit rate.
        With no faults injected this is exactly hits / lookups."""
        served = self.lookups - self.degraded_misses
        return self.hits / served if served else 0.0

    @property
    def availability(self) -> float:
        """Fraction of lookups the cache was reachable for."""
        if not self.lookups:
            return 1.0
        return 1.0 - self.degraded_misses / self.lookups

    @property
    def false_positive_rate(self) -> float:
        total = self.false_positives + self.true_positives
        return self.false_positives / total if total else 0.0

    @property
    def mean_latency_ms(self) -> float:
        """Mean over lookups the cache actually SERVED — the same
        denominator as ``hit_rate``: degraded lookups never reached the
        cache, so no latency was charged to them here."""
        served = self.lookups - self.degraded_misses
        return self.latency_ms_sum / served if served else 0.0

    def to_dict(self) -> dict:
        return {
            "lookups": self.lookups, "hits": self.hits, "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "fp_rate": round(self.false_positive_rate, 4),
            "mean_latency_ms": round(self.mean_latency_ms, 3),
            "compliance_rejects": self.compliance_rejects,
            "insert_rejects": self.insert_rejects,
            "admission_skips": self.admission_skips,
            "degraded_misses": self.degraded_misses,
            "degraded_seconds": round(self.degraded_seconds, 3),
            "store_timeouts": self.store_timeouts,
            "ttl_evictions": self.ttl_evictions,
            "quota_evictions": self.quota_evictions,
            "capacity_evictions": self.capacity_evictions,
            "inserts": self.inserts,
            "reranks": self.reranks,
            "rerank_flips": self.rerank_flips,
            "stale_served": self.stale_served,
            "false_positives": self.false_positives,
            "true_positives": self.true_positives,
        }


#: Fields summed when aggregating CategoryStats across categories.
_SUM_FIELDS = tuple(CategoryStats.__dataclass_fields__)


def overall_stats(per_category: dict[str, CategoryStats]) -> CategoryStats:
    """Sum every counter field across categories; the derived
    properties (hit_rate, availability, mean_latency_ms) then hold the
    fleet-wide values for free."""
    out = CategoryStats()
    for st in per_category.values():
        for f in _SUM_FIELDS:
            setattr(out, f, getattr(out, f) + getattr(st, f))
    return out


def overall_row(per_category: dict[str, CategoryStats]) -> dict:
    """The ``"_overall"`` snapshot entry: a summed ``to_dict()`` plus
    ``availability`` (rates are recomputed from the summed counters,
    NOT averaged across categories)."""
    ov = overall_stats(per_category)
    row = ov.to_dict()
    row["availability"] = round(ov.availability, 4)
    return row


@dataclass
class MetricsRegistry:
    per_category: dict[str, CategoryStats] = field(default_factory=dict)

    def cat(self, name: str) -> CategoryStats:
        if name not in self.per_category:
            self.per_category[name] = CategoryStats()
        return self.per_category[name]

    def overall_hit_rate(self) -> float:
        lookups = sum(s.lookups for s in self.per_category.values())
        hits = sum(s.hits for s in self.per_category.values())
        return hits / lookups if lookups else 0.0

    def snapshot(self) -> dict:
        """Per-category rows plus an ``"_overall"`` aggregate row
        (sorted first by the ``_`` prefix; skip keys starting with
        ``_`` when iterating categories)."""
        snap = {k: v.to_dict() for k, v in sorted(self.per_category.items())}
        snap["_overall"] = overall_row(self.per_category)
        return snap
