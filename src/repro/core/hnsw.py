"""TPU-adapted HNSW index (paper §5, §5.3, §7.4).

The paper's hot loop is CPU HNSW: pointer-chasing greedy traversal with
per-category thresholds applied *during* traversal and early exit on the
first match above threshold. A literal port is hostile to TPU, so the
device-side search is re-blocked for the MXU (see DESIGN.md §3):

* **Host control plane** (this module, numpy): hierarchical HNSW insertion,
  level assignment, neighbor wiring, tombstoning, entry-point maintenance.
  Also an exact hierarchical search used for CPU latency benchmarks.
* **Device data plane** (JAX): *batched fixed-width beam search* over the
  level-0 graph from a multi-entry start set. One hop is the FUSED
  frontier-hop primitive (``repro.kernels.frontier_hop`` via
  ``ops.frontier_hop``): the scalar-prefetched frontier ids drive an
  in-kernel neighbor-row fetch, per-candidate embedding DMAs and the
  masked dot — no XLA-materialized (B, F·M, d) gather — followed by a
  top-F merge. Early exit is the `while_loop` predicate ``best_score ≥
  τ_q`` with a per-query threshold vector — the paper's
  threshold-during-traversal, vectorized — and a *done* query's lanes
  clamp to INVALID inside the hop, so it stops issuing gather DMAs
  entirely. The pure-jnp path here is the portable reference used on CPU
  (``HNSWParams.hop_impl`` selects; None = auto per backend).

Capacity is fixed at construction: tables are preallocated so the jitted
search never recompiles as the cache fills, and the batch dimension is
bucketed to powers of two so every serve batch size B = 1..max_batch
shares one compiled program. ``search_classified`` additionally runs
Algorithm 1's TTL check on device (the ``inserted`` table rides the
delta-sync protocol) and returns {hit, expired, miss} classes.

**Device residency (delta synchronization).** The device tables are
persistent, not a lazily re-uploaded mirror: every host-side mutation
(insert, evict/tombstone, level-0 neighbor rewire) records its touched
rows in a compact dirty-row log, and ``device_tables()`` applies the log
with donated in-place row scatters (``repro.kernels.ops.scatter_rows``:
the Pallas ``scatter_update`` kernel for the lane-aligned embedding
table, XLA scatter for the narrow/flag tables) instead of
re-materializing the full O(capacity·d) tables. A full upload happens only on first use and when
the dirty fraction exceeds ``HNSWParams.rebuild_threshold``. The tiny
entry-point set is re-uploaded on every sync. ``sync_stats`` counts
uploads, rows and bytes moved — the steady-state serve benchmark
(benchmarks/bench_serve.py) asserts sync cost is O(delta) from these.

**Quantized residency (int8 data plane).** With ``emb_dtype="int8"``
(``HNSWParams.emb_dtype`` / the FlatIndex constructor arg) the
device-resident embedding tier is int8 end to end: the host keeps the
fp32 rows as the control plane (graph wiring, exact host search), but
every row is ALSO quantized on write — per-slot symmetric scale,
``q = round(v · 127 / max|v|)`` — and the device tables carry the int8
``emb`` plus a per-slot fp32 ``scale`` table that rides the same
dirty-row delta sync. All three data-plane kernels fuse the dequant into
their dot products (asymmetric scoring: fp32 query, int8 rows, score ×
scale after the dot), so every frontier-hop DMA, delta-sync scatter and
flat-scan tile moves ~1/4 the bytes and a category quota holds ~4x the
entries per HBM byte. fp32 stays the default and the exact baseline.
Quantization can shift a score by ~1e-3, so the cache layer re-scores
borderline results (|score − τ| ≤ margin) from the fp32 embedding stored
next to the document (see core/cache.py re-rank tier) — latency may
change at the boundary; the returned candidate's hit/miss decision does
not (see cache.py for the near-tie scope note).

Callers must treat ``device_tables()`` as the *live* mirror: the returned
buffers are donated to the next delta flush, so do not hold references
to them across index mutations — re-fetch per search (``search_batch``
does).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.frontier_hop import TOMBSTONE

INVALID = -1

# Lookup classification (paper Algorithm 1 lines 12-21), computed ON DEVICE
# inside the jitted search so the cache's Python loop only touches actual
# hits (doc fetch) and expirations (evict):
CLS_MISS, CLS_EXPIRED, CLS_HIT = 0, 1, 2


def _bucket_batch(n: int) -> int:
    """Pad serve batches to the next power of two (min 8 — the fp32
    sublane): engine queue drains produce B = 1..max_batch, and without
    bucketing every distinct B compiles its own program."""
    return max(8, 1 << (max(1, n) - 1).bit_length())


def _pad_query_batch(queries: np.ndarray, thresholds, categories, ttls
                     ) -> tuple[int, int, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray]:
    """Bucket the batch dimension. Padding lanes get τ = -inf, so they
    are born *done*: beyond the one-time entry-set scoring every query
    pays at init, the frozen hop emits INVALID candidates for them — zero
    per-hop gather DMAs, not just zero result updates."""
    q = np.atleast_2d(np.asarray(queries, np.float32))
    B = q.shape[0]
    Bp = _bucket_batch(B)
    qp = np.zeros((Bp, q.shape[1]), np.float32)
    qp[:B] = q
    taup = np.full(Bp, -np.inf, np.float32)
    taup[:B] = np.broadcast_to(np.asarray(thresholds, np.float32), (B,))
    qcp = np.full(Bp, -1, np.int32)
    if categories is not None:
        qcp[:B] = np.broadcast_to(np.asarray(categories, np.int32), (B,))
    tp = np.full(Bp, np.inf, np.float32)
    if ttls is not None:
        tp[:B] = np.broadcast_to(np.asarray(ttls, np.float32), (B,))
    return B, Bp, qp, taup, qcp, tp


def quantize_rows(vecs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric int8 quantization: ``q = round(v / s)`` with
    ``s = max|v| / 127`` — the layout of the quantized resident tier.
    Returns (int8 rows (B, d), fp32 scales (B,)). Zero rows get scale
    eps so the dequant ``q · s`` is exactly zero, never NaN."""
    vecs = np.atleast_2d(np.asarray(vecs, np.float32))
    scale = (np.max(np.abs(vecs), axis=1) / 127.0).astype(np.float32)
    scale = np.maximum(scale, np.float32(1e-12))
    q = np.clip(np.rint(vecs / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def _flush_device_tables(device: dict | None, host: dict[str, np.ndarray],
                         dirty: set, capacity: int, rebuild_threshold: float,
                         row_nbytes: int, emb_row_nbytes: int,
                         sync_stats: dict) -> dict:
    """The delta-sync protocol, shared by FlatIndex and HNSWIndex: apply
    the dirty-row log with donated in-place scatters (O(delta) bytes), or
    re-upload everything on first use / past ``rebuild_threshold``
    (negative = always full, the benchmark contrast).
    ``emb_row_nbytes`` is the embedding payload per row (incl. the quant
    scale word), tracked separately — it is the component the int8 tier
    shrinks ~4x, and what the quant benchmark gates on."""
    if device is None or len(dirty) > rebuild_threshold * capacity:
        device = {k: jnp.asarray(v) for k, v in host.items()}
        sync_stats["full_uploads"] += 1
        sync_stats["rows_synced"] += capacity
        sync_stats["bytes_synced"] += capacity * row_nbytes
        sync_stats["emb_bytes_synced"] += capacity * emb_row_nbytes
    elif dirty:
        rows = np.fromiter(dirty, np.int64, len(dirty))
        rows.sort()
        # Bucket the row count (same power-of-two policy as the batch
        # dimension) so the jit cache holds O(log capacity) entries;
        # padding repeats row 0 of the delta with identical payload — a
        # deterministic no-op.
        bucket = _bucket_batch(len(rows))
        rows = np.concatenate(
            [rows, np.full(bucket - len(rows), rows[0])]).astype(np.int32)
        rows_j = jnp.asarray(rows)
        device = {k: ops.scatter_rows(device[k], rows_j,
                                      jnp.asarray(host[k][rows]))
                  for k in host}
        sync_stats["delta_updates"] += 1
        sync_stats["rows_synced"] += len(rows)
        sync_stats["bytes_synced"] += len(rows) * row_nbytes
        sync_stats["emb_bytes_synced"] += len(rows) * emb_row_nbytes
    return device


def _batched_add(index, vecs: np.ndarray,
                 categories: np.ndarray | None) -> np.ndarray:
    """Shared add_batch body: normalize the batch, loop ``index.add``,
    return the (B,) assigned slot ids."""
    vecs = np.atleast_2d(np.asarray(vecs, np.float32))
    B = vecs.shape[0]
    cats = (np.full(B, -1, np.int32) if categories is None
            else np.broadcast_to(np.asarray(categories, np.int32), (B,)))
    slots = np.empty(B, np.int32)
    for i in range(B):
        slots[i] = index.add(vecs[i], category=int(cats[i]))
    return slots


# ---------------------------------------------------------------------------
# Shared device-residency protocol.
# ---------------------------------------------------------------------------

class DeviceResidentIndex:
    """Device-residency + search-observability protocol shared by
    ``FlatIndex`` and ``HNSWIndex``: the version counter, dirty-row log,
    persistent mirror with delta flush (``_flush_device_tables``), sync
    accounting, the embedding-tier dtype (fp32 / int8 with per-slot
    scales), and the searches/compilations/last-search counters. A
    subclass provides ``_host_tables()``, ``_row_nbytes()``,
    ``_rebuild_threshold()`` and (optionally) ``_finish_sync()`` for
    state that rides along on every sync (the HNSW entry set)."""

    def _init_residency(self, emb_dtype: str = "float32") -> None:
        if emb_dtype not in ("float32", "int8"):
            raise ValueError(f"emb_dtype must be 'float32' or 'int8', "
                             f"got {emb_dtype!r}")
        self.emb_dtype = emb_dtype
        if self.quantized:
            # The quantized resident tier: what the device actually holds
            # and the delta sync actually moves. The fp32 ``emb`` host
            # table remains the control plane (graph wiring, exact host
            # search) and is NEVER uploaded in this mode.
            self.emb_q = np.zeros((self.capacity, self.dim), np.int8)
            self.emb_scale = np.zeros((self.capacity,), np.float32)
        self._version = 0
        self._device: dict | None = None
        self._device_version = -1
        # Delta log: rows whose host tables changed since the last device
        # sync. A set — rows touched repeatedly within one serve step
        # coalesce to one scattered row.
        self._dirty: set[int] = set()
        self.sync_stats = {"full_uploads": 0, "delta_updates": 0,
                           "rows_synced": 0, "bytes_synced": 0,
                           "emb_bytes_synced": 0}
        self.search_stats = {"searches": 0, "compilations": 0}
        self._compiled_keys: set = set()
        self.last_search: dict = {}

    @property
    def quantized(self) -> bool:
        return self.emb_dtype == "int8"

    def emb_row_nbytes(self) -> int:
        """Bytes the resident tier moves per embedding row: the row itself
        plus the fp32 dequant scale when quantized — the unit behind both
        the sync and the gather byte counters (~4x smaller at int8)."""
        return self.dim + 4 if self.quantized else self.dim * 4

    def row_nbytes(self) -> int:
        """Bytes one full synced delta row moves (embedding tier + the
        subclass's graph/flag columns) — the public face of the
        ``_row_nbytes`` hook, for benchmarks and reports."""
        return self._row_nbytes()

    def _emb_tables(self) -> dict[str, np.ndarray]:
        """The embedding tier as host tables: the fp32 rows, or the int8
        rows plus the per-slot scale table (which rides the same
        dirty-row delta sync — a row's scale changes exactly when the
        row does)."""
        if self.quantized:
            return {"emb": self.emb_q, "scale": self.emb_scale}
        return {"emb": self.emb}

    def _quantize_slot(self, slot: int, vec: np.ndarray) -> None:
        """Keep the quantized mirror of one row in lockstep with the fp32
        write (callers already mark the row dirty)."""
        if self.quantized:
            q, s = quantize_rows(vec[None])
            self.emb_q[slot] = q[0]        # mirror-ok
            self.emb_scale[slot] = s[0]    # mirror-ok

    def export_rows(self, slots: np.ndarray) -> dict[str, np.ndarray]:
        """Copy the per-slot tables for ``slots`` out of the index — the
        shard-migration export (core/shard.py): the fp32 control-plane
        rows, the category/inserted metadata, and (under int8 residency)
        the quantized rows + scales exactly as the source device holds
        them. All arrays are copies; exporting does not mutate the index
        or its dirty log, so the source keeps serving during a drain."""
        slots = np.asarray(slots, np.int64)
        out = {"emb": self.emb[slots].copy(),
               "category": self.category[slots].copy(),
               "inserted": self.inserted[slots].copy()}
        if self.quantized:
            out["emb_q"] = self.emb_q[slots].copy()
            out["scale"] = self.emb_scale[slots].copy()
        return out

    # -- subclass hooks --------------------------------------------------------
    def _host_tables(self) -> dict:
        raise NotImplementedError

    def _row_nbytes(self) -> int:
        raise NotImplementedError

    def _rebuild_threshold(self) -> float:
        raise NotImplementedError

    def _finish_sync(self, device: dict) -> None:
        pass

    # -- the protocol ----------------------------------------------------------
    def device_tables(self) -> dict:
        """The persistent device mirror, synced to the host state.

        Protocol: no mutation since last sync → returned as-is. Otherwise
        the dirty-row log is applied with one donated in-place scatter
        (O(delta) bytes); a full O(capacity) upload happens only on first
        use or when the dirty fraction exceeds the rebuild threshold.
        Returned buffers are donated to the NEXT flush — re-fetch after
        any mutation, never cache them caller-side.
        """
        if self._device is not None and self._device_version == self._version:
            return self._device
        try:
            self._device = _flush_device_tables(
                self._device, self._host_tables(), self._dirty, self.capacity,
                self._rebuild_threshold(), self._row_nbytes(),
                self.emb_row_nbytes(), self.sync_stats)
        except BaseException:
            # A flush that dies mid-delta (device OOM, injected fault)
            # may have DONATED some of the old mirror's buffers to
            # scatters that never completed — the old self._device can
            # no longer be trusted. Drop it so the retry rebuilds the
            # mirror from the (authoritative, untouched) host tables
            # with a clean full upload; the dirty log is preserved
            # unconsumed. tests/test_coherence.py injects exactly this
            # and checks the retried flush restores exact table
            # equality.
            self._device = None
            raise
        self._finish_sync(self._device)
        self._dirty.clear()
        self._device_version = self._version
        return self._device

    def _record_search(self, B: int, Bp: int, key_extra: tuple = (),
                       stats: dict | None = None) -> None:
        """Count a device search: ``compilations`` is the number of
        distinct compiled signatures seen (padded batch + impl knobs) —
        the bucketing acceptance counter — and ``last_search`` keeps the
        hops/rows-gathered device scalars without forcing a host sync.
        ``gather_row_nbytes`` is the per-row cost of those gathers (the
        int8 tier cuts it ~4x), so callers can derive bytes gathered per
        query without another device round trip."""
        st = self.search_stats
        st["searches"] += 1
        self._compiled_keys.add((Bp,) + tuple(key_extra))
        st["compilations"] = len(self._compiled_keys)
        if stats is None:   # flat scan: the whole table streams per batch
            self.last_search = {"batch": B, "padded_batch": Bp, "hops": 0,
                                "rows_gathered": np.full(B, self.capacity,
                                                         np.int64)}
        else:
            self.last_search = {"batch": B, "padded_batch": Bp,
                                "hops": stats["hops"],
                                "rows_gathered": stats["rows_gathered"][:B]}
        self.last_search["gather_row_nbytes"] = self.emb_row_nbytes()


# ---------------------------------------------------------------------------
# Flat (brute force) index — exact oracle + small-category fast path.
# ---------------------------------------------------------------------------

class FlatIndex(DeviceResidentIndex):
    """Exact cosine top-1 with threshold. O(n·d) per query batch.

    On TPU this is memory-bound at ~1.9 ms per 1M×384 fp32 scan (819 GB/s),
    which is *itself* within the paper's 2 ms local-search budget — see
    EXPERIMENTS.md. Kernel: ``repro.kernels.flat_topk``.

    Search is category-masked (§5.3): each slot carries an int32 category
    id and each query may carry one; a slot only qualifies as a result for
    queries of the same category (query category < 0 = wildcard), so the
    returned neighbor is the best *same-category* match, not the global
    nearest.
    """

    rebuild_threshold: float = 0.25     # delta-sync protocol (see HNSWParams)

    def __init__(self, dim: int, capacity: int, emb_dtype: str = "float32"):
        self.dim = dim
        self.capacity = capacity
        self.emb = np.zeros((capacity, dim), dtype=np.float32)
        self.valid = np.zeros((capacity,), dtype=bool)
        self.category = np.full((capacity,), -1, dtype=np.int32)
        # Insertion timestamps (the cache's slot_inserted aliases this):
        # a device table like emb/valid/category, so TTL classification
        # runs inside the jitted search (Algorithm 1 line 18 on device).
        self.inserted = np.zeros((capacity,), dtype=np.float32)
        self._n = 0
        self._free: list[int] = []
        self._init_residency(emb_dtype)

    def __len__(self) -> int:
        return int(self.valid.sum())

    def add(self, vec: np.ndarray, category: int = -1) -> int:
        slot = self._free.pop() if self._free else self._n
        if slot >= self.capacity:
            raise RuntimeError("FlatIndex full — evict before inserting")
        if slot == self._n:
            self._n += 1
        self.emb[slot] = vec
        self._quantize_slot(slot, np.asarray(vec, np.float32))
        self.valid[slot] = True
        self.category[slot] = category
        self._dirty.add(int(slot))
        self._version += 1
        return slot

    def add_batch(self, vecs: np.ndarray,
                  categories: np.ndarray | None = None) -> np.ndarray:
        """Multi-insert (same signature as HNSWIndex.add_batch).
        Returns the (B,) assigned slot ids."""
        return _batched_add(self, vecs, categories)

    def remove(self, slot: int) -> None:
        if self.valid[slot]:
            self.valid[slot] = False
            self.category[slot] = -1
            self._free.append(slot)
            self._dirty.add(int(slot))
            self._version += 1

    def search_host(self, queries: np.ndarray, thresholds: np.ndarray,
                    ef: int | None = None, *,
                    categories: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Returns (idx, score) per query; idx = -1 below threshold.

        ``categories`` (B,) int32 restricts each query's result set to its
        own category (< 0 = no restriction).
        """
        queries = np.atleast_2d(queries)
        if self._n == 0:
            B = queries.shape[0]
            return np.full(B, INVALID, np.int32), np.full(B, -np.inf, np.float32)
        sims = queries @ self.emb[:self._n].T                     # (B, n)
        sims = np.where(self.valid[None, :self._n], sims, -np.inf)
        if categories is not None:
            qc = np.asarray(categories, np.int32).reshape(-1, 1)  # (B, 1)
            allowed = (qc < 0) | (self.category[None, :self._n] == qc)
            sims = np.where(allowed, sims, -np.inf)
        idx = np.argmax(sims, axis=1)
        score = sims[np.arange(len(idx)), idx]
        # isfinite guard: with every slot masked out (empty category, all
        # tombstones) argmax lands on an arbitrary -inf slot, and a -inf
        # threshold would otherwise accept it (-inf >= -inf).
        ok = (score >= thresholds) & np.isfinite(score)
        return (np.where(ok, idx, INVALID).astype(np.int32),
                score.astype(np.float32))

    # -- device path (ops.cache_topk over the resident tables) -----------------
    def _row_nbytes(self) -> int:
        """Bytes one synced delta row moves (emb [+ scale] + valid + cat +
        ts + id)."""
        return self.emb_row_nbytes() + 1 + 4 + 4 + 4

    def _host_tables(self) -> dict:
        return {**self._emb_tables(), "valid": self.valid,
                "category": self.category, "inserted": self.inserted}

    def _rebuild_threshold(self) -> float:
        return self.rebuild_threshold

    def search_batch(self, queries: np.ndarray, thresholds: np.ndarray, *,
                     categories: np.ndarray | None = None
                     ) -> tuple[jax.Array, jax.Array]:
        """Batched device search via the ``flat_topk`` kernel
        (``ops.cache_topk``). Returns DEVICE arrays — convert once at the
        cache layer, not per index call."""
        idx, score, _, _ = self.search_classified(queries, thresholds,
                                                  categories=categories)
        return idx, score

    def search_classified(self, queries: np.ndarray, thresholds: np.ndarray,
                          *, categories: np.ndarray | None = None,
                          ttls: np.ndarray | None = None, now: float = 0.0
                          ) -> tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array]:
        """Search + on-device TTL classification in one compiled program.
        Returns device (idx, score, cls, cand) with cls ∈ {CLS_MISS,
        CLS_EXPIRED, CLS_HIT} and ``cand`` the best same-category
        candidate BEFORE thresholding (INVALID only when nothing valid
        matched at all) — the cache's fp32 re-rank tier re-scores it when
        the quantized score lands within the τ-margin band. Batch sizes
        are bucketed to powers of two so B = 1..max_batch share one
        compilation."""
        t = self.device_tables()
        B, Bp, qp, taup, qcp, tp = _pad_query_batch(
            queries, thresholds, categories, ttls)
        idx, score, cls, cand = _flat_search_classified(
            t["emb"], t["valid"], t["category"], t["inserted"],
            jnp.asarray(qp), jnp.asarray(taup), jnp.asarray(qcp),
            jnp.asarray(tp), jnp.float32(now), t.get("scale"))
        self._record_search(B, Bp)
        return idx[:B], score[:B], cls[:B], cand[:B]


# ---------------------------------------------------------------------------
# Device-side batched beam search (jnp reference + fused-kernel dispatch).
# ---------------------------------------------------------------------------

def _classify(idx: jax.Array, score: jax.Array, inserted: jax.Array,
              ttls: jax.Array, now: jax.Array) -> jax.Array:
    """Algorithm 1 lines 12-21 on device: {miss, expired, hit} per query
    from the synced ``inserted`` table and the per-query TTL vector."""
    found = idx != INVALID
    age = now - jnp.take(inserted, jnp.maximum(idx, 0))
    expired = found & (age > ttls)
    return jnp.where(expired, CLS_EXPIRED,
                     jnp.where(found, CLS_HIT, CLS_MISS)).astype(jnp.int8)


@jax.jit
def _flat_search_classified(emb, valid, category, inserted, queries, taus,
                            qcat, ttls, now, scale=None):
    score, idx = ops.cache_topk(emb, valid, queries, category, qcat,
                                scales=scale)
    cand = jnp.where(jnp.isfinite(score), idx, INVALID).astype(jnp.int32)
    ok = (score >= taus) & jnp.isfinite(score)
    idx = jnp.where(ok, idx, INVALID).astype(jnp.int32)
    return idx, score, _classify(idx, score, inserted, ttls, now), cand

@partial(jax.jit, static_argnames=("beam", "max_hops", "hop_impl"))
def beam_search(emb: jax.Array,          # (cap, d) float32 or int8 rows
                neighbors: jax.Array,    # (cap, M0) int32, INVALID padded
                valid: jax.Array,        # (cap,) bool
                entries: jax.Array,      # (E,) int32 entry points
                queries: jax.Array,      # (B, d) float32, L2-normalized
                thresholds: jax.Array,   # (B,) float32 per-query τ (category)
                slot_category: jax.Array | None = None,   # (cap,) int32
                query_category: jax.Array | None = None,  # (B,) int32, -1 = any
                scales: jax.Array | None = None,  # (cap,) f32 — emb is int8
                *, beam: int = 32, max_hops: int = 12,
                hop_impl: str = "reference"):
    """Batched fixed-width beam search with per-query threshold early exit.

    Returns (best_idx (B,), best_score (B,), stats) with stats =
    ``{"hops": (), "rows_gathered": (B,), "cand": (B,)}``. best_idx is -1
    where no valid node reached the query's threshold (a cache miss —
    paper Algorithm 1 line 12-14: return immediately, no external access);
    ``stats["cand"]`` keeps the best same-category candidate regardless of
    τ, which the cache's fp32 re-rank tier re-scores for borderline
    queries on the quantized path.

    With ``scales`` (cap,) fp32 the embedding rows are int8 (per-slot
    symmetric quant) and every scoring site — entry-set init, jnp
    reference hop, fused kernel hop — dequantizes inside its dot product
    (asymmetric: fp32 query against int8 rows).

    Tombstoned (invalid) nodes still route traffic (DiskANN-style) but are
    excluded from results. Cross-category nodes get the same treatment
    (§5.3): when ``slot_category``/``query_category`` are given, a node only
    qualifies as a result for queries of its own category (query category
    < 0 = wildcard) — routing stays category-blind so dense regions still
    carry traffic toward sparse ones. Both masks travel as ONE packed
    per-slot ``meta`` word (category, or -2 for tombstones).

    ``hop_impl`` selects the expansion data plane:

    * ``"reference"`` — pure-jnp gathers (the portable CPU oracle);
    * ``"fused"`` — ``ops.frontier_hop``: on compiled backends one Pallas
      kernel per hop fetches the neighbor rows off the level-0 table from
      the scalar-prefetched frontier ids, DMAs the candidate embeddings
      and emits masked scores — no XLA-materialized (B, F·M, d) gather
      ever exists. On CPU it falls back to the jnp reference.
    * ``"fused_pallas"`` — force the kernel (interpret-mode on CPU; the
      parity tests' path).

    DONE-QUERY FREEZE: a query that reached its τ (or a routing fixpoint)
    stops *issuing gathers* — the hop clamps its candidate ids to INVALID
    — instead of merely not updating its best. ``rows_gathered`` counts
    the per-query embedding rows actually fetched (init + hops), the
    deterministic counter the lookup benchmark gates on.
    """
    B = queries.shape[0]
    E = entries.shape[0]
    cap = emb.shape[0]
    # Lane-align d once, outside the hop loop (the kernels require
    # multiples of 128; a no-op for the native 384).
    pad = (-queries.shape[1]) % 128
    if pad:
        emb = jnp.pad(emb, ((0, 0), (0, pad)))
        queries = jnp.pad(queries, ((0, 0), (0, pad)))
    qcat = (jnp.full((B,), -1, jnp.int32) if query_category is None
            else query_category.astype(jnp.int32))
    scat = (jnp.full((cap,), -1, jnp.int32) if slot_category is None
            else slot_category.astype(jnp.int32))
    meta = jnp.where(valid, scat, TOMBSTONE).astype(jnp.int32)
    fused = hop_impl in ("fused", "fused_pallas")
    kernel_impl = "pallas" if hop_impl == "fused_pallas" else None

    def score_nodes(idx):  # idx (B, K) -> cosine scores (B, K)
        safe = jnp.maximum(idx, 0)
        vecs = jnp.take(emb, safe, axis=0).astype(jnp.float32)     # (B,K,d)
        s = jnp.einsum("bkd,bd->bk", vecs, queries)
        if scales is not None:      # fused per-row dequant (int8 rows)
            s = s * jnp.take(scales, safe, axis=0)
        return jnp.where(idx == INVALID, -jnp.inf, s)

    def res_mask(idx, scores):  # -inf at non-results (tombstone/category)
        m = jnp.take(meta, jnp.maximum(idx, 0))
        ok = (idx != INVALID) & (m != TOMBSTONE) & \
            ((qcat[:, None] < 0) | (m == qcat[:, None]))
        return jnp.where(ok, scores, -jnp.inf)

    def expand(f_idx, done):
        """One hop: (B, F) frontier -> (B, F·M) candidate (ids, routing
        scores, result scores). Done queries emit INVALID / -inf lanes."""
        if fused:
            return ops.frontier_hop(emb, neighbors, meta, f_idx, queries,
                                    qcat, done.astype(jnp.int32), scales,
                                    impl=kernel_impl)
        nbr = jnp.take(neighbors, jnp.maximum(f_idx, 0), axis=0)
        dead = (f_idx == INVALID)[:, :, None] | done[:, None, None]
        cand = jnp.where(dead, INVALID, nbr).reshape(B, -1)
        route = score_nodes(cand)
        return cand, route, res_mask(cand, route)

    # Initial frontier: entry points (same for all queries), padded to beam.
    if E >= beam:
        f0 = entries.astype(jnp.int32)[:beam]
    else:
        f0 = jnp.concatenate([entries.astype(jnp.int32),
                              jnp.full((beam - E,), INVALID, jnp.int32)])
    f_idx = jnp.broadcast_to(f0[None, :], (B, beam))
    f_score = (ops.hop_scores(emb, f_idx, queries, scales=scales) if fused
               else score_nodes(f_idx))
    f_res = res_mask(f_idx, f_score)
    rows = jnp.sum(f_idx != INVALID, axis=1).astype(jnp.int32)

    best_score = jnp.max(f_res, axis=1)
    best_idx = jnp.take_along_axis(
        f_idx, jnp.argmax(f_res, axis=1)[:, None], axis=1)[:, 0]
    best_idx = jnp.where(jnp.isfinite(best_score), best_idx, INVALID)

    def cond(state):
        hop, _f, _s, _r, _bs, _bi, done, _rows = state
        return (hop < max_hops) & ~jnp.all(done)

    def body(state):
        hop, f_idx, f_score, f_res, best_s, best_i, done, rows = state
        # Expand: one fused hop. Done queries' lanes come back INVALID, so
        # they issue no gather DMAs and cannot re-enter the merge.
        cand, c_route, c_res = expand(f_idx, done)
        rows = rows + jnp.sum(cand != INVALID, axis=1).astype(jnp.int32)

        # Merge frontier ∪ candidates, keep top-beam by raw routing score;
        # the result-masked scores ride along through the same top-k
        # positions (no per-hop validity/category gathers needed).
        all_idx = jnp.concatenate([f_idx, cand], axis=1)
        all_route = jnp.concatenate([f_score, c_route], axis=1)
        all_res = jnp.concatenate([f_res, c_res], axis=1)
        top_s, top_pos = jax.lax.top_k(all_route, beam)
        top_i = jnp.take_along_axis(all_idx, top_pos, axis=1)
        top_r = jnp.take_along_axis(all_res, top_pos, axis=1)

        # Result tracking only over valid (non-tombstoned) same-category
        # nodes — exactly the lanes top_r left finite.
        hop_best_s = jnp.max(top_r, axis=1)
        hop_best_i = jnp.take_along_axis(
            top_i, jnp.argmax(top_r, axis=1)[:, None], axis=1)[:, 0]
        improved = hop_best_s > best_s + 1e-9
        new_best_s = jnp.where(improved, hop_best_s, best_s)
        new_best_i = jnp.where(improved, hop_best_i, best_i)

        # Early exit (paper §5.3): per-query done once τ reached; also stop
        # queries whose frontier reached a fixpoint (the merge returned the
        # previous frontier unchanged — no new candidates route anywhere).
        # Convergence is judged at the ROUTING level, not on the masked
        # best: under category masking the result may stall for hops while
        # the beam traverses a cross-category region.
        converged = jnp.all(top_i == f_idx, axis=1)
        frozen = done[:, None]
        top_i = jnp.where(frozen, f_idx, top_i)
        top_s = jnp.where(frozen, f_score, top_s)
        top_r = jnp.where(frozen, f_res, top_r)
        new_done = done | (new_best_s >= thresholds) | converged
        return (hop + 1, top_i, top_s, top_r, new_best_s, new_best_i,
                new_done, rows)

    done0 = best_score >= thresholds
    state = (jnp.asarray(0), f_idx, f_score, f_res, best_score, best_idx,
             done0, rows)
    hops, _, _, _, best_score, best_idx, _, rows = jax.lax.while_loop(
        cond, body, state)

    hit = best_score >= thresholds
    return (jnp.where(hit, best_idx, INVALID), best_score,
            {"hops": hops, "rows_gathered": rows, "cand": best_idx})


@partial(jax.jit, static_argnames=("beam", "max_hops", "hop_impl"))
def beam_search_classified(emb, neighbors, valid, entries, inserted,
                           queries, thresholds, ttls, now,
                           slot_category=None, query_category=None,
                           scales=None, *,
                           beam: int = 32, max_hops: int = 12,
                           hop_impl: str = "reference"):
    """Algorithm 1 lines 9-21 as ONE compiled program: masked beam search
    plus on-device TTL classification against the synced ``inserted``
    table. Returns (idx, score, cls, stats); the cache's Python loop then
    touches only actual hits and expirations."""
    idx, score, stats = beam_search(
        emb, neighbors, valid, entries, queries, thresholds,
        slot_category, query_category, scales,
        beam=beam, max_hops=max_hops, hop_impl=hop_impl)
    return idx, score, _classify(idx, score, inserted, ttls, now), stats


# ---------------------------------------------------------------------------
# HNSW proper.
# ---------------------------------------------------------------------------

@dataclass
class HNSWParams:
    M: int = 16                 # neighbors per node, upper levels
    M0: int = 32                # neighbors per node, level 0
    ef_construction: int = 64
    ef_search: int = 48         # host-search beam
    beam: int = 32              # device-search beam width F
    max_hops: int = 12          # device-search hop cap
    n_entries: int = 8          # device-search entry set size E
    # Delta-sync protocol: apply dirty rows in place until their fraction
    # of capacity exceeds this, then re-upload the full tables (a graph
    # that churned that much is cheaper to rebuild than to scatter).
    # Negative forces a full upload on every sync (the pre-delta behavior,
    # kept as the O(capacity) contrast for benchmarks).
    rebuild_threshold: float = 0.25
    # Hop data plane: None = auto (the fused frontier-hop kernel on
    # compiled backends, the jnp reference on CPU); "reference" | "fused"
    # | "fused_pallas" force a path (see beam_search).
    hop_impl: str | None = None
    # Device-resident embedding dtype: "float32" (exact baseline) or
    # "int8" (per-slot symmetric scales; every kernel fuses the dequant —
    # ~4x fewer bytes per sync scatter and per gather DMA, ~4x more
    # entries per quota byte). The host keeps fp32 as the control plane.
    emb_dtype: str = "float32"


class HNSWIndex(DeviceResidentIndex):
    """Hierarchical build on host; batched beam search on device.

    Fixed ``capacity``; slots are recycled through a freelist on removal
    (cache eviction). The device tables are persistent: mutations log
    their touched rows in the ``DeviceResidentIndex`` dirty set and
    ``device_tables()`` flushes the log with an in-place scatter (see
    module docstring — sync cost is O(delta), not O(capacity)).
    """

    def __init__(self, dim: int, capacity: int, params: HNSWParams | None = None,
                 seed: int = 0):
        self.dim = dim
        self.capacity = capacity
        self.p = params or HNSWParams()
        self.rng = np.random.default_rng(seed)
        self.ml = 1.0 / math.log(self.p.M)

        self.emb = np.zeros((capacity, dim), dtype=np.float32)
        self.valid = np.zeros((capacity,), dtype=bool)
        self.category = np.full((capacity,), -1, dtype=np.int32)
        # Insertion timestamps (the cache's slot_inserted aliases this) —
        # a device table like the others, riding the same dirty-row delta
        # sync, so TTL classification happens inside the jitted search.
        self.inserted = np.zeros((capacity,), dtype=np.float32)
        self.level = np.full((capacity,), -1, dtype=np.int8)
        # neighbors[0] is the device-visible level-0 graph.
        self.neighbors: list[np.ndarray] = [
            np.full((capacity, self.p.M0), INVALID, dtype=np.int32)
        ]
        self.entry_point: int = INVALID
        self.max_level: int = -1
        self._n = 0
        self._free: list[int] = []
        self._entries_cache: np.ndarray | None = None
        self._entries_version = -1
        self._init_residency(self.p.emb_dtype)

    # -- basic bookkeeping ---------------------------------------------------
    def __len__(self) -> int:
        return int(self.valid.sum())

    def _alloc_slot(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n >= self.capacity:
            raise RuntimeError("HNSWIndex full — evict before inserting")
        slot = self._n
        self._n += 1
        return slot

    def _ensure_level_arrays(self, level: int) -> None:
        while len(self.neighbors) <= level:
            self.neighbors.append(
                np.full((self.capacity, self.p.M), INVALID, dtype=np.int32))

    def _draw_level(self) -> int:
        return int(-math.log(max(self.rng.random(), 1e-12)) * self.ml)

    # -- host greedy search helpers -------------------------------------------
    def _greedy_descend(self, q: np.ndarray, entry: int, level: int) -> int:
        """Greedy 1-best descent at one level (used above the target level)."""
        cur = entry
        cur_sim = float(q @ self.emb[cur])
        improved = True
        nbrs = self.neighbors[level]
        while improved:
            improved = False
            nb = nbrs[cur]
            nb = nb[nb != INVALID]
            if nb.size == 0:
                break
            sims = self.emb[nb] @ q
            j = int(np.argmax(sims))
            if sims[j] > cur_sim:
                cur_sim = float(sims[j])
                cur = int(nb[j])
                improved = True
        return cur

    def _search_level(self, q: np.ndarray, entries: list[int], level: int,
                      ef: int) -> tuple[np.ndarray, np.ndarray]:
        """Best-first search at one level. Returns (ids, sims) sorted desc."""
        nbrs = self.neighbors[level]
        visited = set(entries)
        cand_ids = list(entries)
        cand_sims = list(self.emb[entries] @ q)
        # results kept as parallel arrays, pruned to ef
        res_ids = list(cand_ids)
        res_sims = list(cand_sims)
        while cand_ids:
            j = int(np.argmax(cand_sims))
            c_sim = cand_sims.pop(j)
            c = cand_ids.pop(j)
            worst = min(res_sims) if len(res_sims) >= ef else -np.inf
            if c_sim < worst:
                break
            nb = nbrs[c]
            nb = nb[nb != INVALID]
            nb = [int(x) for x in nb if int(x) not in visited]
            if not nb:
                continue
            visited.update(nb)
            sims = self.emb[nb] @ q
            for node, s in zip(nb, sims):
                if len(res_sims) < ef or s > min(res_sims):
                    res_ids.append(node)
                    res_sims.append(float(s))
                    cand_ids.append(node)
                    cand_sims.append(float(s))
                    if len(res_sims) > ef:
                        k = int(np.argmin(res_sims))
                        res_ids.pop(k)
                        res_sims.pop(k)
        order = np.argsort(res_sims)[::-1]
        return (np.asarray(res_ids, np.int32)[order],
                np.asarray(res_sims, np.float32)[order])

    # -- insertion -------------------------------------------------------------
    def add(self, vec: np.ndarray, category: int = -1) -> int:
        vec = np.asarray(vec, np.float32)
        slot = self._alloc_slot()
        self.emb[slot] = vec
        self._quantize_slot(slot, vec)
        self.valid[slot] = True
        self.category[slot] = category
        lvl = min(self._draw_level(), 8)
        self.level[slot] = lvl
        self._ensure_level_arrays(lvl)
        for l in range(len(self.neighbors)):
            self.neighbors[l][slot] = INVALID
        self._dirty.add(slot)

        if self.entry_point == INVALID:
            self.entry_point = slot
            self.max_level = lvl
            self._version += 1
            return slot

        cur = self.entry_point
        for l in range(self.max_level, lvl, -1):
            cur = self._greedy_descend(vec, cur, l)
        entries = [cur]
        for l in range(min(lvl, self.max_level), -1, -1):
            ids, _sims = self._search_level(vec, entries, l, self.p.ef_construction)
            m = self.p.M0 if l == 0 else self.p.M
            chosen = ids[:m]
            self.neighbors[l][slot, :len(chosen)] = chosen
            # bidirectional wiring with pruning to closest-m
            for nb in chosen:
                row = self.neighbors[l][nb]
                empty = np.where(row == INVALID)[0]
                if empty.size:
                    row[empty[0]] = slot
                else:
                    cand = np.concatenate([row, [slot]])
                    sims = self.emb[cand] @ self.emb[nb]
                    keep = cand[np.argsort(sims)[::-1][:m]]
                    self.neighbors[l][nb] = keep
            if l == 0:     # only the level-0 graph is device-visible
                self._dirty.update(int(nb) for nb in chosen)
            entries = list(ids[:1]) if len(ids) else entries

        if lvl > self.max_level:
            self.max_level = lvl
            self.entry_point = slot
        self._version += 1
        return slot

    def add_batch(self, vecs: np.ndarray,
                  categories: np.ndarray | None = None) -> np.ndarray:
        """Insert a batch of vectors. Returns the (B,) assigned slot ids.

        Graph wiring stays host-sequential (HNSW insertion is inherently
        so), but the whole batch's touched rows coalesce in the delta log,
        so the device pays ONE scatter flush on the next search instead of
        B full-table uploads.
        """
        return _batched_add(self, vecs, categories)

    def remove(self, slot: int) -> None:
        """Tombstone: stays routable until slot reuse, excluded from results."""
        if not self.valid[slot]:
            return
        self.valid[slot] = False
        self.category[slot] = -1
        self._free.append(slot)
        self._dirty.add(int(slot))
        if slot == self.entry_point:
            alive = np.where(self.valid)[0]
            if alive.size:
                lv = self.level[alive]
                best = alive[int(np.argmax(lv))]
                self.entry_point = int(best)
                self.max_level = int(self.level[best])
            else:
                self.entry_point = INVALID
                self.max_level = -1
        self._version += 1

    # -- host search (exact hierarchical; CPU latency benchmarks) --------------
    def search_host(self, queries: np.ndarray, thresholds: np.ndarray,
                    ef: int | None = None, *,
                    categories: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-query best match above threshold; -1 on miss.

        ``categories`` (B,) int32 masks result tracking by category (< 0 =
        wildcard): traversal stays category-blind — cross-category nodes
        route traffic exactly like tombstones do — but only same-category
        nodes can be returned, so a globally-nearer cross-category neighbor
        no longer shadows a valid same-category match (§5.3).
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        thresholds = np.broadcast_to(np.asarray(thresholds, np.float32),
                                     (queries.shape[0],))
        if categories is not None:
            categories = np.broadcast_to(
                np.asarray(categories, np.int32), (queries.shape[0],))
        ef = ef or self.p.ef_search
        out_idx = np.full(queries.shape[0], INVALID, np.int32)
        out_sim = np.full(queries.shape[0], -np.inf, np.float32)
        if self.entry_point == INVALID:
            return out_idx, out_sim
        for i, q in enumerate(queries):
            entries = [self.entry_point]
            for l in range(self.max_level, 0, -1):
                # small-beam descent (more robust than 1-greedy on the
                # bulk-built pivot graphs; negligible cost on upper levels)
                ids_l, _ = self._search_level(q, entries, l, ef=16)
                entries = [int(x) for x in ids_l[:8]] or entries
            ids, sims = self._search_level(q, entries, 0, ef)
            ok = self.valid[ids]
            if categories is not None and categories[i] >= 0:
                ok &= self.category[ids] == categories[i]
            ids, sims = ids[ok], sims[ok]
            if len(ids) and sims[0] >= thresholds[i]:
                out_idx[i] = ids[0]
                out_sim[i] = sims[0]
            elif len(ids):
                out_sim[i] = sims[0]
        return out_idx, out_sim

    # -- device search ----------------------------------------------------------
    def entry_set(self) -> np.ndarray:
        """Multi-entry start set: entry point + highest-level live nodes.

        Cached on ``_version``: a delta flush re-derives this at most once
        per mutation batch, and selection is O(n) ``argpartition`` (top-E
        by level, order within the set is irrelevant to the beam), not a
        full argsort of all live nodes.
        """
        if self._entries_version == self._version and \
                self._entries_cache is not None:
            return self._entries_cache
        E = self.p.n_entries
        ents = np.full((E,), INVALID, np.int32)
        if self.entry_point != INVALID:
            alive = np.where(self.valid)[0]
            if alive.size > E:
                top = np.argpartition(self.level[alive], alive.size - E)[-E:]
                chosen = alive[top].astype(np.int32)
            else:
                chosen = alive.astype(np.int32)
            ents[:len(chosen)] = chosen
            if self.entry_point not in chosen:
                ents[0] = self.entry_point
        self._entries_cache = ents
        self._entries_version = self._version
        return ents

    def _row_nbytes(self) -> int:
        """Bytes one synced delta row moves (emb [+ scale] + nbrs + valid
        + cat + inserted-timestamp + id)."""
        return (self.emb_row_nbytes()
                + self.neighbors[0].itemsize * self.p.M0
                + self.valid.itemsize + self.category.itemsize
                + self.inserted.itemsize + 4)

    def _host_tables(self) -> dict:
        return {**self._emb_tables(), "neighbors": self.neighbors[0],
                "valid": self.valid, "category": self.category,
                "inserted": self.inserted}

    def _rebuild_threshold(self) -> float:
        return self.p.rebuild_threshold

    def _finish_sync(self, device: dict) -> None:
        # The tiny entry set (E ints) rides along on every sync.
        entries = self.entry_set()
        device["entries"] = jnp.asarray(entries)
        self.sync_stats["bytes_synced"] += entries.nbytes

    def _resolve_hop_impl(self) -> str:
        impl = self.p.hop_impl
        if impl is None:
            impl = "reference" if jax.default_backend() == "cpu" else "fused"
        return impl

    def search_batch(self, queries: np.ndarray, thresholds: np.ndarray, *,
                     categories: np.ndarray | None = None
                     ) -> tuple[jax.Array, jax.Array]:
        """Batched device beam search over the resident tables.

        ``categories`` (B,) int32 per-query category mask (< 0 = wildcard);
        None searches category-blind. The batch dimension is bucketed to
        the next power of two so engine queue drains (B = 1..max_batch)
        share one compiled program, and the returned (idx, score) are
        DEVICE arrays — callers that branch on them convert ONCE at their
        layer instead of this method forcing a blocking host sync on both
        outputs. Per-search hops/rows-gathered stats (device scalars, no
        sync) land in ``self.last_search``.
        """
        t = self.device_tables()
        B, Bp, qp, taup, qcp, _ = _pad_query_batch(
            queries, thresholds, categories, None)
        impl = self._resolve_hop_impl()
        idx, score, stats = beam_search(
            t["emb"], t["neighbors"], t["valid"], t["entries"],
            jnp.asarray(qp), jnp.asarray(taup), t["category"],
            jnp.asarray(qcp), t.get("scale"), beam=self.p.beam,
            max_hops=self.p.max_hops, hop_impl=impl)
        self._record_search(B, Bp,
                            ("beam", self.p.beam, self.p.max_hops, impl),
                            stats)
        return idx[:B], score[:B]

    def search_classified(self, queries: np.ndarray, thresholds: np.ndarray,
                          *, categories: np.ndarray | None = None,
                          ttls: np.ndarray | None = None, now: float = 0.0
                          ) -> tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array]:
        """Beam search + on-device TTL classification in one compiled
        program (Algorithm 1 lines 9-21): returns device (idx, score, cls,
        cand) with cls ∈ {CLS_MISS, CLS_EXPIRED, CLS_HIT}, judged against
        the synced ``inserted`` table, per-query ``ttls`` and ``now``;
        ``cand`` is the best same-category candidate BEFORE the τ test
        (the cache's fp32 re-rank tier re-scores it at the boundary on
        the quantized path)."""
        t = self.device_tables()
        B, Bp, qp, taup, qcp, tp = _pad_query_batch(
            queries, thresholds, categories, ttls)
        impl = self._resolve_hop_impl()
        idx, score, cls, stats = beam_search_classified(
            t["emb"], t["neighbors"], t["valid"], t["entries"],
            t["inserted"], jnp.asarray(qp), jnp.asarray(taup),
            jnp.asarray(tp), jnp.float32(now), t["category"],
            jnp.asarray(qcp), t.get("scale"), beam=self.p.beam,
            max_hops=self.p.max_hops, hop_impl=impl)
        self._record_search(B, Bp,
                            ("classified", self.p.beam, self.p.max_hops,
                             impl), stats)
        return idx[:B], score[:B], cls[:B], stats["cand"][:B]

    # -- bulk build (benchmarks) -------------------------------------------------
    @classmethod
    def bulk_build(cls, vecs: np.ndarray, capacity: int | None = None,
                   params: HNSWParams | None = None, seed: int = 0,
                   categories: np.ndarray | None = None) -> "HNSWIndex":
        """Pivot-clustered approximate build: O(n·√n·d), for large benchmark
        indexes where incremental insertion would dominate runtime.

        ``categories`` (n,) int32 assigns per-slot categories (the masked
        search input, §5.3); omitted → -1 (matched only by wildcard
        queries, i.e. category-blind search still works)."""
        n, dim = vecs.shape
        capacity = capacity or int(n * 1.25) + 8
        idx = cls(dim, capacity, params, seed)
        if categories is not None:
            idx.category[:n] = np.asarray(categories, np.int32)
        p = idx.p
        n_piv = max(1, int(math.sqrt(n) * 2))
        rng = np.random.default_rng(seed)
        piv = rng.choice(n, size=min(n_piv, n), replace=False)
        pivots = vecs[piv]
        sims_pv = vecs @ pivots.T                               # (n, P)
        assign = np.argmax(sims_pv, axis=1)
        # overlap: second-best pivot too, for boundary connectivity
        assign2 = np.argsort(-sims_pv, axis=1)[:, 1] if pivots.shape[0] > 1 \
            else assign
        idx.emb[:n] = vecs
        if idx.quantized:
            idx.emb_q[:n], idx.emb_scale[:n] = quantize_rows(vecs)
        idx.valid[:n] = True
        idx.level[:n] = 0
        idx._n = n
        piv_nodes = piv.astype(np.int64)      # pivots ARE real points
        for c in range(pivots.shape[0]):
            members = np.where((assign == c) | (assign2 == c))[0]
            if members.size <= 1:
                continue
            sims = vecs[members] @ vecs[members].T
            np.fill_diagonal(sims, -np.inf)
            k = min(p.M0 - 2, members.size - 1)   # leave room for hub edges
            nn = np.argpartition(-sims, k - 1, axis=1)[:, :k]
            idx.neighbors[0][members[:, None].repeat(k, 1),
                             np.arange(k)[None, :]] = members[nn]
            # hub edges: every member ↔ its pivot keeps the graph connected
            idx.neighbors[0][members, p.M0 - 1] = piv_nodes[c]
        # pivot-to-pivot kNN edges (level 0 + level 1) bridge clusters
        psims = pivots @ pivots.T
        np.fill_diagonal(psims, -np.inf)
        kp = min(p.M, piv_nodes.size - 1)
        idx._ensure_level_arrays(1)
        idx.level[piv_nodes] = 1
        if kp > 0:
            pnn = np.argpartition(-psims, kp - 1, axis=1)[:, :kp]
            for j, node in enumerate(piv_nodes):
                idx.neighbors[1][node, :kp] = piv_nodes[pnn[j]]
                idx.neighbors[0][node, p.M0 - kp - 1:p.M0 - 1] = \
                    piv_nodes[pnn[j][:kp]]
        idx.entry_point = int(piv_nodes[0])
        idx.max_level = 1
        # Every row was written above; log them all dirty. The first sync
        # is a full upload anyway (no device mirror exists yet), but a
        # build into a PRE-SYNCED index must not skip the delta log.
        idx._dirty.update(range(n))
        idx._version += 1
        return idx
