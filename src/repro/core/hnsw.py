"""TPU-adapted HNSW index (paper §5, §5.3, §7.4).

The paper's hot loop is CPU HNSW: pointer-chasing greedy traversal with
per-category thresholds applied *during* traversal and early exit on the
first match above threshold. A literal port is hostile to TPU, so the
device-side search is re-blocked for the MXU (see DESIGN.md §3):

* **Host control plane** (this module, numpy): hierarchical HNSW insertion,
  level assignment, neighbor wiring, tombstoning, entry-point maintenance.
  Also an exact hierarchical search used for CPU latency benchmarks.
* **Device data plane** (JAX): *batched fixed-width beam search* over the
  level-0 graph from a multi-entry start set. One hop = gather (B,F,M)
  neighbor ids → gather embeddings → one (B, F·M, d)×(B, d) contraction on
  the MXU → top-F merge. Early exit is the `while_loop` predicate
  ``best_score ≥ τ_q`` with a per-query threshold vector — the paper's
  threshold-during-traversal, vectorized. The gather+score primitive has a
  Pallas kernel (``repro.kernels.gather_scores``); the pure-jnp path here is
  the portable reference used on CPU.

Capacity is fixed at construction: tables are preallocated so the jitted
search never recompiles as the cache fills.

**Device residency (delta synchronization).** The device tables are
persistent, not a lazily re-uploaded mirror: every host-side mutation
(insert, evict/tombstone, level-0 neighbor rewire) records its touched
rows in a compact dirty-row log, and ``device_tables()`` applies the log
with donated in-place row scatters (``repro.kernels.ops.scatter_rows``:
the Pallas ``scatter_update`` kernel for the lane-aligned embedding
table, XLA scatter for the narrow/flag tables) instead of
re-materializing the full O(capacity·d) tables. A full upload happens only on first use and when
the dirty fraction exceeds ``HNSWParams.rebuild_threshold``. The tiny
entry-point set is re-uploaded on every sync. ``sync_stats`` counts
uploads, rows and bytes moved — the steady-state serve benchmark
(benchmarks/bench_serve.py) asserts sync cost is O(delta) from these.

Callers must treat ``device_tables()`` as the *live* mirror: the returned
buffers are donated to the next delta flush, so do not hold references
to them across index mutations — re-fetch per search (``search_batch``
does).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

INVALID = -1


def _batched_add(index, vecs: np.ndarray,
                 categories: np.ndarray | None) -> np.ndarray:
    """Shared add_batch body: normalize the batch, loop ``index.add``,
    return the (B,) assigned slot ids."""
    vecs = np.atleast_2d(np.asarray(vecs, np.float32))
    B = vecs.shape[0]
    cats = (np.full(B, -1, np.int32) if categories is None
            else np.broadcast_to(np.asarray(categories, np.int32), (B,)))
    slots = np.empty(B, np.int32)
    for i in range(B):
        slots[i] = index.add(vecs[i], category=int(cats[i]))
    return slots


# ---------------------------------------------------------------------------
# Flat (brute force) index — exact oracle + small-category fast path.
# ---------------------------------------------------------------------------

class FlatIndex:
    """Exact cosine top-1 with threshold. O(n·d) per query batch.

    On TPU this is memory-bound at ~1.9 ms per 1M×384 fp32 scan (819 GB/s),
    which is *itself* within the paper's 2 ms local-search budget — see
    EXPERIMENTS.md. Kernel: ``repro.kernels.flat_topk``.

    Search is category-masked (§5.3): each slot carries an int32 category
    id and each query may carry one; a slot only qualifies as a result for
    queries of the same category (query category < 0 = wildcard), so the
    returned neighbor is the best *same-category* match, not the global
    nearest.
    """

    def __init__(self, dim: int, capacity: int):
        self.dim = dim
        self.capacity = capacity
        self.emb = np.zeros((capacity, dim), dtype=np.float32)
        self.valid = np.zeros((capacity,), dtype=bool)
        self.category = np.full((capacity,), -1, dtype=np.int32)
        self._n = 0
        self._free: list[int] = []

    def __len__(self) -> int:
        return int(self.valid.sum())

    def add(self, vec: np.ndarray, category: int = -1) -> int:
        slot = self._free.pop() if self._free else self._n
        if slot >= self.capacity:
            raise RuntimeError("FlatIndex full — evict before inserting")
        if slot == self._n:
            self._n += 1
        self.emb[slot] = vec
        self.valid[slot] = True
        self.category[slot] = category
        return slot

    def add_batch(self, vecs: np.ndarray,
                  categories: np.ndarray | None = None) -> np.ndarray:
        """Multi-insert (same signature as HNSWIndex.add_batch).
        Returns the (B,) assigned slot ids."""
        return _batched_add(self, vecs, categories)

    def remove(self, slot: int) -> None:
        if self.valid[slot]:
            self.valid[slot] = False
            self.category[slot] = -1
            self._free.append(slot)

    def search_host(self, queries: np.ndarray, thresholds: np.ndarray,
                    ef: int | None = None, *,
                    categories: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Returns (idx, score) per query; idx = -1 below threshold.

        ``categories`` (B,) int32 restricts each query's result set to its
        own category (< 0 = no restriction).
        """
        queries = np.atleast_2d(queries)
        if self._n == 0:
            B = queries.shape[0]
            return np.full(B, INVALID, np.int32), np.full(B, -np.inf, np.float32)
        sims = queries @ self.emb[:self._n].T                     # (B, n)
        sims = np.where(self.valid[None, :self._n], sims, -np.inf)
        if categories is not None:
            qc = np.asarray(categories, np.int32).reshape(-1, 1)  # (B, 1)
            allowed = (qc < 0) | (self.category[None, :self._n] == qc)
            sims = np.where(allowed, sims, -np.inf)
        idx = np.argmax(sims, axis=1)
        score = sims[np.arange(len(idx)), idx]
        # isfinite guard: with every slot masked out (empty category, all
        # tombstones) argmax lands on an arbitrary -inf slot, and a -inf
        # threshold would otherwise accept it (-inf >= -inf).
        ok = (score >= thresholds) & np.isfinite(score)
        return (np.where(ok, idx, INVALID).astype(np.int32),
                score.astype(np.float32))


# ---------------------------------------------------------------------------
# Device-side batched beam search (pure-jnp reference implementation).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("beam", "max_hops"))
def beam_search(emb: jax.Array,          # (cap, d) float32, rows L2-normalized
                neighbors: jax.Array,    # (cap, M0) int32, INVALID padded
                valid: jax.Array,        # (cap,) bool
                entries: jax.Array,      # (E,) int32 entry points
                queries: jax.Array,      # (B, d) float32, L2-normalized
                thresholds: jax.Array,   # (B,) float32 per-query τ (category)
                slot_category: jax.Array | None = None,   # (cap,) int32
                query_category: jax.Array | None = None,  # (B,) int32, -1 = any
                *, beam: int = 32, max_hops: int = 12):
    """Batched fixed-width beam search with per-query threshold early exit.

    Returns (best_idx (B,), best_score (B,), hops_used ()). best_idx is -1
    where no valid node reached the query's threshold (a cache miss —
    paper Algorithm 1 line 12-14: return immediately, no external access).

    Tombstoned (invalid) nodes still route traffic (DiskANN-style) but are
    excluded from results. Cross-category nodes get the same treatment
    (§5.3): when ``slot_category``/``query_category`` are given, a node only
    qualifies as a result for queries of its own category (query category
    < 0 = wildcard) — routing stays category-blind so dense regions still
    carry traffic toward sparse ones.
    """
    B = queries.shape[0]
    E = entries.shape[0]

    def score_nodes(idx):  # idx (B, K) -> cosine scores (B, K)
        vecs = jnp.take(emb, jnp.maximum(idx, 0), axis=0)          # (B,K,d)
        s = jnp.einsum("bkd,bd->bk", vecs, queries)
        return jnp.where(idx == INVALID, -jnp.inf, s)

    def result_ok(idx):  # idx (B, K) -> bool: may this node be a result?
        ok = jnp.take(valid, jnp.maximum(idx, 0)) & (idx != INVALID)
        if slot_category is not None and query_category is not None:
            cat = jnp.take(slot_category, jnp.maximum(idx, 0))
            ok &= (query_category[:, None] < 0) | \
                  (cat == query_category[:, None])
        return ok

    # Initial frontier: entry points (same for all queries), padded to beam.
    if E >= beam:
        f0 = entries.astype(jnp.int32)[:beam]
    else:
        f0 = jnp.concatenate([entries.astype(jnp.int32),
                              jnp.full((beam - E,), INVALID, jnp.int32)])
    f_idx = jnp.broadcast_to(f0[None, :], (B, beam))
    f_score = score_nodes(f_idx)

    res_score = jnp.where(result_ok(f_idx), f_score, -jnp.inf)
    best_score = jnp.max(res_score, axis=1)
    best_idx = jnp.take_along_axis(f_idx, jnp.argmax(res_score, axis=1)[:, None], axis=1)[:, 0]
    best_idx = jnp.where(jnp.isfinite(best_score), best_idx, INVALID)

    def cond(state):
        hop, _, _, best_s, _, done = state
        return (hop < max_hops) & ~jnp.all(done)

    def body(state):
        hop, f_idx, f_score, best_s, best_i, done = state
        # Expand: neighbors of the frontier. (B, F, M) -> (B, F*M)
        nbr = jnp.take(neighbors, jnp.maximum(f_idx, 0), axis=0)
        nbr = jnp.where(f_idx[:, :, None] == INVALID, INVALID, nbr)
        cand = nbr.reshape(B, -1)
        c_score = score_nodes(cand)

        # Merge frontier ∪ candidates, keep top-beam by raw routing score.
        all_idx = jnp.concatenate([f_idx, cand], axis=1)
        all_score = jnp.concatenate([f_score, c_score], axis=1)
        top_s, top_pos = jax.lax.top_k(all_score, beam)
        top_i = jnp.take_along_axis(all_idx, top_pos, axis=1)

        # Result tracking only over valid (non-tombstoned) same-category nodes.
        res_s = jnp.where(result_ok(top_i), top_s, -jnp.inf)
        hop_best_s = jnp.max(res_s, axis=1)
        hop_best_i = jnp.take_along_axis(
            top_i, jnp.argmax(res_s, axis=1)[:, None], axis=1)[:, 0]
        improved = hop_best_s > best_s + 1e-9
        new_best_s = jnp.where(improved, hop_best_s, best_s)
        new_best_i = jnp.where(improved, hop_best_i, best_i)

        # Early exit (paper §5.3): per-query done once τ reached; also stop
        # queries whose frontier reached a fixpoint (the merge returned the
        # previous frontier unchanged — no new candidates route anywhere).
        # Convergence is judged at the ROUTING level, not on the masked
        # best: under category masking the result may stall for hops while
        # the beam is still traversing a cross-category region toward the
        # query's category.
        converged = jnp.all(top_i == f_idx, axis=1)
        frozen = done[:, None]
        top_i = jnp.where(frozen, f_idx, top_i)
        top_s = jnp.where(frozen, f_score, top_s)
        new_done = done | (new_best_s >= thresholds) | converged
        return hop + 1, top_i, top_s, new_best_s, new_best_i, new_done

    done0 = best_score >= thresholds
    state = (jnp.asarray(0), f_idx, f_score, best_score, best_idx, done0)
    hops, _, _, best_score, best_idx, _ = jax.lax.while_loop(cond, body, state)

    hit = best_score >= thresholds
    return jnp.where(hit, best_idx, INVALID), best_score, hops


# ---------------------------------------------------------------------------
# HNSW proper.
# ---------------------------------------------------------------------------

@dataclass
class HNSWParams:
    M: int = 16                 # neighbors per node, upper levels
    M0: int = 32                # neighbors per node, level 0
    ef_construction: int = 64
    ef_search: int = 48         # host-search beam
    beam: int = 32              # device-search beam width F
    max_hops: int = 12          # device-search hop cap
    n_entries: int = 8          # device-search entry set size E
    # Delta-sync protocol: apply dirty rows in place until their fraction
    # of capacity exceeds this, then re-upload the full tables (a graph
    # that churned that much is cheaper to rebuild than to scatter).
    # Negative forces a full upload on every sync (the pre-delta behavior,
    # kept as the O(capacity) contrast for benchmarks).
    rebuild_threshold: float = 0.25


class HNSWIndex:
    """Hierarchical build on host; batched beam search on device.

    Fixed ``capacity``; slots are recycled through a freelist on removal
    (cache eviction). The device tables are persistent: mutations log
    their touched rows in ``_dirty`` and ``device_tables()`` flushes the
    log with an in-place scatter (see module docstring — sync cost is
    O(delta), not O(capacity)).
    """

    def __init__(self, dim: int, capacity: int, params: HNSWParams | None = None,
                 seed: int = 0):
        self.dim = dim
        self.capacity = capacity
        self.p = params or HNSWParams()
        self.rng = np.random.default_rng(seed)
        self.ml = 1.0 / math.log(self.p.M)

        self.emb = np.zeros((capacity, dim), dtype=np.float32)
        self.valid = np.zeros((capacity,), dtype=bool)
        self.category = np.full((capacity,), -1, dtype=np.int32)
        self.level = np.full((capacity,), -1, dtype=np.int8)
        # neighbors[0] is the device-visible level-0 graph.
        self.neighbors: list[np.ndarray] = [
            np.full((capacity, self.p.M0), INVALID, dtype=np.int32)
        ]
        self.entry_point: int = INVALID
        self.max_level: int = -1
        self._n = 0
        self._free: list[int] = []
        self._version = 0
        self._device_version = -1
        self._device: dict | None = None
        # Delta log: level-0 rows whose emb/neighbors/valid/category changed
        # since the last device sync. A set — rows touched repeatedly within
        # one serve step coalesce to one scattered row.
        self._dirty: set[int] = set()
        self._entries_cache: np.ndarray | None = None
        self._entries_version = -1
        self.sync_stats = {"full_uploads": 0, "delta_updates": 0,
                           "rows_synced": 0, "bytes_synced": 0}

    # -- basic bookkeeping ---------------------------------------------------
    def __len__(self) -> int:
        return int(self.valid.sum())

    def _alloc_slot(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n >= self.capacity:
            raise RuntimeError("HNSWIndex full — evict before inserting")
        slot = self._n
        self._n += 1
        return slot

    def _ensure_level_arrays(self, level: int) -> None:
        while len(self.neighbors) <= level:
            self.neighbors.append(
                np.full((self.capacity, self.p.M), INVALID, dtype=np.int32))

    def _draw_level(self) -> int:
        return int(-math.log(max(self.rng.random(), 1e-12)) * self.ml)

    # -- host greedy search helpers -------------------------------------------
    def _greedy_descend(self, q: np.ndarray, entry: int, level: int) -> int:
        """Greedy 1-best descent at one level (used above the target level)."""
        cur = entry
        cur_sim = float(q @ self.emb[cur])
        improved = True
        nbrs = self.neighbors[level]
        while improved:
            improved = False
            nb = nbrs[cur]
            nb = nb[nb != INVALID]
            if nb.size == 0:
                break
            sims = self.emb[nb] @ q
            j = int(np.argmax(sims))
            if sims[j] > cur_sim:
                cur_sim = float(sims[j])
                cur = int(nb[j])
                improved = True
        return cur

    def _search_level(self, q: np.ndarray, entries: list[int], level: int,
                      ef: int) -> tuple[np.ndarray, np.ndarray]:
        """Best-first search at one level. Returns (ids, sims) sorted desc."""
        nbrs = self.neighbors[level]
        visited = set(entries)
        cand_ids = list(entries)
        cand_sims = list(self.emb[entries] @ q)
        # results kept as parallel arrays, pruned to ef
        res_ids = list(cand_ids)
        res_sims = list(cand_sims)
        while cand_ids:
            j = int(np.argmax(cand_sims))
            c_sim = cand_sims.pop(j)
            c = cand_ids.pop(j)
            worst = min(res_sims) if len(res_sims) >= ef else -np.inf
            if c_sim < worst:
                break
            nb = nbrs[c]
            nb = nb[nb != INVALID]
            nb = [int(x) for x in nb if int(x) not in visited]
            if not nb:
                continue
            visited.update(nb)
            sims = self.emb[nb] @ q
            for node, s in zip(nb, sims):
                if len(res_sims) < ef or s > min(res_sims):
                    res_ids.append(node)
                    res_sims.append(float(s))
                    cand_ids.append(node)
                    cand_sims.append(float(s))
                    if len(res_sims) > ef:
                        k = int(np.argmin(res_sims))
                        res_ids.pop(k)
                        res_sims.pop(k)
        order = np.argsort(res_sims)[::-1]
        return (np.asarray(res_ids, np.int32)[order],
                np.asarray(res_sims, np.float32)[order])

    # -- insertion -------------------------------------------------------------
    def add(self, vec: np.ndarray, category: int = -1) -> int:
        vec = np.asarray(vec, np.float32)
        slot = self._alloc_slot()
        self.emb[slot] = vec
        self.valid[slot] = True
        self.category[slot] = category
        lvl = min(self._draw_level(), 8)
        self.level[slot] = lvl
        self._ensure_level_arrays(lvl)
        for l in range(len(self.neighbors)):
            self.neighbors[l][slot] = INVALID
        self._dirty.add(slot)

        if self.entry_point == INVALID:
            self.entry_point = slot
            self.max_level = lvl
            self._version += 1
            return slot

        cur = self.entry_point
        for l in range(self.max_level, lvl, -1):
            cur = self._greedy_descend(vec, cur, l)
        entries = [cur]
        for l in range(min(lvl, self.max_level), -1, -1):
            ids, _sims = self._search_level(vec, entries, l, self.p.ef_construction)
            m = self.p.M0 if l == 0 else self.p.M
            chosen = ids[:m]
            self.neighbors[l][slot, :len(chosen)] = chosen
            # bidirectional wiring with pruning to closest-m
            for nb in chosen:
                row = self.neighbors[l][nb]
                empty = np.where(row == INVALID)[0]
                if empty.size:
                    row[empty[0]] = slot
                else:
                    cand = np.concatenate([row, [slot]])
                    sims = self.emb[cand] @ self.emb[nb]
                    keep = cand[np.argsort(sims)[::-1][:m]]
                    self.neighbors[l][nb] = keep
            if l == 0:     # only the level-0 graph is device-visible
                self._dirty.update(int(nb) for nb in chosen)
            entries = list(ids[:1]) if len(ids) else entries

        if lvl > self.max_level:
            self.max_level = lvl
            self.entry_point = slot
        self._version += 1
        return slot

    def add_batch(self, vecs: np.ndarray,
                  categories: np.ndarray | None = None) -> np.ndarray:
        """Insert a batch of vectors. Returns the (B,) assigned slot ids.

        Graph wiring stays host-sequential (HNSW insertion is inherently
        so), but the whole batch's touched rows coalesce in the delta log,
        so the device pays ONE scatter flush on the next search instead of
        B full-table uploads.
        """
        return _batched_add(self, vecs, categories)

    def remove(self, slot: int) -> None:
        """Tombstone: stays routable until slot reuse, excluded from results."""
        if not self.valid[slot]:
            return
        self.valid[slot] = False
        self.category[slot] = -1
        self._free.append(slot)
        self._dirty.add(int(slot))
        if slot == self.entry_point:
            alive = np.where(self.valid)[0]
            if alive.size:
                lv = self.level[alive]
                best = alive[int(np.argmax(lv))]
                self.entry_point = int(best)
                self.max_level = int(self.level[best])
            else:
                self.entry_point = INVALID
                self.max_level = -1
        self._version += 1

    # -- host search (exact hierarchical; CPU latency benchmarks) --------------
    def search_host(self, queries: np.ndarray, thresholds: np.ndarray,
                    ef: int | None = None, *,
                    categories: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-query best match above threshold; -1 on miss.

        ``categories`` (B,) int32 masks result tracking by category (< 0 =
        wildcard): traversal stays category-blind — cross-category nodes
        route traffic exactly like tombstones do — but only same-category
        nodes can be returned, so a globally-nearer cross-category neighbor
        no longer shadows a valid same-category match (§5.3).
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        thresholds = np.broadcast_to(np.asarray(thresholds, np.float32),
                                     (queries.shape[0],))
        if categories is not None:
            categories = np.broadcast_to(
                np.asarray(categories, np.int32), (queries.shape[0],))
        ef = ef or self.p.ef_search
        out_idx = np.full(queries.shape[0], INVALID, np.int32)
        out_sim = np.full(queries.shape[0], -np.inf, np.float32)
        if self.entry_point == INVALID:
            return out_idx, out_sim
        for i, q in enumerate(queries):
            entries = [self.entry_point]
            for l in range(self.max_level, 0, -1):
                # small-beam descent (more robust than 1-greedy on the
                # bulk-built pivot graphs; negligible cost on upper levels)
                ids_l, _ = self._search_level(q, entries, l, ef=16)
                entries = [int(x) for x in ids_l[:8]] or entries
            ids, sims = self._search_level(q, entries, 0, ef)
            ok = self.valid[ids]
            if categories is not None and categories[i] >= 0:
                ok &= self.category[ids] == categories[i]
            ids, sims = ids[ok], sims[ok]
            if len(ids) and sims[0] >= thresholds[i]:
                out_idx[i] = ids[0]
                out_sim[i] = sims[0]
            elif len(ids):
                out_sim[i] = sims[0]
        return out_idx, out_sim

    # -- device search ----------------------------------------------------------
    def entry_set(self) -> np.ndarray:
        """Multi-entry start set: entry point + highest-level live nodes.

        Cached on ``_version``: a delta flush re-derives this at most once
        per mutation batch, and selection is O(n) ``argpartition`` (top-E
        by level, order within the set is irrelevant to the beam), not a
        full argsort of all live nodes.
        """
        if self._entries_version == self._version and \
                self._entries_cache is not None:
            return self._entries_cache
        E = self.p.n_entries
        ents = np.full((E,), INVALID, np.int32)
        if self.entry_point != INVALID:
            alive = np.where(self.valid)[0]
            if alive.size > E:
                top = np.argpartition(self.level[alive], alive.size - E)[-E:]
                chosen = alive[top].astype(np.int32)
            else:
                chosen = alive.astype(np.int32)
            ents[:len(chosen)] = chosen
            if self.entry_point not in chosen:
                ents[0] = self.entry_point
        self._entries_cache = ents
        self._entries_version = self._version
        return ents

    def _row_nbytes(self) -> int:
        """Bytes one synced delta row moves (emb + nbrs + valid + cat + id)."""
        return (self.emb.itemsize * self.dim
                + self.neighbors[0].itemsize * self.p.M0
                + self.valid.itemsize + self.category.itemsize + 4)

    def device_tables(self) -> dict:
        """The persistent device mirror, synced to the host state.

        Protocol: no mutation since last sync → returned as-is. Otherwise
        the dirty-row log is applied with one donated in-place scatter
        (O(delta) bytes); a full O(capacity) upload happens only on first
        use or when the dirty fraction exceeds ``rebuild_threshold``. The
        entry set (E ints) rides along on every sync. Returned buffers are
        donated to the NEXT flush — re-fetch after any mutation, never
        cache them caller-side.
        """
        if self._device is not None and self._device_version == self._version:
            return self._device
        if self._device is None or len(self._dirty) > \
                self.p.rebuild_threshold * self.capacity:
            self._device = {
                "emb": jnp.asarray(self.emb),
                "neighbors": jnp.asarray(self.neighbors[0]),
                "valid": jnp.asarray(self.valid),
                "category": jnp.asarray(self.category),
            }
            self.sync_stats["full_uploads"] += 1
            self.sync_stats["rows_synced"] += self.capacity
            self.sync_stats["bytes_synced"] += \
                self.capacity * self._row_nbytes()
        elif self._dirty:
            rows = np.fromiter(self._dirty, np.int64, len(self._dirty))
            rows.sort()
            # Bucket the row count (next power of two) so the jit cache
            # holds O(log capacity) entries; padding repeats row 0 of the
            # delta with identical payload — a deterministic no-op.
            bucket = max(8, 1 << (len(rows) - 1).bit_length())
            rows = np.concatenate(
                [rows, np.full(bucket - len(rows), rows[0])]).astype(np.int32)
            d = self._device
            rows_j = jnp.asarray(rows)
            self._device = {
                "emb": ops.scatter_rows(
                    d["emb"], rows_j, jnp.asarray(self.emb[rows])),
                "neighbors": ops.scatter_rows(
                    d["neighbors"], rows_j,
                    jnp.asarray(self.neighbors[0][rows])),
                "valid": ops.scatter_rows(
                    d["valid"], rows_j, jnp.asarray(self.valid[rows])),
                "category": ops.scatter_rows(
                    d["category"], rows_j, jnp.asarray(self.category[rows])),
            }
            self.sync_stats["delta_updates"] += 1
            self.sync_stats["rows_synced"] += len(rows)
            self.sync_stats["bytes_synced"] += len(rows) * self._row_nbytes()
        entries = self.entry_set()
        self._device["entries"] = jnp.asarray(entries)
        self.sync_stats["bytes_synced"] += entries.nbytes
        self._dirty.clear()
        self._device_version = self._version
        return self._device

    def search_batch(self, queries: np.ndarray, thresholds: np.ndarray, *,
                     categories: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Batched device beam search (jnp reference path).

        ``categories`` (B,) int32 per-query category mask (< 0 = wildcard);
        None searches category-blind.
        """
        t = self.device_tables()
        q = jnp.asarray(np.atleast_2d(queries).astype(np.float32))
        B = q.shape[0]
        tau = jnp.asarray(np.broadcast_to(
            np.asarray(thresholds, np.float32), (B,)))
        if categories is None:
            qcat = np.full((B,), -1, np.int32)
        else:
            qcat = np.broadcast_to(np.asarray(categories, np.int32), (B,))
        idx, score, _ = beam_search(t["emb"], t["neighbors"], t["valid"],
                                    t["entries"], q, tau,
                                    t["category"], jnp.asarray(qcat),
                                    beam=self.p.beam, max_hops=self.p.max_hops)
        return np.asarray(idx), np.asarray(score)

    # -- bulk build (benchmarks) -------------------------------------------------
    @classmethod
    def bulk_build(cls, vecs: np.ndarray, capacity: int | None = None,
                   params: HNSWParams | None = None, seed: int = 0,
                   categories: np.ndarray | None = None) -> "HNSWIndex":
        """Pivot-clustered approximate build: O(n·√n·d), for large benchmark
        indexes where incremental insertion would dominate runtime.

        ``categories`` (n,) int32 assigns per-slot categories (the masked
        search input, §5.3); omitted → -1 (matched only by wildcard
        queries, i.e. category-blind search still works)."""
        n, dim = vecs.shape
        capacity = capacity or int(n * 1.25) + 8
        idx = cls(dim, capacity, params, seed)
        if categories is not None:
            idx.category[:n] = np.asarray(categories, np.int32)
        p = idx.p
        n_piv = max(1, int(math.sqrt(n) * 2))
        rng = np.random.default_rng(seed)
        piv = rng.choice(n, size=min(n_piv, n), replace=False)
        pivots = vecs[piv]
        sims_pv = vecs @ pivots.T                               # (n, P)
        assign = np.argmax(sims_pv, axis=1)
        # overlap: second-best pivot too, for boundary connectivity
        assign2 = np.argsort(-sims_pv, axis=1)[:, 1] if pivots.shape[0] > 1 \
            else assign
        idx.emb[:n] = vecs
        idx.valid[:n] = True
        idx.level[:n] = 0
        idx._n = n
        piv_nodes = piv.astype(np.int64)      # pivots ARE real points
        for c in range(pivots.shape[0]):
            members = np.where((assign == c) | (assign2 == c))[0]
            if members.size <= 1:
                continue
            sims = vecs[members] @ vecs[members].T
            np.fill_diagonal(sims, -np.inf)
            k = min(p.M0 - 2, members.size - 1)   # leave room for hub edges
            nn = np.argpartition(-sims, k - 1, axis=1)[:, :k]
            idx.neighbors[0][members[:, None].repeat(k, 1),
                             np.arange(k)[None, :]] = members[nn]
            # hub edges: every member ↔ its pivot keeps the graph connected
            idx.neighbors[0][members, p.M0 - 1] = piv_nodes[c]
        # pivot-to-pivot kNN edges (level 0 + level 1) bridge clusters
        psims = pivots @ pivots.T
        np.fill_diagonal(psims, -np.inf)
        kp = min(p.M, piv_nodes.size - 1)
        idx._ensure_level_arrays(1)
        idx.level[piv_nodes] = 1
        if kp > 0:
            pnn = np.argpartition(-psims, kp - 1, axis=1)[:, :kp]
            for j, node in enumerate(piv_nodes):
                idx.neighbors[1][node, :kp] = piv_nodes[pnn[j]]
                idx.neighbors[0][node, p.M0 - kp - 1:p.M0 - 1] = \
                    piv_nodes[pnn[j][:kp]]
        idx.entry_point = int(piv_nodes[0])
        idx.max_level = 1
        idx._version += 1
        return idx
