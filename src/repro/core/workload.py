"""Heterogeneous workload generation (paper §3, Table 1).

Each ``CategorySpec`` controls the four properties the paper identifies:

    density     — via the category's ``SyntheticCategorySpace`` (sigma /
                  center_spread / n_centers)
    repetition  — Zipf(α) over an intent pool (code: α≈1.2 → top 10 % of
                  intents ≈ 45 % of traffic), uniform (chat), bursty
                  (rotating working set) or drifting (moving Zipf head)
    staleness   — Poisson content-update rate per intent (fraction/second);
                  a served response is *stale* iff the intent's content
                  version advanced since caching
    cost        — downstream model latency/price (drives economics)

The generator emits a time-ordered stream of ``Query`` records carrying the
ground-truth intent id + content version, so the simulator can measure true
hit rates, false positives (matched a different intent) and staleness.

``scenario_matrix()`` packages named workload shapes — per-category
power_law / uniform_tail / bursty / drifting plus the session_drift,
flash_crowd and stale_burst composites — keyed by the paper's category
names so ``paper_policies()`` applies unchanged. The matrix drives
``serving/simulator.py`` and ``benchmarks/bench_admission.py``; every
scenario is seed-deterministic (fixed seed → identical trace).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.embedding import EMBED_DIM, SyntheticCategorySpace


@dataclass
class CategorySpec:
    name: str
    traffic_share: float            # fraction of total queries
    pool_size: int                  # number of distinct intents
    zipf_alpha: float | None        # None → uniform repetition
    staleness_per_s: float          # per-intent content update rate (1/s)
    t_llm_ms: float                 # downstream model latency
    model_name: str = "default"
    cost_per_call: float = 0.01
    sigma: float = 0.10             # paraphrase noise (density)
    center_spread: float = 1.0      # cluster concentration (density)
    loose_frac: float = 0.30        # fraction of loose paraphrases
    loose_mult: float = 2.0         # loose paraphrase noise multiplier
    seed: int = 0
    # Repetition shape: "auto" resolves to "zipf" when zipf_alpha is set,
    # else "uniform" (the seed semantics — TABLE1 traces are unchanged).
    # "bursty" concentrates burst_frac of traffic on a working set that
    # rotates every burst_window_s; "drifting" slides a Zipf head through
    # the pool at drift_per_s intents/second (session topics wandering).
    repetition: str = "auto"        # auto | zipf | uniform | bursty | drifting
    burst_window_s: float = 60.0
    burst_working_set: int = 32
    burst_frac: float = 0.85
    drift_per_s: float = 0.0
    # Flash-crowd overlay (inert at flash_frac=0, composable with any
    # repetition kind): inside [flash_start_s, flash_end_s) a flash_frac
    # slice of the category's traffic collapses onto the first
    # flash_intents intents — the breaking-news spike of §7.5.
    flash_start_s: float = 0.0
    flash_end_s: float = 0.0
    flash_frac: float = 0.0
    flash_intents: int = 64

    def make_space(self, dim: int = EMBED_DIM) -> SyntheticCategorySpace:
        return SyntheticCategorySpace(
            name=self.name, n_centers=self.pool_size, sigma=self.sigma,
            center_spread=self.center_spread, loose_frac=self.loose_frac,
            loose_mult=self.loose_mult, dim=dim, seed=self.seed)


@dataclass
class Query:
    category: str
    intent_id: int                   # ground truth
    content_version: int             # ground truth at issue time
    embedding: np.ndarray
    t_llm_ms: float
    model_name: str
    cost_per_call: float
    timestamp: float
    text: str = ""


class WorkloadGenerator:
    """Streams queries across categories at ``rate_per_s`` aggregate QPS."""

    def __init__(self, specs: list[CategorySpec], rate_per_s: float = 30.0,
                 dim: int = EMBED_DIM, seed: int = 0):
        total = sum(s.traffic_share for s in specs)
        if abs(total - 1.0) > 1e-6:
            specs = [dataclass_replace(s, traffic_share=s.traffic_share / total)
                     for s in specs]
        self.specs = specs
        self.rate_per_s = rate_per_s
        self.dim = dim
        self.rng = np.random.default_rng(seed)
        self.spaces = {s.name: s.make_space(dim) for s in specs}
        self._shares = np.array([s.traffic_share for s in specs])
        # content versions advance lazily: we store last-update sample time
        self._versions: dict[str, np.ndarray] = {
            s.name: np.zeros(s.pool_size, np.int64) for s in specs}
        self._last_t: dict[str, float] = {s.name: 0.0 for s in specs}
        self._zipf_p: dict[str, np.ndarray] = {}

    def _advance_versions(self, spec: CategorySpec, now: float) -> None:
        """Poisson content updates since the last observation."""
        dt = now - self._last_t[spec.name]
        if dt <= 0 or spec.staleness_per_s <= 0:
            self._last_t[spec.name] = now
            return
        lam = spec.staleness_per_s * dt
        self._versions[spec.name] += self.rng.poisson(
            lam, size=spec.pool_size)
        self._last_t[spec.name] = now

    def _zipf_probs(self, spec: CategorySpec) -> np.ndarray:
        if spec.name not in self._zipf_p:
            # Bounded Zipf over [1, pool]: p(k) ∝ k^-α.
            alpha = 1.1 if spec.zipf_alpha is None else spec.zipf_alpha
            ranks = np.arange(1, spec.pool_size + 1, dtype=np.float64)
            p = ranks ** (-alpha)
            self._zipf_p[spec.name] = p / p.sum()
        return self._zipf_p[spec.name]

    def _draw_intent(self, spec: CategorySpec, t: float = 0.0) -> int:
        # Flash overlay first (no rng draw at all unless the spec opts
        # in AND the clock is inside the window — default-off specs keep
        # the seed's exact rng call sequence).
        if spec.flash_frac > 0.0 and \
                spec.flash_start_s <= t < spec.flash_end_s and \
                self.rng.random() < spec.flash_frac:
            return int(self.rng.integers(
                0, min(spec.flash_intents, spec.pool_size)))
        kind = spec.repetition
        if kind == "auto":
            kind = "uniform" if spec.zipf_alpha is None else "zipf"
        if kind == "uniform":
            return int(self.rng.integers(0, spec.pool_size))
        if kind == "zipf":
            return int(self.rng.choice(spec.pool_size,
                                       p=self._zipf_probs(spec)))
        if kind == "bursty":
            # A working set of burst_working_set intents receives
            # burst_frac of traffic; the set rotates (disjointly, until
            # the pool wraps) each burst_window_s.
            if self.rng.random() < spec.burst_frac:
                w = int(t // spec.burst_window_s)
                base = (w * spec.burst_working_set) % spec.pool_size
                off = int(self.rng.integers(
                    0, min(spec.burst_working_set, spec.pool_size)))
                return (base + off) % spec.pool_size
            return int(self.rng.integers(0, spec.pool_size))
        if kind == "drifting":
            # A Zipf head anchored to a center that slides through the
            # pool at drift_per_s intents/second: yesterday's hot topics
            # cool as the session moves on.
            center = int(t * spec.drift_per_s) % spec.pool_size
            off = int(self.rng.choice(spec.pool_size,
                                      p=self._zipf_probs(spec)))
            return (center + off) % spec.pool_size
        raise ValueError(f"{spec.name}: unknown repetition {kind!r}")

    def version_of(self, category: str, intent_id: int, now: float) -> int:
        spec = next(s for s in self.specs if s.name == category)
        self._advance_versions(spec, now)
        return int(self._versions[category][intent_id])

    def generate(self, n: int, start_time: float = 0.0) -> list[Query]:
        """n queries with exponential inter-arrival at the aggregate rate."""
        out: list[Query] = []
        t = start_time
        cat_idx = self.rng.choice(len(self.specs), size=n, p=self._shares)
        gaps = self.rng.exponential(1.0 / self.rate_per_s, size=n)
        for i in range(n):
            spec = self.specs[int(cat_idx[i])]
            t += float(gaps[i])
            self._advance_versions(spec, t)
            intent = self._draw_intent(spec, t)
            emb = self.spaces[spec.name].sample(intent, self.rng)
            out.append(Query(
                category=spec.name, intent_id=intent,
                content_version=int(self._versions[spec.name][intent]),
                embedding=emb, t_llm_ms=spec.t_llm_ms,
                model_name=spec.model_name, cost_per_call=spec.cost_per_call,
                timestamp=t,
                text=f"{spec.name}:intent{intent}",
            ))
        return out


def dataclass_replace(spec: CategorySpec, **kw) -> CategorySpec:
    from dataclasses import replace
    return replace(spec, **kw)


# ---------------------------------------------------------------------------
# Table 1 workload: calibrated so the paper's hit-rate long tail emerges.
# Head: power-law repetition, dense spaces, stable content → 45–55 %.
# Tail: uniform repetition / volatile content / sparse spaces → 6–12 %.
# ---------------------------------------------------------------------------

# Pool sizes / Zipf exponents calibrated (8 k queries @30 qps, 12 k-entry
# cache, flat index) so the paper's Table 1 hit-rate bands emerge:
# head 40–60 %, tail 5–15 %, volatility-limited financial, TTL-limited.
TABLE1_WORKLOAD: list[CategorySpec] = [
    CategorySpec("code_generation", traffic_share=0.35, pool_size=4000,
                 zipf_alpha=1.1, staleness_per_s=1.2e-9,    # ~0.01 %/day
                 t_llm_ms=500.0, model_name="o1", cost_per_call=0.10,
                 sigma=0.012, center_spread=0.25, seed=11),
    CategorySpec("api_documentation", traffic_share=0.25, pool_size=6500,
                 zipf_alpha=1.05, staleness_per_s=2.3e-7,     # ~2 %/day
                 t_llm_ms=500.0, model_name="gpt4o", cost_per_call=0.05,
                 sigma=0.013, center_spread=0.28, seed=12),
    CategorySpec("conversational_chat", traffic_share=0.15, pool_size=5200,
                 zipf_alpha=None, staleness_per_s=0.0,
                 t_llm_ms=200.0, model_name="haiku", cost_per_call=0.01,
                 sigma=0.022, center_spread=0.36, loose_mult=1.5, seed=13),
    CategorySpec("financial_data", traffic_share=0.10, pool_size=3200,
                 zipf_alpha=0.7, staleness_per_s=2.2e-4,     # ~80 %/hour
                 t_llm_ms=200.0, model_name="gpt4o_mini", cost_per_call=0.01,
                 sigma=0.015, center_spread=0.50, seed=14),
    CategorySpec("legal_queries", traffic_share=0.08, pool_size=8000,
                 zipf_alpha=0.7, staleness_per_s=1.2e-8,
                 t_llm_ms=500.0, model_name="gpt4o", cost_per_call=0.05,
                 sigma=0.020, center_spread=0.55, seed=15),
    CategorySpec("medical_queries", traffic_share=0.04, pool_size=3000,
                 zipf_alpha=0.6, staleness_per_s=1.2e-8,
                 t_llm_ms=500.0, model_name="gpt4o", cost_per_call=0.05,
                 sigma=0.021, center_spread=0.60, seed=16),
    CategorySpec("specialized_domains", traffic_share=0.03, pool_size=4500,
                 zipf_alpha=0.7, staleness_per_s=1.2e-8,
                 t_llm_ms=200.0, model_name="haiku", cost_per_call=0.01,
                 sigma=0.022, center_spread=0.60, seed=17),
]


# ---------------------------------------------------------------------------
# Scenario matrix (admission/eviction stress shapes). Categories reuse the
# paper's names so paper_policies() applies without edits; rates and spans
# are chosen so each scenario's defining pressure actually occurs inside a
# few-thousand-query run (deterministic at fixed seed).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Scenario:
    """One named workload shape: specs + the aggregate rate that makes
    its time-dependent structure (burst windows, flash spans, TTL storms)
    land inside a benchmark-sized run."""

    name: str
    specs: tuple
    rate_per_s: float = 30.0
    description: str = ""

    def generator(self, seed: int = 0, dim: int = EMBED_DIM,
                  rate_per_s: float | None = None) -> WorkloadGenerator:
        return WorkloadGenerator(list(self.specs),
                                 rate_per_s=rate_per_s or self.rate_per_s,
                                 dim=dim, seed=seed)


def _code(share: float, **kw) -> CategorySpec:
    return CategorySpec("code_generation", traffic_share=share,
                        pool_size=4000, zipf_alpha=1.1,
                        staleness_per_s=1.2e-9, t_llm_ms=500.0,
                        model_name="o1", cost_per_call=0.10, sigma=0.012,
                        center_spread=0.25, seed=11, **kw)


def _chat(share: float, pool: int = 5200, zipf_alpha: float | None = None,
          **kw) -> CategorySpec:
    return CategorySpec("conversational_chat", traffic_share=share,
                        pool_size=pool, zipf_alpha=zipf_alpha,
                        staleness_per_s=0.0, t_llm_ms=200.0,
                        model_name="haiku", cost_per_call=0.01, sigma=0.022,
                        center_spread=0.36, loose_mult=1.5, seed=13, **kw)


def scenario_matrix() -> dict[str, Scenario]:
    """The named workload shapes bench_admission / test_simulator sweep."""
    return {s.name: s for s in [
        # Per-category primitives -------------------------------------------
        Scenario("power_law", (_code(1.0),), description=(
            "Pure Zipf(1.1) code traffic — the head-repetition baseline; "
            "admission control must leave its hit rate untouched")),
        Scenario("uniform_tail", (
            _chat(1.0, pool=50000, flash_start_s=0.0, flash_end_s=1e9,
                  flash_frac=0.12, flash_intents=64),
        ), description=(
            "Uniform chat over a 50 k-intent pool (≈ no repetition) with "
            "a small persistent hot set — the shape where unconditional "
            "admission churns quota bytes on entries that never re-hit")),
        Scenario("bursty", (
            CategorySpec("api_documentation", traffic_share=1.0,
                         pool_size=6500, zipf_alpha=1.05,
                         staleness_per_s=2.3e-7, t_llm_ms=500.0,
                         model_name="gpt4o", cost_per_call=0.05,
                         sigma=0.013, center_spread=0.28, seed=12,
                         repetition="bursty", burst_window_s=60.0,
                         burst_working_set=32, burst_frac=0.85),
        ), description=(
            "85 % of traffic on a 32-intent working set that rotates "
            "every 60 s — repetition is high inside a window, zero "
            "across windows")),
        Scenario("drifting", (
            _chat(1.0, repetition="drifting", zipf_alpha=1.1,
                  drift_per_s=2.0),
        ), description=(
            "Zipf head sliding 2 intents/s through the chat pool — "
            "session topics wander, so old entries cool deterministically")),
        # Composites ---------------------------------------------------------
        Scenario("session_drift", (
            _code(0.5),
            _chat(0.5, repetition="drifting", zipf_alpha=1.1,
                  drift_per_s=2.0),
        ), description=(
            "Stable code head + drifting chat sessions competing for "
            "capacity — eviction must age out the drift's cold wake "
            "without touching the stable head")),
        Scenario("flash_crowd", (
            _chat(0.6, pool=20000, flash_start_s=20.0, flash_end_s=80.0,
                  flash_frac=0.5, flash_intents=16),
            _code(0.4),
        ), description=(
            "Breaking-news spike: between t=20 s and t=80 s half the "
            "chat traffic collapses onto 16 intents, then reverts to "
            "uniform-over-20k")),
        Scenario("stale_burst", (
            CategorySpec("financial_data", traffic_share=0.7,
                         pool_size=1200, zipf_alpha=0.9,
                         staleness_per_s=5e-3,          # ~version / 200 s
                         t_llm_ms=200.0, model_name="gpt4o_mini",
                         cost_per_call=0.01, sigma=0.015,
                         center_spread=0.50, seed=14,
                         flash_start_s=0.0, flash_end_s=1e9,
                         flash_frac=0.3, flash_intents=32),
            _code(0.3),
        ), rate_per_s=6.0, description=(
            "financial_data TTL storm: hot quotes re-asked faster than "
            "content updates land, at a 6 qps rate so a bench-sized run "
            "spans the 5-minute TTL repeatedly")),
    ]}


SCENARIO_NAMES = tuple(scenario_matrix())


def scenario_generator(name: str, seed: int = 0, dim: int = EMBED_DIM,
                       rate_per_s: float | None = None) -> WorkloadGenerator:
    """Build the named scenario's generator (KeyError on unknown name)."""
    return scenario_matrix()[name].generator(seed=seed, dim=dim,
                                             rate_per_s=rate_per_s)
