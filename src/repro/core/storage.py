"""External document storage + the vector-DB baseline (paper §4, §5.1).

The hybrid architecture keeps only embeddings + metadata in memory and the
documents (request body, response body, timestamps) in an external store
accessed by primary key. Stores are pluggable:

    InMemoryStore      — dict-backed (tests, simulator)
    FileStore          — one file per doc + manifest (restart-durable)
    LatencyModelStore  — wraps any store and charges simulated latency on a
                         ``Clock`` (the 5 ms fetch of §4.4)
    FlakyStore         — wraps any store and injects scheduled transient
                         failures from a ``core.faults.FaultInjector``
    RetryingStore      — wraps any store with bounded retries, Clock-charged
                         deterministic exponential backoff and a per-call
                         latency budget; exhaustion raises ``StoreTimeout``
                         (the cache lookup path degrades it to a counted
                         served-from-model miss instead of a stall)
    VectorDBEmulator   — the *baseline the paper argues against*: coupled
                         remote search+storage. Charges 30 ms search on every
                         query (hit or miss), applies thresholds post-search,
                         collection-level config, server-side TTL checks that
                         waste a fetch on expired entries (§4.1–4.3).
"""

from __future__ import annotations

import json
import os
import tempfile
import zlib
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.clock import Clock, SimClock
from repro.core.faults import FaultInjector, StoreTimeout, \
    TransientStoreError


@dataclass
class Document:
    """A cached (request, response) pair with timestamps (§5.1).

    ``embedding`` is the full-precision fp32 query embedding, stored
    NEXT TO the document — i.e. in the external tier, not the compact
    in-memory one. It is the ground truth for the cache's re-rank tier:
    when the device index holds quantized (int8) rows, borderline
    matches (|score − τ| ≤ margin) are exactly re-scored against this
    copy, so the resident tier can shrink 4x without moving hit/miss
    decisions at the threshold boundary.
    """

    doc_id: int
    request: str
    response: str
    created_at: float
    category: str = ""
    meta: dict = field(default_factory=dict)
    embedding: Any = None            # fp32 vector (np.ndarray or list)

    def embedding_array(self) -> np.ndarray | None:
        """The stored embedding as fp32 numpy (None if absent)."""
        if self.embedding is None:
            return None
        return np.asarray(self.embedding, np.float32)

    def to_json(self) -> str:
        emb = self.embedding
        if emb is not None:
            emb = np.asarray(emb, np.float32).tolist()
        return json.dumps({
            "doc_id": self.doc_id, "request": self.request,
            "response": self.response, "created_at": self.created_at,
            "category": self.category, "meta": self.meta,
            "embedding": emb,
        })

    @classmethod
    def from_json(cls, s: str) -> "Document":
        return cls(**json.loads(s))

    def nbytes(self) -> int:
        emb_bytes = 0 if self.embedding is None else 4 * len(self.embedding)
        return (len(self.request.encode()) + len(self.response.encode())
                + emb_bytes + 64)


class DocumentStore:
    """Primary-key document store interface."""

    def put(self, doc: Document) -> None:
        raise NotImplementedError

    def put_many(self, docs: list[Document]) -> None:
        """Batched write — ONE store pass for a whole insert batch.

        Default loops ``put``; stores with per-call round-trip cost
        (network, fsync) override this to amortize it.
        """
        for doc in docs:
            self.put(doc)

    def get(self, doc_id: int) -> Document | None:
        raise NotImplementedError

    def delete(self, doc_id: int) -> None:
        raise NotImplementedError

    def scan(self, category: str | None = None) -> list[Document]:
        """Bulk-iterate documents (optionally one category), ordered by
        doc_id for determinism. This is the RECOVERY path — outage
        rebalancing rebuilds a dead shard's resident set from its
        (separately durable) store — not the per-key hot path, so
        wrappers delegate it without per-op fault/latency accounting.
        """
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class InMemoryStore(DocumentStore):
    def __init__(self):
        self._docs: dict[int, Document] = {}

    def put(self, doc: Document) -> None:
        self._docs[doc.doc_id] = doc

    def put_many(self, docs: list[Document]) -> None:
        self._docs.update((d.doc_id, d) for d in docs)

    def get(self, doc_id: int) -> Document | None:
        return self._docs.get(doc_id)

    def delete(self, doc_id: int) -> None:
        self._docs.pop(doc_id, None)

    def scan(self, category: str | None = None) -> list[Document]:
        docs = sorted(self._docs.values(), key=lambda d: d.doc_id)
        if category is not None:
            docs = [d for d in docs if d.category == category]
        return docs

    def __len__(self) -> int:
        return len(self._docs)

    def total_bytes(self) -> int:
        return sum(d.nbytes() for d in self._docs.values())


class FileStore(DocumentStore):
    """One compressed file per document; atomic writes; restart-durable."""

    def __init__(self, root: str, compress: bool = True):
        self.root = root
        self.compress = compress
        os.makedirs(root, exist_ok=True)

    def _path(self, doc_id: int) -> str:
        return os.path.join(self.root, f"{doc_id:016x}.doc")

    def put(self, doc: Document) -> None:
        payload = doc.to_json().encode()
        if self.compress:
            payload = zlib.compress(payload, level=1)
        fd, tmp = tempfile.mkstemp(dir=self.root)
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, self._path(doc.doc_id))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get(self, doc_id: int) -> Document | None:
        path = self._path(doc_id)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            payload = f.read()
        if self.compress:
            payload = zlib.decompress(payload)
        return Document.from_json(payload.decode())

    def delete(self, doc_id: int) -> None:
        path = self._path(doc_id)
        if os.path.exists(path):
            os.unlink(path)

    def scan(self, category: str | None = None) -> list[Document]:
        ids = sorted(int(n[:-4], 16) for n in os.listdir(self.root)
                     if n.endswith(".doc"))
        docs = [d for d in (self.get(i) for i in ids) if d is not None]
        if category is not None:
            docs = [d for d in docs if d.category == category]
        return docs

    def __len__(self) -> int:
        return sum(1 for n in os.listdir(self.root) if n.endswith(".doc"))


class LatencyModelStore(DocumentStore):
    """Charges per-op latency on a simulated clock (paper's 5 ms fetch)."""

    def __init__(self, inner: DocumentStore, clock: Clock,
                 get_ms: float = 5.0, put_ms: float = 1.0, delete_ms: float = 0.5):
        self.inner = inner
        self.clock = clock
        self.get_ms = get_ms
        self.put_ms = put_ms
        self.delete_ms = delete_ms

    def put(self, doc: Document) -> None:
        self.clock.advance(self.put_ms / 1e3)   # span-ok: caller-owned span
        self.inner.put(doc)

    def put_many(self, docs: list[Document]) -> None:
        # one batched round trip, not one per document
        self.clock.advance(self.put_ms / 1e3)   # span-ok: caller-owned span
        self.inner.put_many(docs)

    def get(self, doc_id: int) -> Document | None:
        self.clock.advance(self.get_ms / 1e3)   # span-ok: caller-owned span
        return self.inner.get(doc_id)

    def delete(self, doc_id: int) -> None:
        self.clock.advance(self.delete_ms / 1e3)  # span-ok: caller-owned span
        self.inner.delete(doc_id)

    def scan(self, category: str | None = None) -> list[Document]:
        # one bulk round trip, not one per document
        self.clock.advance(self.get_ms / 1e3)   # span-ok: caller-owned span
        return self.inner.scan(category)

    def __len__(self) -> int:
        return len(self.inner)


class FlakyStore(DocumentStore):
    """Injects scheduled transient failures in front of any store.

    Every operation first consults the shared ``FaultInjector`` (which
    counts ops globally and raises ``TransientStoreError`` on scheduled
    indices), then delegates. With an inert injector the consult is a
    no-op and behavior is identical to the inner store — the
    empty-schedule baseline gate depends on that.
    """

    def __init__(self, inner: DocumentStore, faults: FaultInjector):
        self.inner = inner
        self.faults = faults

    def put(self, doc: Document) -> None:
        self.faults.store_op("put")
        self.inner.put(doc)

    def put_many(self, docs: list[Document]) -> None:
        # one batched round trip = one failure opportunity
        self.faults.store_op("put")
        self.inner.put_many(docs)

    def get(self, doc_id: int) -> Document | None:
        self.faults.store_op("get")
        return self.inner.get(doc_id)

    def delete(self, doc_id: int) -> None:
        self.faults.store_op("delete")
        self.inner.delete(doc_id)

    def scan(self, category: str | None = None) -> list[Document]:
        # recovery/bulk path: not indexed into the per-op fault schedule
        # (op indices name hot-path gets/puts, and a recovery scan racing
        # the schedule would make crash sweeps non-enumerable)
        return self.inner.scan(category)

    def __len__(self) -> int:
        return len(self.inner)


class RetryingStore(DocumentStore):
    """Bounded retries + deterministic Clock-charged backoff + a per-call
    latency budget over any store.

    A failed operation (``TransientStoreError`` from the inner store)
    retries up to ``retries`` times with exponential backoff
    ``backoff_ms · 2^attempt`` charged on the injected ``Clock`` — on a
    ``SimClock`` that is simulated latency, never a wall-clock sleep, so
    retry behavior is deterministic in tests and benchmarks. Retrying
    stops early once the CUMULATIVE backoff would exceed ``budget_ms``
    (the per-lookup latency budget: a cache hit that needs the external
    doc is only worth so much stall). Exhaustion — by retry count or by
    budget — raises ``StoreTimeout``; ``SemanticCache.lookup_batch``
    catches it on the hit path and degrades the lookup to a
    served-from-model miss with a ``store_timeouts`` counter, keeping
    the entry resident (the fault was transient, not data loss).

    ``stats`` counts retries/timeouts/backoff per op kind — all
    deterministic under a fixed schedule.
    """

    def __init__(self, inner: DocumentStore, clock: Clock | None = None,
                 retries: int = 3, backoff_ms: float = 1.0,
                 budget_ms: float = 50.0, obs=None):
        self.inner = inner
        self.clock = clock or SimClock()
        self.retries = int(retries)
        self.backoff_ms = float(backoff_ms)
        self.budget_ms = float(budget_ms)
        # Optional TraceRecorder: retries/timeouts land on the event
        # stream; the backoff charge itself is timed by whichever span
        # the caller has open (store_fetch / write / migration_copy).
        self.obs = obs
        self.stats = {"get_retries": 0, "put_retries": 0,
                      "delete_retries": 0, "get_timeouts": 0,
                      "put_timeouts": 0, "delete_timeouts": 0,
                      "backoff_ms_charged": 0.0}

    def _call(self, op: str, fn):
        spent = 0.0
        last: BaseException | None = None
        for attempt in range(self.retries + 1):
            try:
                return fn()
            except TransientStoreError as e:
                last = e
                wait = self.backoff_ms * (2.0 ** attempt)
                if attempt >= self.retries or spent + wait > self.budget_ms:
                    break
                spent += wait
                self.stats[f"{op}_retries"] += 1
                self.stats["backoff_ms_charged"] += wait
                if self.obs is not None:
                    self.obs.event("store_retry", op=op, attempt=attempt,
                                   wait_ms=wait)
                self.clock.advance(wait / 1e3)  # span-ok: caller-owned span
        self.stats[f"{op}_timeouts"] += 1
        if self.obs is not None:
            self.obs.event("store_timeout_raised", op=op)
        raise StoreTimeout(op) from last

    def put(self, doc: Document) -> None:
        self._call("put", lambda: self.inner.put(doc))

    def put_many(self, docs: list[Document]) -> None:
        self._call("put", lambda: self.inner.put_many(docs))

    def get(self, doc_id: int) -> Document | None:
        return self._call("get", lambda: self.inner.get(doc_id))

    def delete(self, doc_id: int) -> None:
        self._call("delete", lambda: self.inner.delete(doc_id))

    def scan(self, category: str | None = None) -> list[Document]:
        return self.inner.scan(category)

    def __len__(self) -> int:
        return len(self.inner)


# ---------------------------------------------------------------------------
# Baseline: remote vector database (what the paper argues against).
# ---------------------------------------------------------------------------

class VectorDBEmulator:
    """Coupled remote search + storage with the paper's cost structure.

    Architectural constraints faithfully reproduced (§4):
      * every query pays ``search_ms`` network+server cost, hit or miss (§4.4)
      * ONE collection-level threshold; per-category thresholds are not
        supported (§4.2) — caller gets the raw top-1 and the collection
        threshold is applied post-search (§4.1)
      * TTL enforced server-side AFTER fetching the document, wasting the
        fetch on expired entries (§4.3)
    """

    def __init__(self, dim: int, capacity: int, clock: Clock | None = None,
                 collection_threshold: float = 0.85, collection_ttl: float = 3600.0,
                 search_ms: float = 30.0, fetch_ms: float = 5.0, insert_ms: float = 10.0):
        from repro.core.hnsw import FlatIndex  # exact search server-side
        self.index = FlatIndex(dim, capacity)
        self.docs: dict[int, Document] = {}
        self.slot_doc: dict[int, int] = {}
        self.created: dict[int, float] = {}
        self.clock = clock or SimClock()
        self.collection_threshold = collection_threshold
        self.collection_ttl = collection_ttl
        self.search_ms = search_ms
        self.fetch_ms = fetch_ms
        self.insert_ms = insert_ms
        self._next_doc = 0

    def __len__(self) -> int:
        return len(self.index)

    def query(self, emb: np.ndarray) -> Document | None:
        """Remote search → post-search threshold → fetch → server TTL check."""
        self.clock.advance(self.search_ms / 1e3)  # span-ok: untraced baseline
        idx, score = self.index.search_host(emb[None, :], np.array([-np.inf]))
        slot, score = int(idx[0]), float(score[0])
        if slot < 0 or score < self.collection_threshold:  # §4.1 post-search
            return None
        self.clock.advance(self.fetch_ms / 1e3)   # span-ok: untraced baseline
        doc_id = self.slot_doc[slot]
        if self.clock.now() - self.created[slot] > self.collection_ttl:  # §4.3
            self._evict(slot)
            return None
        return self.docs.get(doc_id)

    def insert(self, emb: np.ndarray, doc: Document) -> None:
        self.clock.advance(self.insert_ms / 1e3)  # span-ok: untraced baseline
        if len(self.index) >= self.index.capacity:
            oldest = min(self.created, key=self.created.get)
            self._evict(oldest)
        slot = self.index.add(emb)
        doc = Document(self._next_doc, doc.request, doc.response,
                       self.clock.now(), doc.category, doc.meta)
        self._next_doc += 1
        self.docs[doc.doc_id] = doc
        self.slot_doc[slot] = doc.doc_id
        self.created[slot] = doc.created_at

    def _evict(self, slot: int) -> None:
        self.index.remove(slot)
        doc_id = self.slot_doc.pop(slot, None)
        if doc_id is not None:
            self.docs.pop(doc_id, None)
        self.created.pop(slot, None)
