"""The paper's primary contribution: category-aware semantic caching.

Modules:
    policy     — per-category configs + adaptive load-based controller (§3, §7.5)
    admission  — frequency-sketch admission control + cost-aware eviction
                 scoring (expected-hits × miss-cost per resident byte)
    embedding  — 384-d feature-hash embedder + synthetic category spaces (§3.1)
    hnsw       — TPU-adapted batched-frontier HNSW index (§5, §5.3)
    cache      — hybrid cache: Algorithm 1 lookup, insert, evict, quotas (§5)
    shard      — sharded cache tier: quota-byte placement planner, fan-out
                 masked search, live category migration (§7.4 scaling)
    storage    — external document stores + vector-DB baseline emulator (§4)
    faults     — deterministic fault injection: shard outages, transient
                 store errors, migration crash points (degraded serving)
    economics  — break-even analysis, eqs (1)-(6) (§4.4, §5.5, §7.5.1)
    workload   — heterogeneous category workload generator (Table 1)
    metrics    — per-category statistics
    clock      — simulated / wall clocks
"""

from repro.core.policy import (  # noqa: F401
    CategoryConfig,
    PolicyEngine,
    AdaptiveController,
    LoadSignal,
)
from repro.core.admission import (  # noqa: F401
    AdmissionController,
    CategoryTracker,
    FrequencySketch,
    QueryFingerprinter,
    CostAwareEvictionScorer,
    StaticEvictionScorer,
)
from repro.core.cache import SemanticCache, CacheResult  # noqa: F401
from repro.core.shard import (  # noqa: F401
    ShardPlanner,
    ShardedSemanticCache,
    CategoryMigration,
    OutageRebalance,
    crc32_shard,
)
from repro.core.economics import (  # noqa: F401
    break_even_hit_rate,
    expected_latency,
    CostModel,
    HYBRID_COSTS,
    VDB_COSTS,
)
from repro.core.embedding import FeatureHashEmbedder, SyntheticCategorySpace  # noqa: F401
from repro.core.hnsw import HNSWIndex, FlatIndex  # noqa: F401
from repro.core.storage import (  # noqa: F401
    InMemoryStore,
    FileStore,
    LatencyModelStore,
    FlakyStore,
    RetryingStore,
    VectorDBEmulator,
)
from repro.core.faults import (  # noqa: F401
    FaultInjector,
    FaultSchedule,
    InjectedCrash,
    StoreTimeout,
    TransientStoreError,
)
from repro.core.workload import WorkloadGenerator, CategorySpec, TABLE1_WORKLOAD  # noqa: F401
from repro.core.clock import SimClock, WallClock  # noqa: F401
