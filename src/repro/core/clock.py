"""Clocks.

Benchmarks and the serving simulator run on a simulated clock so that
"30 ms remote search" style costs are charged without wall-clock sleeps,
while live serving uses the wall clock.
"""

from __future__ import annotations

import time


class Clock:
    def now(self) -> float:
        raise NotImplementedError

    def advance(self, seconds: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Real time. ``advance`` sleeps (used only in live serving tests)."""

    def now(self) -> float:
        return time.monotonic()

    def advance(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class SimClock(Clock):
    """Manually advanced clock for discrete-event simulation."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"cannot advance clock backwards by {seconds}")
        self._t += seconds
