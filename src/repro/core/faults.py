"""Deterministic fault injection for the sharded serving stack.

The paper's hybrid architecture keeps low-hit-rate categories viable
because the miss path is cheap and the cache is *always available* — so
the repro's availability story has to be engineered, not assumed
("Rethinking Caching for LLM Serving Systems", PAPERS.md: serving caches
are systems components with explicit cost AND availability behavior).
This module is the control knob: a schedule-driven ``FaultInjector``
that the sharded cache, the store wrappers and the migration protocol
consult at well-defined points, so every failure mode the degraded-mode
tests exercise is reproducible bit-for-bit:

``FaultSchedule``
    A plain declarative schedule — no randomness at fire time:

    * ``shard_outages`` — ``(start_s, end_s, shard_id)`` windows in
      simulated-clock seconds: the shard's index is unreachable inside
      ``[start, end)``. Lookups degrade to counted ``degraded_miss``es,
      writes land in the front door's bounded write-behind queue
      (core/shard.py).
    * ``store_get_failures`` / ``store_put_failures`` — 0-based
      operation indices (per op kind, counted on the injector across
      every wrapped store) that raise ``TransientStoreError``. Bounded
      runs of consecutive indices model a flaky store that retries
      absorb; runs longer than the retry budget exhaust it and surface
      as ``store_timeout`` (storage.RetryingStore).
    * ``crash_at`` — ``{site: visit_index}``: the visit_index-th visit
      to a named crash site raises ``InjectedCrash``. Sites are placed
      between migration protocol steps (core/shard.py
      ``CategoryMigration``), so "crash at every step index" is an
      enumerable sweep: dry-run, read ``visits(site)``, rerun once per
      index. A crash point fires AT MOST once per injector (it disarms
      itself), so recovery can re-traverse the same sites.

``FaultInjector``
    The runtime: counts operations/visits, applies the schedule. With
    an EMPTY schedule every hook is a no-op returning the non-fault
    answer — callers wired against an inert injector are bit-identical
    to callers with no injector at all (the ``bench_faults`` baseline
    gate).

The store-op error type (``TransientStoreError``) and the retry-budget
exhaustion type (``StoreTimeout``) live here so ``core/storage`` and
``core/cache`` share them without a dependency cycle.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

from repro.core.clock import Clock, SimClock


class InjectedCrash(Exception):
    """Raised at a scheduled crash point (models a process dying between
    two protocol steps — in-process state is NOT rolled back, exactly
    like a real crash leaves partial effects behind). Deliberately NOT a
    ``RuntimeError``: retry loops and the migration's target-full
    handler catch RuntimeError, and an injected crash must never be
    absorbed by either."""

    def __init__(self, site: str, visit: int):
        super().__init__(f"injected crash at {site!r} (visit {visit})")
        self.site = site
        self.visit = visit


class TransientStoreError(RuntimeError):
    """A single failed store operation (network blip, lease lost). The
    ``RetryingStore`` wrapper absorbs bounded runs of these."""


class StoreTimeout(RuntimeError):
    """Retry/latency budget exhausted on a store operation. The cache
    lookup path degrades a would-be hit into a served-from-model miss
    (counted ``store_timeouts``) instead of letting this escape."""

    def __init__(self, op: str):
        super().__init__(f"store {op} exhausted its retry budget")
        self.op = op


@dataclass
class FaultSchedule:
    """Declarative fault plan; empty (the default) means no faults."""

    # (start_s, end_s, shard_id) — shard unreachable for clock times in
    # [start_s, end_s). Same shape as SimConfig.load_spikes windows.
    shard_outages: list = field(default_factory=list)
    # 0-based per-kind operation indices that fail transiently.
    store_get_failures: frozenset = frozenset()
    store_put_failures: frozenset = frozenset()
    # site name -> visit index at which to crash (once).
    crash_at: dict = field(default_factory=dict)

    def __post_init__(self):
        self.store_get_failures = frozenset(self.store_get_failures)
        self.store_put_failures = frozenset(self.store_put_failures)

    @staticmethod
    def op_range(start: int, n: int) -> frozenset:
        """``n`` consecutive failing op indices starting at ``start``."""
        return frozenset(range(start, start + n))

    @property
    def empty(self) -> bool:
        return not (self.shard_outages or self.store_get_failures
                    or self.store_put_failures or self.crash_at)


class FaultInjector:
    """Applies a ``FaultSchedule`` deterministically.

    One injector is shared by every component of a serving stack (front
    door, per-shard store wrappers, migrations): the operation counters
    that index into the schedule are global, so a schedule names THE
    k-th store get of the run, not the k-th of one shard. Single-writer
    (the simulator/engine loop), no locking.
    """

    def __init__(self, schedule: FaultSchedule | None = None,
                 clock: Clock | None = None, obs=None):
        self.schedule = schedule or FaultSchedule()
        self.clock = clock or SimClock()
        self.active = not self.schedule.empty
        # Optional TraceRecorder (repro.obs): injected faults land on
        # the event stream so a degraded window is explainable. Never
        # consulted when the schedule is inert.
        self.obs = obs
        self._store_ops = {"get": 0, "put": 0, "delete": 0}
        self._visits: dict[str, int] = {}
        self._crashed: set[str] = set()
        self.injected = {"shard_down_checks": 0, "store_faults": 0,
                         "crashes": 0}

    # -- shard outages ---------------------------------------------------------
    def shard_down(self, shard: int) -> bool:
        """Is ``shard`` inside a scheduled outage window right now?"""
        if not self.active:
            return False
        now = self.clock.now()
        for (t0, t1, s) in self.schedule.shard_outages:
            if s == shard and t0 <= now < t1:
                self.injected["shard_down_checks"] += 1
                return True
        return False

    # -- store faults ----------------------------------------------------------
    def store_op(self, op: str) -> None:
        """Count one store operation; raise ``TransientStoreError`` when
        its index is scheduled to fail. Inert schedules count nothing,
        so wrapped and unwrapped stores behave identically."""
        if not self.active:
            return
        idx = self._store_ops.get(op, 0)
        self._store_ops[op] = idx + 1
        fails: Iterable[int] = ()
        if op == "get":
            fails = self.schedule.store_get_failures
        elif op == "put":
            fails = self.schedule.store_put_failures
        if idx in fails:
            self.injected["store_faults"] += 1
            if self.obs is not None:
                self.obs.event("injected_store_fault", op=op, op_index=idx)
            raise TransientStoreError(f"injected {op} fault (op {idx})")

    # -- crash points ----------------------------------------------------------
    def crash_point(self, site: str) -> None:
        """Count one visit to ``site``; raise ``InjectedCrash`` on the
        scheduled visit (at most once per site — recovery re-traverses
        the protocol without re-crashing)."""
        if not self.active:
            return
        visit = self._visits.get(site, 0)
        self._visits[site] = visit + 1
        if site in self._crashed:
            return
        target = self.schedule.crash_at.get(site)
        if target is not None and visit == target:
            self._crashed.add(site)
            self.injected["crashes"] += 1
            if self.obs is not None:
                self.obs.event("injected_crash", site=site, visit=visit)
            raise InjectedCrash(site, visit)

    def visits(self, site: str) -> int:
        """Visit count for a crash site (a no-crash dry run measures the
        enumerable crash-index space: ``range(visits(site))``)."""
        return self._visits.get(site, 0)

    # -- reporting -------------------------------------------------------------
    def stats(self) -> dict:
        return {"active": self.active,
                "store_ops": dict(self._store_ops),
                "crash_site_visits": dict(self._visits),
                **self.injected}
