"""Embedding providers (§3.1).

Two providers, both deterministic and offline:

``FeatureHashEmbedder``
    Character/word n-gram feature hashing into ``dim`` buckets with signed
    hashing, L2-normalized. Stable across processes (crc32-based, not
    Python's randomized ``hash``). Real text in → real 384-d vectors out.

``SyntheticCategorySpace``
    The controlled generator used by benchmarks: each category owns a set of
    cluster centers on the unit sphere; queries are ``center + sigma * noise``
    re-normalized. ``sigma`` (paraphrase spread) and the number of centers
    control *embedding-space density* — the paper's key category property
    (10th-NN distance ~0.12 for code vs ~0.38 for chat).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

EMBED_DIM = 384  # paper §5.1: 1.5 KB/entry at 384 dims (fp32)


def _stable_hash(token: str, salt: int = 0) -> int:
    return zlib.crc32((f"{salt}\x00" + token).encode("utf-8")) & 0xFFFFFFFF


class FeatureHashEmbedder:
    """Signed n-gram feature hashing. Deterministic, dependency-free."""

    def __init__(self, dim: int = EMBED_DIM, char_ngrams: tuple[int, ...] = (3, 4),
                 use_words: bool = True):
        self.dim = dim
        self.char_ngrams = char_ngrams
        self.use_words = use_words

    def _features(self, text: str) -> list[str]:
        text = text.lower().strip()
        feats: list[str] = []
        if self.use_words:
            feats.extend(w for w in text.split() if w)
        padded = f" {text} "
        for n in self.char_ngrams:
            feats.extend(padded[i:i + n] for i in range(max(0, len(padded) - n + 1)))
        return feats

    def embed(self, text: str) -> np.ndarray:
        vec = np.zeros(self.dim, dtype=np.float32)
        for feat in self._features(text):
            h = _stable_hash(feat)
            idx = h % self.dim
            sign = 1.0 if (h >> 31) & 1 else -1.0
            vec[idx] += sign
        norm = float(np.linalg.norm(vec))
        if norm > 0:
            vec /= norm
        return vec

    def embed_batch(self, texts: list[str]) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), dtype=np.float32)
        return np.stack([self.embed(t) for t in texts])


def _unit(v: np.ndarray, axis: int = -1) -> np.ndarray:
    n = np.linalg.norm(v, axis=axis, keepdims=True)
    return v / np.maximum(n, 1e-12)


@dataclass
class SyntheticCategorySpace:
    """Controlled-density embedding space for one category.

    ``n_centers`` distinct semantic intents; ``sigma`` paraphrase noise.
    Dense (code-like) spaces: many nearby centers, small sigma.
    Sparse (chat-like) spaces: spread-out centers, larger sigma.

    ``center_spread`` < 1 concentrates the centers themselves around a
    category anchor, producing the dense cluster geometry where a loose
    threshold causes cross-intent false positives (§3.1). Centers get a
    per-center spread jitter so cross-intent similarities are dispersed
    (graded FP-vs-τ curves rather than a cliff).

    Paraphrases are a two-component mixture: most rephrasings stay tight
    (σ), a ``loose_frac`` minority drifts further (σ·loose_mult) — the
    sub-threshold mass that §7.5.2's threshold relaxation recovers.
    """

    name: str
    n_centers: int
    sigma: float
    center_spread: float = 1.0
    loose_frac: float = 0.30
    loose_mult: float = 2.4
    dim: int = EMBED_DIM
    seed: int = 0
    _centers: np.ndarray = field(init=False, repr=False, default=None)

    def __post_init__(self):
        rng = np.random.default_rng(
            zlib.crc32(self.name.encode()) ^ self.seed)
        anchor = _unit(rng.standard_normal(self.dim))
        raw = rng.standard_normal((self.n_centers, self.dim))
        # Per-center spread jitter disperses the cross-intent sims.
        w = self.center_spread * rng.uniform(0.85, 1.30, (self.n_centers, 1))
        mixed = w * raw + (1.0 - w) * anchor * np.sqrt(self.dim)
        self._centers = _unit(mixed).astype(np.float32)
        self._rng = rng

    @property
    def centers(self) -> np.ndarray:
        return self._centers

    def _sigmas(self, n: int, rng: np.random.Generator) -> np.ndarray:
        loose = rng.random(n) < self.loose_frac
        return np.where(loose, self.sigma * self.loose_mult, self.sigma)

    def sample(self, intent_id: int, rng: np.random.Generator | None = None) -> np.ndarray:
        """One paraphrase of intent ``intent_id``."""
        rng = rng or self._rng
        c = self._centers[intent_id % self.n_centers]
        sig = self._sigmas(1, rng)[0]
        noisy = c + sig * rng.standard_normal(self.dim).astype(np.float32)
        return _unit(noisy).astype(np.float32)

    def sample_batch(self, intent_ids: np.ndarray, rng: np.random.Generator | None = None) -> np.ndarray:
        rng = rng or self._rng
        c = self._centers[np.asarray(intent_ids) % self.n_centers]
        sig = self._sigmas(c.shape[0], rng)[:, None].astype(np.float32)
        noisy = c + sig * rng.standard_normal(c.shape).astype(np.float32)
        return _unit(noisy).astype(np.float32)

    def nn_distance_profile(self, k: int = 10, n_probe: int = 256,
                            rng: np.random.Generator | None = None) -> float:
        """Mean cosine *distance* to the k-th NN among sampled queries.

        Reproduces the paper's density characterization (§3.1): ~0.12 for
        dense code spaces, ~0.38 for sparse conversational spaces.
        """
        rng = rng or np.random.default_rng(1234)
        ids = rng.integers(0, self.n_centers, size=n_probe)
        pts = self.sample_batch(ids, rng)
        sims = pts @ pts.T
        np.fill_diagonal(sims, -np.inf)
        kth = np.sort(sims, axis=1)[:, -k]
        return float(np.mean(1.0 - kth))


def make_dense_space(name: str = "code", seed: int = 0) -> SyntheticCategorySpace:
    """Code-like: constrained vocabulary → tight clusters.

    Calibrated (384-d): tight paraphrase cos ≈ 0.97, loose ≈ 0.87,
    cross-intent max-sim quartiles ≈ 0.82–0.92, 10th-NN distance ≈ 0.15
    (paper §3.1 ≈ 0.12) — τ=0.80 produces graded cross-intent false
    positives that τ=0.90 suppresses, and the loose-paraphrase mass in
    (0.85, 0.90) is what §7.5.2 threshold relaxation recovers.
    """
    return SyntheticCategorySpace(name=name, n_centers=2000, sigma=0.012,
                                  center_spread=0.25, loose_frac=0.30,
                                  loose_mult=2.4, seed=seed)


def make_sparse_space(name: str = "chat", seed: int = 0) -> SyntheticCategorySpace:
    """Conversation-like: varied phrasing → sparse clusters.

    Calibrated (384-d): paraphrase cos ≈ 0.92 (tight) / 0.83 (loose),
    cross-intent max ≈ 0.65, 10th-NN distance ≈ 0.35 (paper §3.1 ≈ 0.38) —
    τ=0.80 misses loose paraphrases, τ=0.75 captures them FP-free.
    """
    return SyntheticCategorySpace(name=name, n_centers=2000, sigma=0.022,
                                  center_spread=0.36, loose_frac=0.30,
                                  loose_mult=1.5, seed=seed)
