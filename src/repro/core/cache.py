"""The hybrid category-aware semantic cache (paper §5, Algorithm 1).

In-memory index (HNSW or flat) over embeddings + per-slot category metadata;
documents live in an external ``DocumentStore`` reached by primary key only
on fresh, above-threshold hits. Policy enforcement points (§5.4):

    compliance  — before anything (Algorithm 1 line 5): restricted
                  categories never enter the cache, no temporary presence
    threshold   — during traversal (per-query τ vector, §5.3)
    isolation   — during traversal (per-query category vector, §5.3): the
                  index masks results by category, so the best SAME-category
                  match is returned — a nearer cross-category neighbor can
                  route the search but never produce a false miss
    TTL         — after match, BEFORE external fetch (line 18): expired
                  entries evict without wasting a network call
    quota       — at insertion: per-category share of capacity
    eviction    — score = priority × 1/age × hitRate (§5.4); lowest evicted

Extensions implemented from §7.6: hot-document L1 (in-memory docs for the
power-law head → hit latency 7 ms → 2 ms).

**Quantized residency + fp32 re-rank tier.** With ``emb_dtype="int8"``
the device-resident embedding tier is int8 (per-slot symmetric scales,
see core/hnsw.py) — ~4x fewer bytes per sync/gather and ~4x more entries
per quota byte. Mirroring the paper's hybrid split (compact in-memory
search structure vs external document storage), the full-precision fp32
embedding lives NEXT TO the document in the ``DocumentStore``: a device
result whose quantized score lands within the per-category
``rerank_margin`` of τ is exactly re-scored from that stored fp32 copy
before the hit/miss decision — both directions (a borderline "hit" can
demote to a miss, a borderline miss whose best candidate sits just under
τ can promote to a hit). Quantization therefore changes latency only:
the decision for the returned candidate always matches the fp32 oracle.
(Scope: the re-rank covers the ONE best candidate the device search
returns. If two same-category entries' exact scores straddle τ while
sitting within quantization error (~1e-3) of EACH OTHER, the quantized
search may surface the other member of the near-tie — the decision is
then exact for that candidate but can differ from an exact-search
oracle. That needs a near-tie exactly at τ; the τ-boundary property
test pins the guarantee for separated entries.)

The write path is batched end-to-end: ``insert_batch`` runs one eviction
scoring pass, one ``store.put_many`` pass and one ``index.add_batch`` pass
for B entries, whose dirty rows coalesce into a single device delta flush
on the next search (see core/hnsw.py device residency). ``insert`` is a
B=1 wrapper — there is only one write path.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

from repro.core.admission import AdmissionController, make_eviction_scorer
from repro.core.clock import Clock, SimClock
from repro.core.faults import StoreTimeout
from repro.core.hnsw import CLS_EXPIRED, CLS_HIT, CLS_MISS, FlatIndex, \
    HNSWIndex, HNSWParams, INVALID
from repro.core.metrics import MetricsRegistry
from repro.core.policy import PolicyEngine
from repro.core.storage import Document, DocumentStore, InMemoryStore
from repro.obs.trace import NULL_SPAN


@dataclass
class CacheResult:
    hit: bool
    response: str | None = None
    score: float = float("-inf")
    category: str = ""
    slot: int = INVALID
    doc_id: int = INVALID
    reason: str = ""        # "hit" | "hit_l1" | "compliance" | "no_match" | "expired"
    latency_ms: float = 0.0
    meta: dict = field(default_factory=dict)


class SemanticCache:
    """Category-aware hybrid semantic cache.

    ``index_kind``: "hnsw" (default) or "flat" (exact; small caches).
    ``use_device``: route batched lookups through the jitted beam search
    (TPU data plane); otherwise the host search is used (CPU benchmarks).
    ``emb_dtype``: the device-resident embedding dtype — "float32" (the
    exact baseline) or "int8" (quantized residency: ~4x fewer bytes per
    sync/gather, with the fp32 re-rank tier deciding borderline matches
    from the embedding stored next to the document).
    """

    def __init__(self, policies: PolicyEngine, dim: int = 384,
                 capacity: int = 65536, store: DocumentStore | None = None,
                 clock: Clock | None = None, index_kind: str = "hnsw",
                 use_device: bool = False, search_ms: float = 2.0,
                 insert_ms: float = 1.0, l1_capacity: int = 0,
                 seed: int = 0, emb_dtype: str = "float32",
                 quota_capacity: int | None = None,
                 doc_id_start: int = 0, doc_id_step: int = 1,
                 eviction: str = "static",
                 durable_embeddings: bool = False,
                 obs=None, obs_shard: int = 0):
        self.policies = policies
        # Observability (repro.obs.TraceRecorder or None). When None,
        # every instrumented site goes through the shared no-op span —
        # the empty-recorder parity contract: counters, device bytes
        # and clock charges are bit-identical to the untraced build.
        self.obs = obs
        self._obs_shard = obs_shard
        self.dim = dim
        self.capacity = capacity
        # Quota ceilings are fractions of ``quota_capacity`` (default: the
        # physical capacity). A shard of a ShardedSemanticCache passes the
        # GLOBAL capacity here so a category keeps the same entry ceiling
        # (int(quota · total)) it would have in one unsharded cache, while
        # ``capacity`` stays the shard's own preallocated table size.
        self.quota_capacity = capacity if quota_capacity is None \
            else quota_capacity
        # Doc ids stride so N shards sharing a workload mint disjoint id
        # sequences (shard i starts at i, steps by N) — CacheResult.doc_id
        # stays globally unique without a shared id service.
        self._doc_id_step = doc_id_step
        self.clock = clock or SimClock()
        self.store = store if store is not None else InMemoryStore()
        self.use_device = use_device
        self.search_ms = search_ms
        self.insert_ms = insert_ms
        # Persist the fp32 embedding next to EVERY document, not just
        # under quantized residency: a fault-tolerant tier (sharded cache
        # with an injector wired) needs the store alone to be sufficient
        # to rebuild a dead shard's resident set (outage rebalancing),
        # and the resident index of a down shard is by definition
        # unreachable. Costs store bytes only — no counter, decision or
        # clock charge depends on it.
        self.durable_embeddings = durable_embeddings
        self.metrics = MetricsRegistry()
        # Eviction scorer (core/admission.py): "static" = the §5.4
        # priority × 1/age × hitRate formula (seed behavior, default);
        # "cost_aware" prices slots by expected-hits × miss-cost per
        # resident byte (economics.ResidencyModel).
        self.eviction = eviction
        self._evictor = make_eviction_scorer(eviction)
        # Admission control plane: per-category repetition sketches,
        # lazily built and seeded from the category NAME, so shards of a
        # sharded cache reach identical admission decisions. Consulted
        # only for categories with admit_after > 1 — zero cost otherwise.
        self.admission = AdmissionController(dim)

        if index_kind == "hnsw":
            self.index: HNSWIndex | FlatIndex = HNSWIndex(
                dim, capacity, params=HNSWParams(emb_dtype=emb_dtype),
                seed=seed)
        elif index_kind == "flat":
            # FlatIndex has a first-class device path too (the flat_topk
            # kernel via ops.cache_topk), so use_device is legal here.
            self.index = FlatIndex(dim, capacity, emb_dtype=emb_dtype)
        else:
            raise ValueError(f"unknown index_kind {index_kind!r}")

        # Per-slot metadata (§5.1: ~112 B/entry overhead). The category
        # and insertion-time tables LIVE IN THE INDEX (category is a
        # search input, §5.3; insertion time feeds the on-device TTL
        # classification and rides the same delta-sync protocol);
        # ``slot_category``/``slot_inserted`` alias them so cache-side
        # bookkeeping and the index/device mirror never diverge.
        self.slot_category = self.index.category
        self.slot_inserted = self.index.inserted
        # The inserted table is float32 (the device dtype — jax runs with
        # x64 disabled), whose spacing at epoch-scale absolute times
        # (~1.7e9 s) is minutes. All cache-internal timestamps are
        # therefore REBASED to the cache's construction instant: ages and
        # TTL comparisons only ever see small relative values, so float32
        # keeps sub-millisecond resolution for any realistic clock.
        self._t0 = self.clock.now()
        self.slot_hits = np.zeros(capacity, np.int64)
        self.slot_doc = np.full(capacity, INVALID, np.int64)
        self.slot_valid = np.zeros(capacity, bool)
        self._cat_names: dict[int, str] = {}
        self._next_doc_id = doc_id_start
        # Device-search observability (hops, rows gathered) from the last
        # lookup_batch, materialized at the single host-conversion point.
        self.last_lookup_stats: dict = {}
        # Write-path observability from the last insert_batch: batch
        # size, items past the compliance gate, admission skips.
        self.last_insert_stats: dict = {}

        # §7.6 hot-document L1: doc_id -> response, LRU by insertion order
        # (move-to-end on touch, evict from the front) — O(1) per hit.
        self.l1_capacity = l1_capacity
        self._l1: OrderedDict[int, str] = OrderedDict()

    # ------------------------------------------------------------------ utils
    def __len__(self) -> int:
        return int(self.slot_valid.sum())

    def _now(self) -> float:
        """Cache-relative time (see ``_t0``): what slot_inserted stores
        and every TTL/age comparison uses, host and device alike."""
        return self.clock.now() - self._t0

    def _cat_id(self, name: str) -> int:
        cid = self.policies.category_id(name)
        self._cat_names[cid] = name
        return cid

    def _span(self, stage: str, **attrs):
        """Clock-timed span when a ``TraceRecorder`` is attached; the
        shared no-op span otherwise (tracing off leaves the hot path
        untouched)."""
        if self.obs is None:
            return NULL_SPAN
        return self.obs.span(stage, shard=self._obs_shard, **attrs)

    def _event(self, name: str, **fields) -> None:
        if self.obs is not None:
            self.obs.event(name, shard=self._obs_shard, **fields)

    def category_count(self, name: str) -> int:
        cid = self.policies.category_id(name)
        return int((self.slot_valid & (self.slot_category == cid)).sum())

    # -------------------------------------------------------------- Algorithm 1
    def lookup(self, embedding: np.ndarray, category: str) -> CacheResult:
        return self.lookup_batch(embedding[None, :], [category])[0]

    def lookup_batch(self, embeddings: np.ndarray,
                     categories: Sequence[str]) -> list[CacheResult]:
        """Vectorized Algorithm 1 over a mixed-category batch."""
        with self._span("lookup", batch=int(embeddings.shape[0])):
            return self._lookup_batch_impl(embeddings, categories)

    def _lookup_batch_impl(self, embeddings: np.ndarray,
                           categories: Sequence[str]) -> list[CacheResult]:
        B = embeddings.shape[0]
        assert len(categories) == B
        now = self._now()
        self.last_lookup_stats = {}
        results: list[CacheResult] = [None] * B  # type: ignore[list-item]
        rerank_docs: dict[int, Document] = {}   # docs the re-rank fetched

        # Line 4-7: per-category config + compliance gate.
        effective = [self.policies.effective(c) for c in categories]
        active = [i for i in range(B) if effective[i].allow_caching]
        for i in range(B):
            st = self.metrics.cat(categories[i])
            st.lookups += 1
            if not effective[i].allow_caching:
                st.compliance_rejects += 1
                st.misses += 1
                results[i] = CacheResult(False, category=categories[i],
                                         reason="compliance")
        if not active:
            return results

        # Line 9-11: search with per-query thresholds AND categories DURING
        # traversal (§5.3). The index masks results by category, so the
        # returned neighbor is the best SAME-category match — a globally
        # nearer cross-category entry can route traffic but never shadows a
        # valid match (the seed's "category_mismatch" false-miss path is
        # gone by construction).
        # Span "search" covers the search-latency charge, the index
        # traversal and the single device→host sync; the fp32 re-rank
        # tier gets a SIBLING span so its borderline store fetches are
        # attributed separately from the traversal.
        with self._span("search", batch=len(active)):
            self.clock.advance(self.search_ms / 1e3)
            q = embeddings[active]
            taus = np.asarray([effective[i].threshold for i in active],
                              np.float32)
            qcats = np.asarray([self._cat_id(categories[i]) for i in active],
                               np.int32)
            ttls = np.asarray([effective[i].ttl for i in active], np.float64)
            if self.use_device:
                # Line 12-21 classification runs INSIDE the jitted search
                # (the synced ``inserted`` table + per-query TTL/now), so
                # the only host sync is this single device_get — the
                # Python below then touches actual hits (doc fetch) and
                # expirations (evict), not all B results.
                d_idx, d_score, d_cls, d_cand = self.index.search_classified(
                    q, taus, categories=qcats, ttls=ttls, now=now)
                ls = self.index.last_search
                idxs, scores, cls, cands, hops, rows = jax.device_get(
                    (d_idx, d_score, d_cls, d_cand, ls.get("hops", 0),
                     ls.get("rows_gathered", 0)))
                idxs = np.asarray(idxs, np.int64)
                scores = np.asarray(scores, np.float64)
                cls = np.array(cls)    # writable: the re-rank tier may edit
            else:
                idxs, scores = self.index.search_host(q, taus,
                                                      categories=qcats)
                # Host path: same vectorized classification in numpy.
                idxs = np.asarray(idxs, np.int64)
                scores = np.asarray(scores, np.float64)
                safe = np.maximum(idxs, 0)
                found = (idxs != INVALID) & self.slot_valid[safe]
                expired = found & ((now - self.slot_inserted[safe]) > ttls)
                cls = np.where(expired, CLS_EXPIRED,
                               np.where(found, CLS_HIT, CLS_MISS))
        if self.use_device:
            reranks = 0
            if self.index.quantized:
                # The fp32 re-rank tier: borderline quantized scores are
                # re-decided against the exact embedding stored next to
                # the document (may rewrite idxs/scores/cls in place;
                # fetched docs land in rerank_docs so a promoted hit
                # does not fetch the same document twice).
                with self._span("rerank", batch=len(active)):
                    reranks = self._rerank_boundary(
                        q, idxs, scores, cls, np.asarray(cands, np.int64),
                        taus, ttls, now, [effective[i] for i in active],
                        [categories[i] for i in active], rerank_docs)
            row_bytes = ls.get("gather_row_nbytes",
                               self.index.emb_row_nbytes())
            self.last_lookup_stats = {
                "batch": len(active), "hops": int(hops),
                "rows_gathered": int(np.sum(rows)),
                "gathered_bytes": int(np.sum(rows)) * row_bytes,
                "emb_dtype": self.index.emb_dtype,
                "reranks": reranks}
        hit = cls == CLS_HIT
        np.add.at(self.slot_hits, idxs[hit], 1)   # duplicate slots accumulate

        for pos, i in enumerate(active):
            cat = categories[i]
            st = self.metrics.cat(cat)
            slot, score = int(idxs[pos]), float(scores[pos])

            # Line 12-14: miss → return immediately, no external access.
            if cls[pos] == CLS_MISS:
                st.misses += 1
                results[i] = CacheResult(False, score=score, category=cat,
                                         reason="no_match",
                                         latency_ms=self.search_ms)
                continue

            # Line 18-21: TTL validated BEFORE the external fetch. Duplicate
            # matches of one slot within a batch evict (and count) once.
            if cls[pos] == CLS_EXPIRED:
                if self.slot_valid[slot]:
                    self._evict_slot(slot, reason="ttl")
                    st.ttl_evictions += 1
                st.misses += 1
                results[i] = CacheResult(False, score=score, category=cat,
                                         reason="expired",
                                         latency_ms=self.search_ms)
                continue

            # Line 23-25: fetch by ID (L1 first — §7.6 extension).
            doc_id = int(self.slot_doc[slot])
            st.hits += 1
            if doc_id in self._l1:
                self._l1_touch(doc_id)
                results[i] = CacheResult(True, response=self._l1[doc_id],
                                         score=score, category=cat, slot=slot,
                                         doc_id=doc_id, reason="hit_l1",
                                         latency_ms=self.search_ms)
                continue
            try:
                doc = rerank_docs.get(doc_id)
                if doc is None:
                    # A StoreTimeout raised inside the span still closes
                    # it (context-manager unwind) before the rollback.
                    with self._span("store_fetch", category=cat):
                        doc = self.store.get(doc_id)
            except StoreTimeout:
                # Retry budget exhausted on a transient store fault: the
                # would-be hit degrades to a served-from-model miss. The
                # entry STAYS resident (unlike missing_doc — the data is
                # not lost, the store is slow) and the hit bookkeeping
                # rolls back so counters match the serving outcome.
                st.store_timeouts += 1
                self._event("store_timeout", category=cat)
                st.misses += 1
                st.hits -= 1
                self.slot_hits[slot] -= 1
                results[i] = CacheResult(False, score=score, category=cat,
                                         reason="store_timeout",
                                         latency_ms=self.search_ms)
                continue
            if doc is None:   # store lost the doc (crash recovery): treat as miss
                self._evict_slot(slot, reason="missing_doc")
                st.misses += 1
                st.hits -= 1
                self.slot_hits[slot] -= 1
                results[i] = CacheResult(False, score=score, category=cat,
                                         reason="missing_doc",
                                         latency_ms=self.search_ms)
                continue
            self._l1_maybe_promote(doc_id, doc.response, self.slot_hits[slot])
            results[i] = CacheResult(True, response=doc.response, score=score,
                                     category=cat, slot=slot, doc_id=doc_id,
                                     reason="hit", latency_ms=self.search_ms)
        return results

    # --------------------------------------------------------- fp32 re-rank tier
    def _exact_score(self, query: np.ndarray, slot: int,
                     doc_cache: dict) -> float:
        """Exact fp32 score of one candidate slot: the embedding stored
        next to the document (the external tier's ground truth), falling
        back to the index's host fp32 control-plane row if the store
        copy is missing (crash recovery).

        This is one keyed ``store.get`` — on latency-modeled stores the
        clock advances like any fetch, and it happens even when the
        re-rank resolves to a MISS. That is the re-rank tier's one
        deliberate exception to Algorithm 1's "miss → no external
        access": only borderline queries (|score − τ| ≤ margin, rare by
        construction) pay it, in exchange for exact decisions at the
        boundary. The fetched doc lands in ``doc_cache`` so a promoted
        hit serves its response without a second fetch.
        ``CacheResult.latency_ms`` stays the search cost (as it does for
        ordinary hit fetches); the clock and the ``reranks`` counters
        carry the fetch accounting."""
        emb = None
        doc_id = int(self.slot_doc[slot])
        if doc_id != INVALID:
            try:
                doc = self.store.get(doc_id)
            except StoreTimeout:
                # Transient store fault mid-re-rank: the host fp32
                # control-plane row is the same exact embedding, so the
                # decision stays exact without the external fetch.
                doc = None
            if doc is not None:
                doc_cache[doc_id] = doc
                emb = doc.embedding_array()
        if emb is None:
            emb = self.index.emb[slot]
        return float(np.asarray(query, np.float32) @ emb)

    def _rerank_boundary(self, q: np.ndarray, idxs: np.ndarray,
                         scores: np.ndarray, cls: np.ndarray,
                         cands: np.ndarray, taus: np.ndarray,
                         ttls: np.ndarray, now: float,
                         effs: list, cats: list[str],
                         doc_cache: dict) -> int:
        """Re-decide borderline quantized results against fp32 (mutates
        idxs/scores/cls in place; returns the re-score count).

        A query is borderline when its best same-category candidate's
        quantized score lands within the category's ``rerank_margin`` of
        its τ — on EITHER side, so both false hits (quantized score
        crept over τ) and false misses (crept under) are corrected. The
        margin need only cover the int8 error (~1e-3 for unit rows), so
        re-scores stay rare; the decision then exactly matches the fp32
        oracle, with the TTL check reapplied to promoted hits."""
        n = 0
        for pos in range(len(cands)):
            margin = effs[pos].rerank_margin
            slot = int(cands[pos])
            if margin <= 0.0 or slot == INVALID or not self.slot_valid[slot]:
                continue
            if abs(float(scores[pos]) - float(taus[pos])) > margin:
                continue
            exact = self._exact_score(q[pos], slot, doc_cache)
            st = self.metrics.cat(cats[pos])
            st.reranks += 1
            n += 1
            hit = exact >= float(taus[pos])
            if hit != (cls[pos] != CLS_MISS):
                st.rerank_flips += 1
            scores[pos] = exact
            if hit:
                expired = (now - self.slot_inserted[slot]) > ttls[pos]
                cls[pos] = CLS_EXPIRED if expired else CLS_HIT
                idxs[pos] = slot
            else:
                cls[pos] = CLS_MISS
                idxs[pos] = INVALID
        return n

    # ------------------------------------------------------------------ insert
    def insert(self, embedding: np.ndarray, category: str, request: str,
               response: str, meta: dict | None = None) -> int:
        """Insert one (query → response) pair. Returns slot id or INVALID.

        Thin wrapper over ``insert_batch`` — the batched write path is the
        ONLY write path, so single inserts and batch inserts share policy
        enforcement, store writes and the index delta log.
        """
        return self.insert_batch(np.asarray(embedding)[None, :], [category],
                                 [request], [response], [meta])[0]

    def insert_batch(self, embeddings: np.ndarray,
                     categories: Sequence[str], requests: Sequence[str],
                     responses: Sequence[str],
                     metas: Sequence[dict | None] | None = None) -> list[int]:
        """Insert B (query → response) pairs in one write round.

        Enforcement matches the sequential semantics item by item —
        compliance pre-insertion (§5.4: restricted categories never create
        temporary data presence), per-category quota, global capacity
        eviction by economic score — but the batch pays batched costs:

        * ONE eviction-scoring pass (§5.4 score = priority × 1/age ×
          hitRate) over the live slots, updated incrementally as victims
          fall, instead of a per-item rescore;
        * ONE ``store.put_many`` pass for all accepted documents;
        * ONE index write pass (``index.add_batch``) whose touched rows
          coalesce into a single device delta flush on the next search.

        Returns a slot id per item; INVALID for compliance-rejected items
        and for items evicted *within the batch* by a later item's quota or
        capacity pressure (they count as inserted-then-evicted in metrics,
        matching the sequential path, but never touch the store or index).
        """
        embeddings = np.atleast_2d(np.asarray(embeddings, np.float32))
        with self._span("insert", batch=int(embeddings.shape[0])):
            return self._insert_batch_impl(embeddings, categories,
                                           requests, responses, metas)

    def _insert_batch_impl(self, embeddings, categories, requests,
                           responses, metas) -> list[int]:
        B = embeddings.shape[0]
        metas = list(metas) if metas is not None else [None] * B
        if not (len(categories) == len(requests) == len(responses)
                == len(metas) == B):
            raise ValueError("insert_batch: ragged batch")
        slots_out = [INVALID] * B

        # Compliance gate (one policy resolution per distinct category).
        eff = {c: self.policies.effective(c) for c in dict.fromkeys(categories)}
        admitted = []
        for i, c in enumerate(categories):
            if not eff[c].allow_caching or eff[c].quota <= 0.0:
                self.metrics.cat(c).insert_rejects += 1
            else:
                admitted.append(i)
        if not admitted:
            self.last_insert_stats = {"batch": B, "admitted": 0,
                                      "admission_skips": 0,
                                      "insert_rejects": B}
            return slots_out

        # Span "gate": the batched write-round charge plus the admission
        # sketch pass — everything that decides WHAT gets to spend quota.
        with self._span("gate", batch=len(admitted)):
            self.clock.advance(self.insert_ms / 1e3)  # one batched write round
            now = self._now()
            cids = {c: self._cat_id(c) for c in eff}

            # Admission gate (core/admission.py): a category with
            # admit_after > 1 only caches a miss once its canonical key has
            # repeated enough in the per-category sketch. The repetition
            # test reuses the category's OWN similarity threshold — "would
            # this query have hit, had we cached its earlier occurrence?" —
            # so gate and cache agree on what a repeat is. Skipped items
            # return INVALID and count as admission_skips — they were still
            # misses upstream (lookup already counted them), they just don't
            # spend quota bytes. The observed repetition count feeds the
            # fresh-entry eviction prior for items that DO land.
            freq: dict[int, int] = {}
            gated: list[int] = []
            # One batched ring-buffer/sketch pass per gated category (stream
            # order preserved; trackers are per-category, so grouping by
            # category is observation-order-equivalent to the item loop —
            # and a sharded front door routes a category wholly to one
            # shard, so the per-category groups are identical across
            # topologies, keeping single-vs-sharded parity exact).
            by_cat: dict[str, list[int]] = {}
            for i in admitted:
                c = categories[i]
                if eff[c].admit_after > 1:
                    by_cat.setdefault(c, []).append(i)
            counts: dict[int, int] = {}
            for c, items in by_cat.items():
                cnts = self.admission.observe_batch(c, embeddings[items],
                                                    tau=eff[c].threshold)
                counts.update(zip(items, (int(x) for x in cnts)))
            for i in admitted:
                c = categories[i]
                k = eff[c].admit_after
                if k > 1:
                    cnt = counts[i]
                    if cnt < k:
                        self.metrics.cat(c).admission_skips += 1
                        continue
                    freq[i] = cnt
                gated.append(i)
        self.last_insert_stats = {
            "batch": B, "admitted": len(gated),
            "admission_skips": len(admitted) - len(gated),
            "insert_rejects": B - len(admitted)}
        if not gated:
            return slots_out
        admitted = gated

        # Occupancy bookkeeping is one cheap pass; the eviction SCORING
        # pass (+inf marks non-candidates so victim selection is a masked
        # argmin, updated as evictions land) is built lazily — a batch
        # under no quota/capacity pressure never pays it.
        live_mask = self.slot_valid.copy()
        cat_snapshot = self.slot_category.copy()
        cat_counts = {cid: int((live_mask & (cat_snapshot == cid)).sum())
                      for cid in cids.values()}
        live_count = int(live_mask.sum())
        scores: np.ndarray | None = None

        def ensure_scores() -> np.ndarray:
            nonlocal scores
            if scores is None:
                scores = np.full(self.capacity, np.inf, np.float64)
                live = np.where(live_mask)[0]
                if live.size:
                    scores[live] = self._entry_score(live)
            return scores

        # pending: admitted items not yet materialized, as (batch_i, cid,
        # score) — a fresh entry's score comes from the active scorer's
        # ``fresh_score`` (static: pri × 1/age_clamp × 1; cost-aware:
        # sketch-repetition prior × miss-cost / bytes), so a later item's
        # quota pressure can evict an earlier batch item exactly like the
        # sequential path would.
        pending: list[list] = []
        pending_counts: dict[int, int] = {}

        def evict_existing(slot: int, reason: str) -> int:
            nonlocal live_count
            vic_cid = int(cat_snapshot[slot])
            self._evict_slot(slot, reason=reason)
            live_mask[slot] = False
            ensure_scores()[slot] = np.inf
            cat_counts[vic_cid] = cat_counts.get(vic_cid, 1) - 1
            live_count -= 1
            return vic_cid

        def pick_victim(cid: int | None):
            """Lowest-score candidate among live slots (optionally one
            category) and pending batch items. Returns (slot, pending_pos);
            exactly one is valid (INVALID / -1 for the other)."""
            s = ensure_scores()
            mask = live_mask if cid is None else \
                live_mask & (cat_snapshot == cid)
            cand = np.where(mask)[0]
            best_slot, best_score = INVALID, np.inf
            if cand.size:
                j = int(np.argmin(s[cand]))
                best_slot = int(cand[j])
                best_score = float(s[best_slot])
            best_pos = -1
            for pos, (_, p_cid, p_score) in enumerate(pending):
                if cid is not None and p_cid != cid:
                    continue
                if p_score < best_score:
                    best_pos, best_score = pos, p_score
                    best_slot = INVALID
            return best_slot, best_pos

        def drop_pending(pos: int, reason_counter: str) -> None:
            """A batch item fell to a later item's pressure before ever
            reaching the index: account it as inserted-then-evicted (the
            sequential outcome) without a store/index round trip."""
            p_i, p_cid, _ = pending.pop(pos)
            pending_counts[p_cid] -= 1
            p_st = self.metrics.cat(categories[p_i])
            p_st.inserts += 1
            setattr(p_st, reason_counter,
                    getattr(p_st, reason_counter) + 1)

        # Span "evict": quota/capacity victim selection for the batch.
        with self._span("evict", batch=len(admitted)):
            for i in admitted:
                c = categories[i]
                e = eff[c]
                cid = cids[c]
                st = self.metrics.cat(c)
                cat_quota = int(e.quota * self.quota_capacity)
                n_cat = cat_counts.get(cid, 0) + pending_counts.get(cid, 0)
                if n_cat >= max(1, cat_quota):
                    slot, pos = pick_victim(cid)
                    if slot != INVALID:
                        evict_existing(slot, "quota")
                        st.quota_evictions += 1
                    elif pos >= 0:
                        # seed attributes quota evictions to the inserting
                        # category — here victim and inserter share it
                        drop_pending(pos, "quota_evictions")
                if live_count + len(pending) >= self.capacity:
                    slot, pos = pick_victim(None)
                    if slot != INVALID:
                        vic_cat = self._cat_names.get(evict_existing(
                            slot, "capacity"), "?")
                        self.metrics.cat(vic_cat).capacity_evictions += 1
                    elif pos >= 0:
                        drop_pending(pos, "capacity_evictions")
                pending.append([i, cid,
                                self._evictor.fresh_score(self, cid,
                                                          freq.get(i, 1))])
                pending_counts[cid] = pending_counts.get(cid, 0) + 1

        if not pending:
            return slots_out

        # One store pass, one index pass; the index's dirty rows coalesce
        # into a single device delta flush on the next search_batch.
        # Persisted documents keep ABSOLUTE clock time: the rebased ``now``
        # exists only for the float32 index table, and a restart-durable
        # store must not serialize timestamps relative to this process's
        # private _t0.
        # Span "write": the store pass + index pass (store put retries
        # charge their backoff inside this span).
        with self._span("write", items=len(pending)):
            created_at = self.clock.now()
            docs = []
            for p_i, _, _ in pending:
                doc_id = self._next_doc_id
                self._next_doc_id += self._doc_id_step
                # Under quantized residency the fp32 embedding travels WITH
                # the document (external tier): the re-rank tier's exact
                # copy. The fp32 index already IS exact, so its documents
                # skip the duplicate (~4·dim bytes/doc).
                emb = (embeddings[p_i].copy()
                       if self.index.quantized or self.durable_embeddings
                       else None)
                docs.append(Document(doc_id, requests[p_i], responses[p_i],
                                     created_at, categories[p_i],
                                     metas[p_i] or {}, embedding=emb))
            self.store.put_many(docs)
            order = [p_i for p_i, _, _ in pending]
            # The index owns the category table (slot_category aliases it).
            slots = self.index.add_batch(
                embeddings[order],
                np.asarray([cid for _, cid, _ in pending], np.int32))
            for (p_i, _, _), slot, doc in zip(pending, slots, docs):
                slot = int(slot)
                self.slot_inserted[slot] = now
                self.slot_hits[slot] = 0
                self.slot_doc[slot] = doc.doc_id
                self.slot_valid[slot] = True
                self.metrics.cat(categories[p_i]).inserts += 1
                slots_out[p_i] = slot
            return slots_out

    # ---------------------------------------------------------------- migration
    def adopt_entries(self, embeddings: np.ndarray,
                      categories: Sequence[str], inserted: np.ndarray,
                      hits: np.ndarray,
                      docs: Sequence[Document]) -> list[tuple[int, int]]:
        """Materialize fully-formed entries exported from another shard
        (core/shard.py live migration): the fp32 rows re-enter through
        ``index.add_batch`` (graph wiring + dirty log + deterministic
        requantization, so the int8+scale mirror comes out bit-identical
        to the source's), while ``inserted`` timestamps and hit counts
        are PRESERVED — ages, TTL expiry and eviction scores carry over
        unchanged. Documents are re-minted under this cache's doc-id
        sequence with their payloads (request/response/meta/created_at/
        fp32 embedding) intact.

        Deliberately bypasses the compliance/quota gates and the metrics
        counters: a migration is a move of already-admitted entries, not
        new traffic, and the category's quota ceiling is a fraction of
        the shared ``quota_capacity`` — the same ceiling that admitted
        the entries at their source. Returns (slot, doc_id) per entry.
        """
        embeddings = np.atleast_2d(np.asarray(embeddings, np.float32))
        B = embeddings.shape[0]
        if not (len(categories) == len(inserted) == len(hits)
                == len(docs) == B):
            raise ValueError("adopt_entries: ragged batch")
        # All-or-nothing: fail BEFORE touching the index/store when the
        # batch cannot physically fit, so a migration step that hits a
        # full target aborts with both shards unchanged.
        avail = self.capacity - self.index._n + len(self.index._free)
        if B > avail:
            raise RuntimeError(
                f"adopt_entries: {B} entries exceed the {avail} free "
                f"slots (shard_capacity {self.capacity}) — free space "
                f"on the target or migrate in smaller batches")
        cids = np.asarray([self._cat_id(c) for c in categories], np.int32)
        slots = self.index.add_batch(embeddings, cids)
        new_docs, out = [], []
        for k, slot in enumerate(int(s) for s in slots):
            d = docs[k]
            doc_id = self._next_doc_id
            self._next_doc_id += self._doc_id_step
            new_docs.append(Document(doc_id, d.request, d.response,
                                     d.created_at, d.category, dict(d.meta),
                                     embedding=d.embedding))
            # Rows are already dirty from add_batch, so the preserved
            # timestamp rides the same delta flush as the embedding.
            self.slot_inserted[slot] = float(inserted[k])
            self.slot_hits[slot] = int(hits[k])
            self.slot_doc[slot] = doc_id
            self.slot_valid[slot] = True
            out.append((slot, doc_id))
        self.store.put_many(new_docs)
        return out

    def category_slots(self, name: str) -> np.ndarray:
        """Live slots currently holding ``name``'s entries (the unit a
        shard migration drains)."""
        cid = self.policies.category_id(name)
        return np.where(self.slot_valid & (self.slot_category == cid))[0]

    def doc_id_of(self, slot: int) -> int:
        """Doc id behind a slot returned by lookup/insert (INVALID for
        empty slots AND for slot == INVALID itself — never numpy
        negative indexing). ShardedSemanticCache overrides the slot
        encoding, so callers that branch on doc ids use this instead of
        indexing ``slot_doc`` directly."""
        return int(self.slot_doc[slot]) if slot >= 0 else INVALID

    def replica_doc_ids(self, slot: int) -> list[int]:
        """All doc ids that can serve the entry behind ``slot`` — just
        the slot's own doc here; the sharded cache overrides this with
        the full replica set so callers tracking per-doc ground truth
        (the simulator) cover hits served from any replica."""
        d = self.doc_id_of(slot)
        return [d] if d != INVALID else []

    @property
    def sync_stats(self) -> dict:
        """The index's device-sync accounting (uniform with the sharded
        cache's aggregated view)."""
        return dict(self.index.sync_stats)

    # ----------------------------------------------------------------- eviction
    def _per_category_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Dense cid → (effective TTL, priority) lookup tables.

        O(#categories) to build, then slot-level policy reads are pure
        numpy indexing — the per-slot Python policy resolution the seed did
        in ``_entry_score``/``sweep_expired`` loops is gone.
        """
        n = (max(self._cat_names) + 1) if self._cat_names else 0
        ttl = np.full(n, np.inf, np.float64)
        pri = np.ones(n, np.float64)
        for cid, name in self._cat_names.items():
            eff = self.policies.effective(name)
            ttl[cid] = eff.ttl
            pri[cid] = eff.priority
        return ttl, pri

    def _entry_score(self, slots: np.ndarray) -> np.ndarray:
        """Entry value under the active eviction scorer (higher = more
        valuable; the lowest-scored candidate evicts). ``static`` is the
        §5.4 priority × 1/age × hitRate formula; ``cost_aware`` prices
        slots by expected-hits × miss-cost per resident byte
        (core/admission.py). Vectorized over ``slots``."""
        return self._evictor.score(self, slots)

    def _evict_slot(self, slot: int, reason: str = "") -> None:
        if not self.slot_valid[slot]:
            return
        if self.obs is not None:
            self._event("eviction", reason=reason,
                        category=self._cat_names.get(
                            int(self.slot_category[slot]), "?"))
        self.index.remove(slot)   # also resets the (aliased) category entry
        doc_id = int(self.slot_doc[slot])
        self.store.delete(doc_id)
        self._l1.pop(doc_id, None)
        self.slot_valid[slot] = False
        self.slot_doc[slot] = INVALID

    def sweep_expired(self) -> int:
        """Background TTL sweep (complement to lookup-time validation).

        Expiry detection is vectorized: one numpy compare over all valid
        slots against the per-category TTL table; Python only touches the
        (typically few) slots actually being evicted.
        """
        now = self._now()
        slots = np.where(self.slot_valid)[0]
        if slots.size == 0:
            return 0
        ttl_by_cid, _ = self._per_category_arrays()
        ttl = ttl_by_cid[self.slot_category[slots]]
        expired = slots[(now - self.slot_inserted[slots]) > ttl]
        for slot in expired:
            cat = self._cat_names.get(int(self.slot_category[slot]),
                                      "__default__")
            self._evict_slot(int(slot), reason="ttl_sweep")
            self.metrics.cat(cat).ttl_evictions += 1
        return int(expired.size)

    # ----------------------------------------------------------------- L1 docs
    def _l1_touch(self, doc_id: int) -> None:
        self._l1.move_to_end(doc_id)

    def _l1_maybe_promote(self, doc_id: int, response: str, hits: int) -> None:
        if self.l1_capacity <= 0 or hits < 2:
            return
        if doc_id not in self._l1 and len(self._l1) >= self.l1_capacity:
            self._l1.popitem(last=False)        # evict LRU
        self._l1[doc_id] = response
        self._l1.move_to_end(doc_id)

    # ----------------------------------------------------------------- reports
    def memory_report(self) -> dict:
        """§5.1/§7.4 accounting: bytes/entry in-memory vs externalized.

        ``in_memory_bytes_per_entry`` prices the RESIDENT (device/search)
        tier — the paper's compact in-memory structure, and what the
        delta sync moves and a device HBM budget holds: fp32 rows, or
        int8 rows + the fp32 scale word under quantized residency (the
        ~4x shrink that quadruples entries per byte of quota). The host
        CONTROL PLANE is priced separately (``host_bytes_per_entry``):
        it always keeps the fp32 rows for graph wiring/exact search, so
        under int8 residency host RAM per entry is fp32 + the quantized
        mirror — quantization shrinks the device tier, not host numpy."""
        n = max(1, len(self))
        emb_bytes = self.index.emb_row_nbytes()
        # Host numpy: the fp32 row always, + the int8/scale mirror when
        # the resident tier is quantized.
        host_emb_bytes = self.dim * 4 + \
            (emb_bytes if self.index.quantized else 0)
        graph_bytes = 0
        if isinstance(self.index, HNSWIndex):
            graph_bytes = sum(nb.shape[1] * 4 for nb in self.index.neighbors)
        overhead = 16 + 64 + 32   # id map + category metadata + statistics
        doc_bytes = (self.store.total_bytes() // n
                     if isinstance(self.store, InMemoryStore) and len(self.store) else 0)
        return {
            "entries": len(self),
            "emb_dtype": self.index.emb_dtype,
            "in_memory_bytes_per_entry": emb_bytes + graph_bytes + overhead,
            "host_bytes_per_entry": host_emb_bytes + graph_bytes + overhead,
            "embedding_bytes": emb_bytes,
            "graph_bytes": graph_bytes,
            "metadata_overhead_bytes": overhead,
            "external_doc_bytes_per_entry": doc_bytes,
        }

    def category_memory_report(self) -> dict:
        """Per-category residency: entries held, resident bytes, the
        category's quota ceiling in entries (quota × capacity) and the
        headroom left under it — the §5.4 quota math in byte terms, per
        the active ``emb_dtype`` (int8 residency ~4x-ens entries/byte)."""
        rep = self.memory_report()
        per_entry = rep["in_memory_bytes_per_entry"]
        out: dict[str, dict] = {}
        for cid, name in sorted(self._cat_names.items()):
            n_cat = int((self.slot_valid & (self.slot_category == cid)).sum())
            quota = self.policies.effective(name).quota
            quota_entries = int(quota * self.quota_capacity)
            out[name] = {
                "entries": n_cat,
                "resident_bytes": n_cat * per_entry,
                "bytes_per_entry": per_entry,
                "quota_entries": quota_entries,
                "quota_headroom_entries": max(0, quota_entries - n_cat),
            }
        return out
