"""Category policy engine + adaptive load-based controller.

Paper §3 (category properties → policies), §5.4 (enforcement points),
§7.5 (adaptive load-based policies).

A ``CategoryConfig`` carries the per-category policy: similarity threshold,
TTL, quota fraction, priority, compliance gate. The ``PolicyEngine`` owns
all categories and resolves effective (possibly load-adjusted) policies.
The ``AdaptiveController`` implements §7.5.4: load factor
``λ = min(1, Lp/Ltarget·wL + Q/Qtarget·wQ)`` with moving-average damping,
hysteresis (Δλ ≥ 0.1), safety bounds, and a false-positive feedback loop
shrinking ``δ_max``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class CategoryConfig:
    """Per-category cache policy (paper §3, Table 1, §6 use cases)."""

    name: str
    threshold: float                  # τ0: base cosine-similarity threshold
    ttl: float                        # t0: base TTL, seconds
    quota: float                      # max fraction of cache capacity
    priority: float = 1.0             # economic weight in eviction (§5.4)
    allow_caching: bool = True        # compliance gate (§6.4: HIPAA/GDPR)
    # Quantized-residency re-rank tier: device results whose int8 score
    # lands within this margin of τ are exactly re-scored from the fp32
    # embedding stored next to the document (core/cache.py), so
    # quantization can never flip a hit/miss decision at the boundary.
    # Dense categories sitting close to their τ (code) may widen it;
    # 0 disables re-ranking for the category.
    rerank_margin: float = 0.02
    # Admission control (core/admission.py): a miss is only cached once
    # its canonical repetition key (nearest recent representative within
    # the category's own threshold τ, else a fresh SimHash fingerprint)
    # has been observed ``admit_after`` times by the per-category
    # frequency sketch. 1 (default) admits every miss unconditionally —
    # the seed behavior. Uniform-repetition categories (Table 1:
    # conversational) set 2 so the never-repeating tail stops churning
    # quota.
    admit_after: int = 1
    # Adaptive-policy parameters (§7.5.4):
    delta_max: float = 0.05           # max threshold relaxation δ_max
    beta_max: float = 2.0             # max TTL extension factor β_max
    tau_min: float = 0.70             # safety bound: never relax below this
    ttl_max: float | None = None      # safety bound: cap on extended TTL
    # Workload metadata (used by economics + routing, not enforcement):
    model_name: str = "default"
    expected_tllm_ms: float = 500.0   # T_llm for break-even analysis

    def __post_init__(self):
        if not (0.0 < self.threshold <= 1.0):
            raise ValueError(f"{self.name}: threshold must be in (0,1], got {self.threshold}")
        if self.ttl <= 0:
            raise ValueError(f"{self.name}: ttl must be positive")
        if not (0.0 <= self.quota <= 1.0):
            raise ValueError(f"{self.name}: quota must be in [0,1]")
        if self.delta_max < 0 or self.beta_max < 1.0:
            raise ValueError(f"{self.name}: invalid adaptive bounds")
        if self.rerank_margin < 0:
            raise ValueError(f"{self.name}: rerank_margin must be >= 0")
        if self.admit_after < 1:
            raise ValueError(f"{self.name}: admit_after must be >= 1")

    def effective(self, load_factor: float) -> "EffectivePolicy":
        """Resolve τ(λ), t(λ) under load factor λ ∈ [0,1] (§7.5.4)."""
        lam = min(1.0, max(0.0, load_factor))
        tau = max(self.tau_min, self.threshold - lam * self.delta_max)
        ttl = self.ttl * (1.0 + lam * (self.beta_max - 1.0))
        if self.ttl_max is not None:
            ttl = min(ttl, self.ttl_max)
        return EffectivePolicy(threshold=tau, ttl=ttl, quota=self.quota,
                               priority=self.priority,
                               allow_caching=self.allow_caching,
                               rerank_margin=self.rerank_margin,
                               admit_after=self.admit_after)


@dataclass(frozen=True)
class EffectivePolicy:
    threshold: float
    ttl: float
    quota: float
    priority: float
    allow_caching: bool
    rerank_margin: float = 0.02
    admit_after: int = 1


@dataclass
class LoadSignal:
    """One observation of a downstream model's load (§7.5.4 inputs)."""

    latency_ms: float        # observed request latency (we track P95)
    queue_depth: int


class ModelLoadTracker:
    """Per-model load observation → smoothed load factor λ.

    Moving average over a configurable window (paper: 5–10 min) plus
    hysteresis: the *published* λ only moves when the smoothed λ drifts
    ≥ ``hysteresis`` from the last published value (§7.5.6).
    """

    def __init__(self, latency_target_ms: float, queue_target: int,
                 w_latency: float = 0.6, w_queue: float = 0.4,
                 window: int = 64, hysteresis: float = 0.1):
        if abs((w_latency + w_queue) - 1.0) > 1e-9:
            raise ValueError("weights must sum to 1")
        self.latency_target_ms = latency_target_ms
        self.queue_target = queue_target
        self.w_latency = w_latency
        self.w_queue = w_queue
        self.hysteresis = hysteresis
        self._lat = deque(maxlen=window)
        self._queue = deque(maxlen=window)
        self._published = 0.0

    def observe(self, sig: LoadSignal) -> None:
        self._lat.append(sig.latency_ms)
        self._queue.append(sig.queue_depth)

    def p95_latency_ms(self) -> float:
        if not self._lat:
            return 0.0
        xs = sorted(self._lat)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    def mean_queue(self) -> float:
        return sum(self._queue) / len(self._queue) if self._queue else 0.0

    def raw_load_factor(self) -> float:
        """λ = min(1, Lp/Ltarget·wL + Q/Qtarget·wQ)   — eq (7)."""
        if not self._lat and not self._queue:
            return 0.0
        lterm = (self.p95_latency_ms() / self.latency_target_ms) * self.w_latency
        qterm = (self.mean_queue() / max(1, self.queue_target)) * self.w_queue
        return min(1.0, lterm + qterm)

    def load_factor(self) -> float:
        """Hysteresis-damped λ: only republish on ≥ hysteresis drift."""
        raw = self.raw_load_factor()
        if abs(raw - self._published) >= self.hysteresis:
            self._published = raw
        return self._published


class AdaptiveController:
    """§7.5: per-model load tracking + FP-rate feedback on δ_max.

    ``model_for_category`` maps categories to downstream models so that a
    load spike on model A relaxes only A's categories (§7.5.5).
    """

    def __init__(self, fp_rate_limit: float = 0.05, fp_backoff: float = 0.5):
        self._trackers: dict[str, ModelLoadTracker] = {}
        self._fp_rate_limit = fp_rate_limit
        self._fp_backoff = fp_backoff
        self._delta_scale: dict[str, float] = {}   # per-category δ_max scaling

    def register_model(self, model_name: str, latency_target_ms: float,
                       queue_target: int, **kw) -> ModelLoadTracker:
        tr = ModelLoadTracker(latency_target_ms, queue_target, **kw)
        self._trackers[model_name] = tr
        return tr

    def observe(self, model_name: str, sig: LoadSignal) -> None:
        if model_name not in self._trackers:
            self.register_model(model_name, latency_target_ms=500.0, queue_target=32)
        self._trackers[model_name].observe(sig)

    def load_factor(self, model_name: str) -> float:
        tr = self._trackers.get(model_name)
        return tr.load_factor() if tr else 0.0

    def report_false_positive_rate(self, category: str, fp_rate: float) -> None:
        """§7.5.6 monitoring: FP rate above the limit during relaxed
        operation shrinks the category's δ_max; sustained clean windows
        recover it slowly (multiplicative decrease / gentle increase, so
        the relaxation converges to the FP-safe level)."""
        scale = self._delta_scale.get(category, 1.0)
        if fp_rate > self._fp_rate_limit:
            scale *= self._fp_backoff
        elif fp_rate < 0.5 * self._fp_rate_limit:
            scale = min(1.0, scale * 1.15)
        self._delta_scale[category] = scale

    def delta_scale(self, category: str) -> float:
        return self._delta_scale.get(category, 1.0)


class PolicyEngine:
    """Owns all category configs; resolves effective per-query policies."""

    def __init__(self, configs: list[CategoryConfig] | None = None,
                 controller: AdaptiveController | None = None,
                 default: CategoryConfig | None = None):
        self._configs: dict[str, CategoryConfig] = {}
        self._ids: dict[str, int] = {}
        self.controller = controller
        self.default = default or CategoryConfig(
            name="__default__", threshold=0.85, ttl=3600.0, quota=1.0)
        for c in configs or []:
            self.add(c)

    # -- registry ----------------------------------------------------------
    def add(self, config: CategoryConfig) -> None:
        if config.name in self._configs:
            raise ValueError(f"duplicate category {config.name!r}")
        self._ids[config.name] = len(self._ids)
        self._configs[config.name] = config

    def update(self, name: str, **changes) -> None:
        self._configs[name] = replace(self._configs[name], **changes)

    def get(self, name: str) -> CategoryConfig:
        return self._configs.get(name, self.default)

    def category_id(self, name: str) -> int:
        if name not in self._ids:
            self._ids[name] = len(self._ids)
            if name not in self._configs:
                self._configs[name] = replace(self.default, name=name)
        return self._ids[name]

    def categories(self) -> list[str]:
        return list(self._configs)

    def __contains__(self, name: str) -> bool:
        return name in self._configs

    # -- resolution --------------------------------------------------------
    def effective(self, name: str) -> EffectivePolicy:
        """Effective policy: base config adjusted by the (per-model) load
        factor and the FP-feedback δ_max scaling."""
        cfg = self.get(name)
        lam = 0.0
        if self.controller is not None:
            lam = self.controller.load_factor(cfg.model_name)
            scale = self.controller.delta_scale(name)
            if scale != 1.0:
                cfg = replace(cfg, delta_max=cfg.delta_max * scale)
        return cfg.effective(lam)

    def threshold_vector(self, names: list[str]) -> list[float]:
        """Per-query thresholds for a batch — what the TPU traversal consumes."""
        return [self.effective(n).threshold for n in names]


# ---------------------------------------------------------------------------
# The paper's running-example policy set (§6, Table 1, §7.3 guidance).
# ---------------------------------------------------------------------------

DAY = 86400.0
MIN = 60.0


def paper_policies() -> list[CategoryConfig]:
    return [
        # Head categories — dense spaces, power-law repetition, stable content
        CategoryConfig("code_generation", threshold=0.90, ttl=7 * DAY, quota=0.40,
                       priority=4.0, delta_max=0.05, beta_max=2.0, tau_min=0.80,
                       model_name="o1", expected_tllm_ms=500.0),
        CategoryConfig("api_documentation", threshold=0.88, ttl=3 * DAY, quota=0.20,
                       priority=2.0, delta_max=0.05, beta_max=2.0, tau_min=0.80,
                       model_name="gpt4o", expected_tllm_ms=500.0),
        # Tail categories — sparse / volatile / specialized
        CategoryConfig("conversational_chat", threshold=0.75, ttl=6 * 3600.0, quota=0.15,
                       priority=1.0, delta_max=0.10, beta_max=2.0, tau_min=0.68,
                       model_name="haiku", expected_tllm_ms=200.0),
        CategoryConfig("financial_data", threshold=0.85, ttl=5 * MIN, quota=0.08,
                       priority=2.0, delta_max=0.05, beta_max=3.0, tau_min=0.80,
                       ttl_max=15 * MIN, model_name="gpt4o_mini", expected_tllm_ms=200.0),
        CategoryConfig("legal_queries", threshold=0.82, ttl=1 * DAY, quota=0.08,
                       priority=2.5, delta_max=0.06, beta_max=2.0, tau_min=0.76,
                       model_name="gpt4o", expected_tllm_ms=500.0),
        CategoryConfig("medical_queries", threshold=0.82, ttl=1 * DAY, quota=0.05,
                       priority=2.5, delta_max=0.04, beta_max=1.5, tau_min=0.78,
                       model_name="gpt4o", expected_tllm_ms=500.0),
        CategoryConfig("specialized_domains", threshold=0.80, ttl=12 * 3600.0, quota=0.04,
                       priority=1.5, delta_max=0.08, beta_max=2.0, tau_min=0.72,
                       model_name="haiku", expected_tllm_ms=200.0),
        # Compliance-restricted (§6.4): never cached.
        CategoryConfig("phi_medical_records", threshold=0.95, ttl=1.0, quota=0.0,
                       allow_caching=False, model_name="gpt4o"),
    ]
