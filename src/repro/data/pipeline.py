"""Training data pipeline.

``SyntheticCorpus`` generates a deterministic token stream with real
statistical structure (a Zipfian unigram mixture over latent "topics", so
the loss actually goes down during the example training runs).

``PackedBatcher`` packs documents into fixed (batch, seq) blocks with
next-token labels, document-boundary loss masking, and an explicitly
checkpointable cursor: ``state_dict()`` round-trips through the training
checkpoint so a restarted job resumes mid-epoch exactly-once.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


class SyntheticCorpus:
    """Deterministic document stream: doc i is reproducible in isolation."""

    def __init__(self, vocab_size: int, n_topics: int = 32,
                 mean_len: int = 192, seed: int = 0):
        self.vocab_size = vocab_size
        self.n_topics = n_topics
        self.mean_len = mean_len
        self.seed = seed
        base = np.random.default_rng(seed)
        # Per-topic Zipfian unigram distributions over a topic vocabulary.
        self._topic_vocab = base.integers(
            2, vocab_size, size=(n_topics, max(64, vocab_size // 8)))
        ranks = np.arange(1, self._topic_vocab.shape[1] + 1, dtype=np.float64)
        p = ranks ** -1.1
        self._p = p / p.sum()

    def document(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 32) ^ idx)
        topic = int(rng.integers(0, self.n_topics))
        length = max(8, int(rng.poisson(self.mean_len)))
        words = rng.choice(self._topic_vocab.shape[1], size=length, p=self._p)
        toks = self._topic_vocab[topic][words]
        return np.concatenate([[1], toks]).astype(np.int32)   # BOS = 1


@dataclass
class BatcherState:
    doc_cursor: int = 0
    carry: list = None

    def to_dict(self) -> dict:
        return {"doc_cursor": self.doc_cursor,
                "carry": [] if self.carry is None else list(map(int, self.carry))}

    @classmethod
    def from_dict(cls, d: dict) -> "BatcherState":
        return cls(doc_cursor=int(d["doc_cursor"]),
                   carry=list(d.get("carry") or []))


class PackedBatcher:
    """Packs documents into (batch, seq+1) blocks → tokens/labels pairs.

    Labels are next-token; positions crossing a document boundary into a
    new document keep training (BOS separates docs); trailing padding is
    masked with −1.
    """

    def __init__(self, corpus: SyntheticCorpus, batch: int, seq: int,
                 state: BatcherState | None = None):
        self.corpus = corpus
        self.batch = batch
        self.seq = seq
        self.state = state or BatcherState(carry=[])

    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = BatcherState.from_dict(d)

    def _fill(self, n_tokens: int) -> np.ndarray:
        buf = list(self.state.carry or [])
        cur = self.state.doc_cursor
        while len(buf) < n_tokens:
            buf.extend(self.corpus.document(cur).tolist())
            cur += 1
        self.state.doc_cursor = cur
        self.state.carry = buf[n_tokens:]
        return np.asarray(buf[:n_tokens], np.int32)

    def next_batch(self) -> dict:
        need = self.batch * (self.seq + 1)
        flat = self._fill(need).reshape(self.batch, self.seq + 1)
        return {"tokens": flat[:, :-1].copy(),
                "labels": flat[:, 1:].astype(np.int32).copy()}
