"""Data pipeline: synthetic corpus, packing, resumable iteration."""

from repro.data.pipeline import SyntheticCorpus, PackedBatcher  # noqa: F401
