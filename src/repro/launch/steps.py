"""Jittable step functions: train (with gradient accumulation), prefill,
decode. These are what the dry-run lowers and what the drivers run."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, apply_adamw


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics). ``cfg.grad_accum`` microbatches via lax.scan (bounds MoE
    routing buffers and activation memory; kimi-k2 uses 8)."""
    cfg = model.cfg
    n_micro = max(1, cfg.grad_accum)

    def micro_loss(p, mb):
        return model.loss_fn(p, mb)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, met), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, batch)
        else:
            B = batch["tokens"].shape[0]
            assert B % n_micro == 0, (B, n_micro)
            mbs = jax.tree.map(
                lambda x: x.reshape(n_micro, B // n_micro, *x.shape[1:]),
                batch)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _met), g = jax.value_and_grad(
                    micro_loss, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return (acc, loss_acc + loss), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            met = {}
        params, opt_state, opt_met = apply_adamw(params, grads, opt_state,
                                                 opt_cfg)
        metrics = {"loss": loss, **opt_met}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, kv_len):
        return model.decode_step(params, cache, tokens, kv_len)
    return decode_step
