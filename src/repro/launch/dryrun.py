import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) cell on the production
meshes — 16×16 single-pod and 2×16×16 multi-pod — and records
memory_analysis / cost_analysis / collective schedule per cell.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); this module is the ONLY place that sets it.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
        --shape train_4k --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --list
Results: results/dryrun/<arch>__<shape>__<mesh>.json (existing cells are
skipped unless --force).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs import (SHAPES, get_config, runnable_cells,
                           skipped_cells)
from repro.distributed.context import Dist
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models.model import Model
from repro.models.transformer import init_cache
from repro.optim.adamw import AdamWConfig, init_opt_state

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def input_specs(cfg, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    sds = jax.ShapeDtypeStruct
    if spec.kind == "train":
        out = {"tokens": sds((B, S), jnp.int32),
               "labels": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["tokens"] = sds((B, S - cfg.n_patches), jnp.int32)
            out["labels"] = sds((B, S - cfg.n_patches), jnp.int32)
            out["patches"] = sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            out["audio"] = sds((B, cfg.enc_ctx, cfg.enc_dim), jnp.bfloat16)
        return out
    if spec.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["tokens"] = sds((B, S - cfg.n_patches), jnp.int32)
            out["patches"] = sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            out["audio"] = sds((B, cfg.enc_ctx, cfg.enc_dim), jnp.bfloat16)
        return out
    # decode: one new token against a KV cache of length S
    return {"tokens": sds((B,), jnp.int32),
            "kv_len": sds((B,), jnp.int32)}


def _tree_sds(shapes, shardings=None):
    if shardings is None:
        return shapes
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower + compile one cell; return the report payload."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = Dist.from_mesh(mesh)
    model = Model(cfg, dist)
    training = spec.kind == "train"
    plan = shd.param_plan(cfg, dist, training=training)
    pshard = plan.shardings(mesh)
    pshapes = model.param_shapes()
    ns = lambda s: NamedSharding(mesh, s)
    B, S = spec.global_batch, spec.seq_len

    ins = input_specs(cfg, shape_name)
    t0 = time.time()

    if spec.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
        opt_shapes = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg),
                                    pshapes)
        opt_specs = shd.opt_plan(plan.params, opt_shapes, dist)
        opt_shard = jax.tree.map(
            lambda s: ns(s) if s is not None else None, opt_specs,
            is_leaf=lambda x: isinstance(x, P))
        in_specs = shd.input_specs_train(cfg, dist, B)
        in_shard = jax.tree.map(lambda s: ns(s), in_specs,
                                is_leaf=lambda x: isinstance(x, P))
        accum_dtype = jnp.bfloat16 if cfg.opt_state_dtype == "int8" \
            else jnp.float32
        step = make_train_step(model, opt_cfg, accum_dtype=accum_dtype)
        fn = jax.jit(step,
                     in_shardings=(pshard, opt_shard, in_shard),
                     out_shardings=(pshard, opt_shard, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pshapes, opt_shapes, ins)
        tokens = B * S
    elif spec.kind == "prefill":
        in_shard = jax.tree.map(
            lambda s: ns(s),
            {k: (P(shd.batch_spec(dist, B), None) if v.ndim == 2
                 else P(shd.batch_spec(dist, B), None, None))
             for k, v in ins.items()},
            is_leaf=lambda x: isinstance(x, P))
        cspecs = {"stack": shd.cache_specs(cfg, dist, B, S)}
        if cfg.family == "encdec":
            cspecs["enc_kv"] = shd.enc_kv_spec(cfg, dist, B)
        out_shard = (ns(P(shd.batch_spec(dist, B), None)),       # logits
                     jax.tree.map(ns, cspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     ns(P(shd.batch_spec(dist, B))))             # kv_len
        step = make_prefill_step(model, max_len=S)
        fn = jax.jit(step, in_shardings=(pshard, in_shard),
                     out_shardings=out_shard)
        lowered = fn.lower(pshapes, ins)
        tokens = B * S
    else:  # decode
        sds = jax.ShapeDtypeStruct
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, S))
        cspecs = {"stack": shd.cache_specs(cfg, dist, B, S)}
        cache_tree = {"stack": cache_shapes}
        if cfg.family == "encdec":
            # enc_kv shapes (whisper): (G, B, ctx, Hkv, dh)
            from repro.models.transformer import layer_groups
            _, G = layer_groups(cfg)
            cspecs["enc_kv"] = shd.enc_kv_spec(cfg, dist, B)
            cache_tree["enc_kv"] = {
                "k": sds((G, B, cfg.enc_ctx, cfg.n_kv_heads, cfg.head_dim),
                         jnp.bfloat16),
                "v": sds((G, B, cfg.enc_ctx, cfg.n_kv_heads, cfg.head_dim),
                         jnp.bfloat16)}
        cshard = jax.tree.map(ns, cspecs,
                              is_leaf=lambda x: isinstance(x, P))
        bspec = shd.batch_spec(dist, B)
        step = make_decode_step(model)
        fn = jax.jit(step,
                     in_shardings=(pshard, cshard, ns(P(bspec)), ns(P(bspec))),
                     out_shardings=(ns(P(bspec, None)), cshard, ns(P(bspec))),
                     donate_argnums=(1,))
        lowered = fn.lower(pshapes, cache_tree,
                           ins["tokens"], ins["kv_len"])
        tokens = B  # one token per sequence per step

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_dict = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            mem_dict[attr] = int(getattr(mem, attr))
    print(f"  memory_analysis: {mem_dict}")

    cost = compiled.cost_analysis() or {}
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float))}
    print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")

    hlo = compiled.as_text()
    coll = rl.collective_bytes_from_hlo(hlo)
    # loop-aware re-analysis (XLA counts while bodies once; see hlo_cost.py)
    from repro.analysis import hlo_cost
    parsed = hlo_cost.analyze(hlo).to_dict()
    print(f"  hlo_cost(loop-aware): flops={parsed['flops']:.3e} "
          f"bytes={parsed['bytes']:.3e} "
          f"coll={parsed['total_collective_bytes']:.3e}")

    cache_bytes = 0
    if spec.kind == "decode":
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, S))
        cache_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(cache_shapes))

    payload = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_dict,
        "cost_analysis": cost,
        "collectives": coll,
        "hlo_cost": parsed,
        "model_flops": rl.model_flops(cfg, spec.kind, tokens),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "cache_bytes": cache_bytes,
        "hlo_bytes_len": len(hlo),
        "sharding_notes": plan.notes,
    }
    return payload


def cell_path(arch: str, shape: str, mesh: str, out_dir: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = runnable_cells()
    if args.arch:
        from repro.configs import ALIASES
        a = ALIASES.get(args.arch, args.arch).replace("-", "_").replace(".", "_")
        cells = [c for c in cells if c[0] == a]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.list:
        for arch, shape in cells:
            for mp in meshes:
                print(arch, shape, "multi" if mp else "single")
        for arch, shape, why in skipped_cells():
            print(arch, shape, f"SKIP({why})")
        return

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            path = cell_path(arch, shape, mesh_name, args.out)
            if os.path.exists(path) and not args.force:
                print(f"[skip existing] {arch} {shape} {mesh_name}")
                continue
            print(f"[dryrun] {arch} {shape} {mesh_name} ...", flush=True)
            try:
                payload = build_cell(arch, shape, mp)
                with open(path, "w") as f:
                    json.dump(payload, f, indent=1)
                rep = rl.report_from_dryrun(payload)
                print(f"  OK lower={payload['lower_s']}s "
                      f"compile={payload['compile_s']}s "
                      f"bottleneck={rep.bottleneck} "
                      f"roofline_frac={rep.roofline_fraction:.3f}", flush=True)
            except Exception as e:  # record and continue
                failures.append((arch, shape, mesh_name, repr(e)))
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"  FAIL {e!r}", flush=True)
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", *f)
        raise SystemExit(1)
    print("\nAll requested cells compiled.")


if __name__ == "__main__":
    main()
