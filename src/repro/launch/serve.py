"""Serving driver: category-aware semantic cache in front of a real model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --reduced --requests 300 --cache hybrid

Wires the full paper stack: feature-hash embeddings → category policies →
hybrid cache (Algorithm 1) → batched prefill/decode on the JAX model for
misses → cache insertion, with adaptive load-based policy adjustment.
``--cache none`` serves everything from the model (the uncached baseline).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.cache import SemanticCache
from repro.core.clock import WallClock
from repro.core.shard import ShardedSemanticCache
from repro.core.policy import AdaptiveController, PolicyEngine, \
    paper_policies
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.models.model import Model
from repro.obs import (TraceRecorder, coverage_fraction, prometheus_text,
                       span_accounting, telemetry_report)
from repro.serving.engine import ServingEngine


def parse_replicas(spec: str | None) -> dict[str, int] | float | None:
    """``--replicas`` grammar: a float quota-mass threshold (``0.25`` —
    categories at or above it get 2 replicas) or an explicit map
    (``conversational_chat=2,code_generation=3``)."""
    if not spec:
        return None
    try:
        return float(spec)
    except ValueError:
        pass
    out: dict[str, int] = {}
    for part in spec.split(","):
        name, _, k = part.partition("=")
        if not name or not k.isdigit():
            raise SystemExit(
                f"--replicas: expected FLOAT or cat=k[,cat=k...], got "
                f"{spec!r}")
        out[name.strip()] = int(k)
    return out


def run_serving(cfg, *, n_requests: int, cache_kind: str = "hybrid",
                max_batch: int = 8, prompt_len: int = 32,
                max_new_tokens: int = 8, seed: int = 0,
                index_kind: str = "flat", use_device: bool = False,
                emb_dtype: str = "float32", n_shards: int = 1,
                replicas: dict[str, int] | float | None = None,
                telemetry: bool = False,
                telemetry_jsonl: str | None = None,
                telemetry_prom: str | None = None,
                log=print) -> dict:
    model = Model(cfg)
    params = model.init_params(jax.random.key(seed))
    controller = AdaptiveController()
    policies = PolicyEngine(paper_policies(), controller=controller)

    # One WallClock shared by the cache and the recorder so span
    # timestamps and cache timestamps are the same timeline. Under a
    # wall clock span accounting reports leaf coverage, not equality.
    clock = WallClock()
    trace = telemetry or telemetry_jsonl is not None \
        or telemetry_prom is not None
    obs = TraceRecorder(clock) if trace else None
    kw = dict(capacity=max(4096, n_requests), clock=clock,
              index_kind=index_kind, use_device=use_device,
              l1_capacity=256, emb_dtype=emb_dtype, obs=obs)
    cache = (ShardedSemanticCache(policies, n_shards=n_shards,
                                  replication=replicas, **kw)
             if n_shards > 1 else SemanticCache(policies, **kw))
    if cache_kind == "none":
        for name in policies.categories():
            policies.update(name, allow_caching=False)

    engine = ServingEngine(model, params, cache, max_batch=max_batch,
                           prompt_len=prompt_len,
                           max_new_tokens=max_new_tokens,
                           controller=controller, obs=obs)

    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=1e9, seed=seed)
    queries = gen.generate(n_requests)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for q in queries:
        toks = rng.integers(2, cfg.vocab_size, size=prompt_len)
        engine.submit(q.text, q.category, toks)
        if len(engine.queue) >= max_batch:
            engine.step()
    engine.drain()
    wall = time.time() - t0
    st = engine.stats
    log(f"[serve] {st.served} served, hit_rate={st.hit_rate:.3f}, "
        f"model_tokens={st.model_tokens}, "
        f"mean_latency={st.total_latency_ms / max(1, st.served):.1f}ms, "
        f"wall={wall:.1f}s")
    # Data-plane counters aggregate across every index the cache owns
    # (the engine sums cache.last_lookup_stats per step, which the
    # sharded cache pre-merges over its fan-out).
    log(f"[serve] search data plane: {st.search_hops} hops, "
        f"{st.rows_gathered} embedding rows gathered "
        f"across {n_shards} shard(s)")
    sync = getattr(cache, "sync_stats", None)
    if sync is not None:
        log(f"[serve] index sync ({emb_dtype} residency): "
            f"{sync['full_uploads']} full / "
            f"{sync['delta_updates']} delta uploads, "
            f"{sync['bytes_synced'] / 1e6:.2f} MB synced "
            f"({sync['emb_bytes_synced'] / 1e6:.2f} MB embeddings)")
        for si, ss in enumerate(sync.get("per_shard", [])):
            log(f"[serve]   shard {si}: {ss['full_uploads']} full / "
                f"{ss['delta_updates']} delta, "
                f"{ss['bytes_synced'] / 1e6:.2f} MB synced")
    replica_sets = None
    if n_shards > 1:
        replica_sets = {c: list(r) for c, r in sorted(
            getattr(cache.planner, "replica_sets", {}).items())}
        if replica_sets:
            for c, reps in replica_sets.items():
                log(f"[serve] replica set {c}: shards {reps} "
                    f"(writes fan out, reads round-robin)")
            fs = cache.fault_stats
            log(f"[serve] replication: "
                f"{fs['failover_reads']} failover reads, "
                f"{fs['replica_divergence']} divergence events, "
                f"{fs['outage_rebalances']} outage rebalances")
    snap = cache.metrics.snapshot()
    ov = snap["_overall"]
    log(f"[serve] overall: hit_rate={ov['hit_rate']:.3f}, "
        f"availability={ov.get('availability', 1.0):.3f}, "
        f"{ov['inserts']} inserts, "
        f"{ov['ttl_evictions'] + ov['quota_evictions'] + ov['capacity_evictions']}"
        f" evictions")
    tele = None
    if obs is not None:
        acct = span_accounting(obs)
        tele = {"spans": acct["spans"], "roots": acct["roots"],
                "opened": acct["opened"], "closed": acct["closed"],
                "leaf_coverage": round(coverage_fraction(obs), 4),
                "events": obs.event_counts()}
        if telemetry:
            log(telemetry_report(obs, snapshot=snap))
        if telemetry_jsonl:
            n_lines = obs.to_jsonl(telemetry_jsonl)
            log(f"[serve] trace: {n_lines} JSONL lines -> {telemetry_jsonl}")
        if telemetry_prom:
            with open(telemetry_prom, "w") as f:
                f.write(prometheus_text(snapshot=snap, rec=obs))
            log(f"[serve] metrics exposition -> {telemetry_prom}")
    return {"served": st.served, "hit_rate": st.hit_rate,
            "model_tokens": st.model_tokens, "wall_s": wall,
            "search_hops": st.search_hops,
            "rows_gathered": st.rows_gathered,
            "n_shards": n_shards,
            "per_category": snap,
            "replica_sets": replica_sets,
            "telemetry": tele,
            "index_sync": dict(sync) if sync is not None else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--cache", choices=["hybrid", "none"], default="hybrid")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--index", choices=["flat", "hnsw"], default="flat",
                    help="cache index; hnsw enables the graph index")
    ap.add_argument("--use-device", action="store_true",
                    help="route lookups through the device-resident "
                         "(delta-synced) index: the jitted beam search "
                         "for hnsw, the flat_topk kernel for flat")
    ap.add_argument("--emb-dtype", choices=["float32", "int8"],
                    default="float32",
                    help="resident embedding tier: int8 = quantized "
                         "residency (fused-dequant kernels, ~4x fewer "
                         "sync/gather bytes, fp32 re-rank at the τ "
                         "boundary)")
    ap.add_argument("--shards", type=int, default=1,
                    help="category-sharded cache tier: N device-resident "
                         "shards with quota-byte planner placement "
                         "(core/shard.py); the report shows per-shard "
                         "sync accounting")
    ap.add_argument("--replicas", default=None,
                    help="head-category replication (needs --shards > 1): "
                         "a float quota-mass threshold (0.25 = categories "
                         "at/above it get 2 replicas) or an explicit "
                         "cat=k[,cat=k...] map; the report adds replica-"
                         "set, failover and divergence lines")
    ap.add_argument("--telemetry", action="store_true",
                    help="wire a TraceRecorder through the stack and "
                         "print the telemetry report (span accounting, "
                         "per-stage latency table, event counts)")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="PATH",
                    help="dump the span/event trace as JSONL to PATH "
                         "(implies tracing on)")
    ap.add_argument("--telemetry-prom", default=None, metavar="PATH",
                    help="write a Prometheus-style text exposition of "
                         "counters + stage histograms to PATH "
                         "(implies tracing on)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run_serving(cfg, n_requests=args.requests, cache_kind=args.cache,
                max_batch=args.max_batch, index_kind=args.index,
                use_device=args.use_device, emb_dtype=args.emb_dtype,
                n_shards=args.shards,
                replicas=parse_replicas(args.replicas),
                telemetry=args.telemetry,
                telemetry_jsonl=args.telemetry_jsonl,
                telemetry_prom=args.telemetry_prom)


if __name__ == "__main__":
    main()
