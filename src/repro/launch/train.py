"""Training driver: fault-tolerant loop over any assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production features wired in: sharded params via the ShardingPlan (when a
mesh is configured), gradient-accumulation microbatching, async
checkpointing with data-pipeline state (exactly-once batches), preemption
handler (SIGTERM → emergency save), straggler watchdog, restart-resume.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.configs import get_config
from repro.data import PackedBatcher, SyntheticCorpus
from repro.distributed.context import Dist
from repro.distributed.fault import PreemptionHandler, StepWatchdog
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, init_opt_state


def run_training(cfg, *, steps: int, batch: int, seq: int,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 lr: float = 3e-4, dist: Dist | None = None,
                 log_every: int = 10, seed: int = 0,
                 log=print) -> dict:
    model = Model(cfg, dist)
    opt_cfg = AdamWConfig(lr=lr, state_dtype=cfg.opt_state_dtype,
                          total_steps=steps)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=seed)
    batcher = PackedBatcher(corpus, batch, seq)

    start_step = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        tree, extras, start_step = restore_checkpoint(ckpt_dir)
        params, opt_state = tree["params"], tree["opt"]
        batcher.load_state_dict(extras["batcher"])
        log(f"[train] resumed from step {start_step}")
    else:
        params = model.init_params(jax.random.key(seed))
        opt_state = init_opt_state(params, opt_cfg)

    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    preempt = PreemptionHandler().install()
    watchdog = StepWatchdog(
        on_straggler=lambda dt, med: log(
            f"[watchdog] straggler step: {dt:.2f}s vs median {med:.2f}s"))

    losses = []
    t_start = time.time()
    step = start_step
    for step in range(start_step, steps):
        watchdog.step_start()
        np_batch = batcher.next_batch()
        batch_dev = {k: jnp.asarray(v) for k, v in np_batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = watchdog.step_end()
        if step % log_every == 0:
            log(f"[train] step {step} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extras={"batcher": batcher.state_dict()})
        if preempt.preempted:
            log("[train] preemption signal — emergency checkpoint")
            if ckpt:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extras={"batcher": batcher.state_dict()})
                ckpt.wait()
            break
    if ckpt:
        ckpt.save(step + 1, {"params": params, "opt": opt_state},
                  extras={"batcher": batcher.state_dict()})
        ckpt.wait()
    preempt.uninstall()
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "first_loss": losses[0] if losses else float("nan"),
        "losses": losses,
        "steps_run": len(losses),
        "straggler_events": watchdog.straggler_events,
        "wall_s": time.time() - t_start,
        "params": params,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(grad_accum=1)
    res = run_training(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       lr=args.lr)
    print(f"[train] done: first_loss={res['first_loss']:.4f} "
          f"final_loss={res['final_loss']:.4f} "
          f"steps={res['steps_run']} wall={res['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
