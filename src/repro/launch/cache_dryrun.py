import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN data plane: the batched semantic-cache
lookup + index maintenance, lowered + compiled on the production mesh.

Two implementations of the 2 ms local search (§5.2):
    flat — tiled cosine top-1 over the whole table (O(N·d) HBM stream)
    beam — HNSW batched-frontier beam search (O(hops·beam·M·d) gathers)

Plus the write side of the device-resident index:
    delta — the per-step delta flush (donated in-place row scatter over
            emb/neighbors/valid/category). Its "bytes accessed" must scale
            with --delta-rows, not --entries: the dry-run proof that
            steady-state sync cost is O(delta) while the seed's full
            re-upload was O(capacity).

Sharding: the index is replicated per data-group (reads need no
collectives); queries shard over (pod, data). A category-sharded variant
shards the TABLE over data (each group holds a category shard, §7.4) and
is what the router's shard_for() maps onto.

    PYTHONPATH=src python -m repro.launch.cache_dryrun \
        [--entries 1048576] [--batch 128] [--impl flat|beam|both]

Results → results/dryrun_cache/cache__<impl>__<mesh>.json
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.core.hnsw import beam_search
from repro.distributed.context import Dist
from repro.launch.mesh import make_production_mesh

RESULTS = "results/dryrun_cache"


def flat_lookup(emb, valid, queries, thresholds, slot_cat, query_cat):
    """Pure-jnp tiled top-1 (XLA path of kernels/flat_topk), category-masked."""
    scores = jnp.einsum("nd,bd->bn", emb, queries,
                        preferred_element_type=jnp.float32)
    ok = valid[None, :] & ((query_cat[:, None] < 0) |
                           (slot_cat[None, :] == query_cat[:, None]))
    scores = jnp.where(ok, scores, -jnp.inf)
    best = jnp.argmax(scores, axis=1).astype(jnp.int32)
    best_s = jnp.take_along_axis(scores, best[:, None], axis=1)[:, 0]
    hit = best_s >= thresholds
    return jnp.where(hit, best, -1), best_s


def delta_flush(emb, nbrs, valid, cat, rows, emb_rows, nbr_rows,
                valid_rows, cat_rows):
    """Donated in-place scatter of R dirty rows into the resident tables
    (the XLA form of kernels/scatter_update, as HNSWIndex applies it)."""
    return (emb.at[rows].set(emb_rows), nbrs.at[rows].set(nbr_rows),
            valid.at[rows].set(valid_rows), cat.at[rows].set(cat_rows))


def build(impl: str, multi_pod: bool, n_entries: int, batch: int,
          dim: int = 384, m0: int = 32, shard_table: bool = False,
          dtype="f32", delta_rows: int = 256):
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = Dist.from_mesh(mesh)
    ns = lambda s: NamedSharding(mesh, s)
    b_axes = dist.batch_axes
    sds = jax.ShapeDtypeStruct
    # Category-sharded table (§7.4) splits N over data; replicated default.
    table_spec = P(dist.data_axis, None) if shard_table else P(None, None)

    emb_dt = jnp.bfloat16 if dtype == "bf16" else jnp.float32
    emb = sds((n_entries, dim), emb_dt)
    valid = sds((n_entries,), jnp.bool_)
    slot_cat = sds((n_entries,), jnp.int32)
    nbrs = sds((n_entries, m0), jnp.int32)
    entries = sds((8,), jnp.int32)
    queries = sds((batch, dim), jnp.float32)
    taus = sds((batch,), jnp.float32)
    qcat = sds((batch,), jnp.int32)

    if impl == "delta":
        R = delta_rows
        rep2, rep1 = ns(P(None, None)), ns(P(None))
        fn = jax.jit(delta_flush, donate_argnums=(0, 1, 2, 3),
                     in_shardings=(rep2, rep2, rep1, rep1, rep1,
                                   rep2, rep2, rep1, rep1),
                     out_shardings=(rep2, rep2, rep1, rep1))
        lowered = fn.lower(emb, nbrs, valid, slot_cat,
                           sds((R,), jnp.int32),
                           sds((R, dim), emb_dt), sds((R, m0), jnp.int32),
                           sds((R,), jnp.bool_), sds((R,), jnp.int32))
    elif impl == "flat":
        fn = jax.jit(flat_lookup,
                     in_shardings=(ns(table_spec), ns(P(table_spec[0])),
                                   ns(P(b_axes, None)), ns(P(b_axes)),
                                   ns(P(table_spec[0])), ns(P(b_axes))),
                     out_shardings=(ns(P(b_axes)), ns(P(b_axes))))
        lowered = fn.lower(emb, valid, queries, taus, slot_cat, qcat)
    else:
        fn = jax.jit(
            lambda e, nb, v, en, q, t, sc, qc: beam_search(
                e, nb, v, en, q, t, sc, qc, beam=32, max_hops=12),
            in_shardings=(ns(P(None, None)), ns(P(None, None)),
                          ns(P(None)), ns(P(None)),
                          ns(P(b_axes, None)), ns(P(b_axes)),
                          ns(P(None)), ns(P(b_axes))),
            out_shardings=(ns(P(b_axes)), ns(P(b_axes)), None))
        lowered = fn.lower(emb, nbrs, valid, entries, queries, taus,
                           slot_cat, qcat)

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    raw_cost = compiled.cost_analysis() or {}
    if isinstance(raw_cost, (list, tuple)):    # jax ≤ 0.4.x: list per device
        raw_cost = raw_cost[0] if raw_cost else {}
    cost = {k: float(v) for k, v in raw_cost.items()
            if isinstance(v, (int, float))}
    hlo = compiled.as_text()
    coll = rl.collective_bytes_from_hlo(hlo)
    from repro.analysis import hlo_cost
    parsed = hlo_cost.analyze(hlo).to_dict()
    mem = compiled.memory_analysis()
    mem_dict = {a: int(getattr(mem, a)) for a in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes") if hasattr(mem, a)}
    n_dev = 512 if multi_pod else 256
    esz = 2 if dtype == "bf16" else 4
    row_bytes = dim * esz + m0 * 4 + 1 + 4
    payload = {
        "arch": f"cache_{impl}" + ("_sharded" if shard_table else ""),
        "shape": (f"delta_r{delta_rows}_n{n_entries}" if impl == "delta"
                  else f"lookup_b{batch}_n{n_entries}"),
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_dict,
        "cost_analysis": cost,
        "collectives": coll,
        "hlo_cost": parsed,
        # ideal: stream the (replicated) table once per query batch;
        # the delta flush streams only the dirty rows
        "model_flops": 0.0 if impl == "delta"
        else 2.0 * n_entries * dim * batch,
        "active_params": 0,
        "cache_bytes": 0,
        "table_bytes": n_entries * dim * esz,
        "delta_bytes": delta_rows * row_bytes if impl == "delta" else 0,
    }
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=1 << 20)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--impl", default="both",
                    help="flat | beam | delta | both (flat+beam) | all")
    ap.add_argument("--delta-rows", type=int, default=256,
                    help="delta impl: dirty rows per flush")
    ap.add_argument("--shard-table", action="store_true")
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    impls = {"both": ["flat", "beam"],
             "all": ["flat", "beam", "delta"]}.get(args.impl, [args.impl])
    for impl in impls:
        for mp in (False, True):
            name = impl + ("_sharded" if args.shard_table else "") + \
                ("_bf16" if args.dtype == "bf16" else "")
            tag = f"cache__{name}__{'multi' if mp else 'single'}"
            print(f"[cache-dryrun] {tag} ...", flush=True)
            payload = build(impl, mp, args.entries, args.batch,
                            shard_table=args.shard_table, dtype=args.dtype,
                            delta_rows=args.delta_rows)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(payload, f, indent=1)
            cost = payload["cost_analysis"]
            flops = cost.get("flops", 0.0)
            byts = cost.get("bytes accessed", 0.0)
            print(f"  flops={flops:.3e} bytes={byts:.3e} "
                  f"mem_ms={byts / 819e9 * 1e3:.3f} "
                  f"coll={payload['collectives']['total_bytes']:.3e}")


if __name__ == "__main__":
    main()
