"""Pipeline parallelism over the pod axis (GPipe schedule, GSPMD-native).

The multi-pod mesh's slow tier is the inter-pod link, which suits pipeline
parallelism: each pod holds a contiguous range of layer groups, and
activations cross pods once per microbatch instead of every gradient
all-reduce. The schedule is expressed WITHOUT shard_map:

  * stage params: the (G, …) group-stacked stack reshaped to
    (P, G/P, …) and sharded ``P("pod", None, …)``;
  * the activation buffer (P, Bµ, S, d) is sharded ``P("pod", batch…)``;
    each scan step vmaps the stage body over the P dim (every pod runs its
    own layers on its own buffer row) and then ``jnp.roll``s the buffer by
    one along the stage dim — GSPMD lowers the roll to a
    ``collective-permute`` across pods, i.e. the pipeline hand-off;
  * n_micro + P − 1 steps fill/drain the pipe (GPipe bubble); outputs are
    collected from the last stage row.

Identical math to the sequential stack (same groups, same order), so the
correctness test asserts exact loss equality vs the non-PP path. Dense
families only (MoE's shard_map cannot nest under the stage vmap) —
kimi/granite-moe/jamba keep the DP-over-pod layout instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.layers import rms_norm
from repro.models.model import Model


def reshape_stack_for_pp(stack: dict, n_stages: int) -> dict:
    """(G, …) leaves → (P, G/P, …)."""
    def r(x):
        G = x.shape[0]
        assert G % n_stages == 0, (G, n_stages)
        return x.reshape(n_stages, G // n_stages, *x.shape[1:])
    return jax.tree.map(r, stack)


def pp_stack_specs(plan_stack: dict) -> dict:
    """Prepend the stage axis ('pod') to the stack's PartitionSpecs."""
    from jax.sharding import PartitionSpec as P

    def r(spec):
        return P("pod", *spec)
    return jax.tree.map(r, plan_stack,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))


def make_pp_loss(model: Model, n_micro: int):
    """Returns loss_fn(params_pp, batch) running the stack as a GPipe over
    the pod axis. ``params_pp["stack"]`` must be stage-reshaped."""
    cfg = model.cfg
    dist = model.dist
    assert dist is not None and dist.pod_axis, "PP needs the multi-pod mesh"
    P_stages = dist.n_pod
    group, G = tf.layer_groups(cfg)
    assert G % P_stages == 0, f"{G} groups don't split over {P_stages} pods"

    def stage_apply(stage_params, h, positions):
        out, _, _ = tf.stack_apply(h, stage_params, cfg, None, mode="train",
                                   positions=positions, group=group)
        return out

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        assert B % n_micro == 0
        Bm = B // n_micro
        positions = jnp.arange(S)
        x = model._embed_tokens(params, tokens)
        xs = x.reshape(n_micro, Bm, S, -1)

        buf0 = jnp.zeros((P_stages, Bm, S, x.shape[-1]), x.dtype)
        n_steps = n_micro + P_stages - 1

        def step(buf, t):
            out = jax.vmap(lambda sp, h: stage_apply(sp, h, positions)
                           )(params["stack"], buf)
            y_t = out[-1]                                   # last stage
            rolled = jnp.roll(out, 1, axis=0)               # pod hand-off
            feed = xs[jnp.clip(t, 0, n_micro - 1)]
            buf = rolled.at[0].set(feed.astype(buf.dtype))
            return buf, y_t

        # prime: at t the buffer row 0 receives microbatch t; row P-1 emits
        # microbatch t-(P-1).
        buf = buf0.at[0].set(xs[0])
        _, ys = jax.lax.scan(step, buf,
                             jnp.arange(1, n_steps + 1, dtype=jnp.int32))
        ys = ys[P_stages - 1:]                              # drain window
        ys = ys.reshape(n_micro * Bm, S, -1).reshape(B, S, -1)

        h = rms_norm(ys, params["final_norm"], cfg.norm_eps)
        loss, n_tok = model._chunked_xent(params, h, labels)
        return loss, {"xent": loss, "tokens": n_tok}

    return loss_fn
