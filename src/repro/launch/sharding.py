"""Logical-axis sharding rules (MaxText-style), resolved per (arch, mesh).

Rules walk the parameter pytree by path and emit ``PartitionSpec``s:

  * feature axes (heads, ffn, vocab, d_inner) → ``model``  (TP)
  * training adds FSDP: d_model dims → ``data``; the MoE expert axis is
    *stored* over the widest dividing prefix of (pod, data) — kimi-k2's
    1 T params shard across pods at rest and are all-gathered per layer
    into the data-owned compute layout inside the scan (GSPMD inserts the
    gather from the shard_map in_spec mismatch)
  * serving replicates weights over ``data``; batch/KV shard over
    (pod, data), the KV **sequence** goes to ``model`` when kv_heads don't
    divide the model axis, and to (data, model) for batch-1 long context
  * group-stacked leaves (under "stack") get a leading ``None``

Divisibility is checked against the actual mesh: anything non-divisible
falls back to replication on that axis (recorded in ``notes``) — 8-head
gemma2 attention ends up TP-replicated while its 9216-wide FFN TP-shards.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.context import Dist
from repro.models.config import ArchConfig


def _axis_size(dist: Dist, axis) -> int:
    if axis is None:
        return 1
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= dist.axis_size(a)
    return size


def _fit(dist: Dist, dim: int, *candidates):
    """First candidate axis (or axis tuple) whose size divides dim."""
    for axis in candidates:
        if axis is None:
            return None
        if dim % _axis_size(dist, axis) == 0:
            return axis
    return None


@dataclass
class ShardingPlan:
    params: dict                      # pytree of PartitionSpec
    notes: list[str] = field(default_factory=list)

    def shardings(self, mesh) -> dict:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.params,
            is_leaf=lambda x: isinstance(x, P))


def param_plan(cfg: ArchConfig, dist: Dist, *, training: bool) -> ShardingPlan:
    """PartitionSpec pytree matching ``Model.init_params`` structure."""
    shapes = _shape_tree(cfg)
    notes: list[str] = []
    fsdp = dist.data_axis if training else None
    model = dist.model_axis
    # Expert storage: widest dividing prefix of (pod, data); replicate else.
    expert_candidates = []
    if training and dist.pod_axis:
        expert_candidates.append((dist.pod_axis, dist.data_axis))
    expert_candidates.extend([(dist.data_axis,), None])

    def rule(path: str, shape: tuple[int, ...]) -> P:
        stacked = "stack." in path
        base = shape[1:] if stacked else shape
        leaf = path.split(".")[-1]

        def wrap(*axes):
            assert len(axes) == len(base), (path, axes, base)
            spec = tuple(_fit(dist, base[i], a, None)
                         for i, a in enumerate(axes))
            for i, (want, got) in enumerate(zip(axes, spec)):
                if want is not None and got is None:
                    notes.append(f"{path}: dim{i}={base[i]} not divisible by "
                                 f"{want}; replicated")
            return P(*(((None,) + spec) if stacked else spec))

        if leaf in ("embed", "head"):
            return wrap(model, fsdp)
        if leaf in ("final_norm", "ln_mix", "ln_mlp", "ln_cross", "conv_b",
                    "dt_bias", "D"):
            return wrap(*([None] * len(base)))
        if leaf in ("wq", "wk", "wv"):
            return wrap(fsdp, model, None)
        if leaf == "wo":
            return wrap(model, None, fsdp)
        if len(base) == 3 and leaf in ("w_gate", "w_up"):     # MoE experts
            e_axis = _fit(dist, base[0], *expert_candidates)
            return wrap(e_axis, None, model)
        if len(base) == 3 and leaf == "w_down":
            e_axis = _fit(dist, base[0], *expert_candidates)
            return wrap(e_axis, model, None)
        if leaf in ("w_gate", "w_up"):                         # dense MLP
            return wrap(fsdp, model)
        if leaf == "w_down":
            return wrap(model, fsdp)
        if leaf == "router":
            return wrap(fsdp, None)
        if leaf == "w_in":                                     # mamba (d, 2di)
            return wrap(fsdp, model)
        if leaf == "conv_w":
            return wrap(None, model)
        if leaf == "w_x_proj":
            return wrap(model, None)
        if leaf == "w_dt":
            return wrap(None, model)
        if leaf == "A_log":
            return wrap(model, None)
        if leaf == "w_out":                                    # (di, d)
            return wrap(model, fsdp)
        if leaf == "proj":                                     # whisper frontend
            return wrap(None, fsdp)
        notes.append(f"replicated (no rule): {path} {shape}")
        return P(*([None] * len(shape)))

    specs = _map_with_path(shapes, rule)
    return ShardingPlan(params=specs, notes=notes)


def _shape_tree(cfg: ArchConfig) -> dict:
    from repro.models.model import Model
    m = Model(cfg)
    return jax.tree.map(lambda s: s.shape, m.param_shapes())


def _map_with_path(tree, fn, prefix=""):
    if isinstance(tree, dict):
        return {k: _map_with_path(v, fn, prefix + k + ".")
                for k, v in tree.items()}
    return fn(prefix.rstrip("."), tree)


# ---------------------------------------------------------------------------
# Input / cache specs per shape kind.
# ---------------------------------------------------------------------------

def batch_spec(dist: Dist, batch: int):
    """Shard batch over (pod, data) if divisible; fall back to data; none."""
    cands = []
    if dist.pod_axis:
        cands.append((dist.pod_axis, dist.data_axis))
    cands.extend([(dist.data_axis,), None])
    return _fit(dist, batch, *cands)


def input_specs_train(cfg: ArchConfig, dist: Dist, batch: int) -> dict:
    b = batch_spec(dist, batch)
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "vlm":
        specs["patches"] = P(b, None, None)
    if cfg.family == "encdec":
        specs["audio"] = P(b, None, None)
    return specs


def cache_specs(cfg: ArchConfig, dist: Dist, batch: int, seq_len: int) -> dict:
    """Group-stacked cache PartitionSpecs (mirrors tf.init_cache)."""
    from repro.models.transformer import layer_groups
    group, _ = layer_groups(cfg)
    b = batch_spec(dist, batch)
    if b is not None:
        if cfg.n_kv_heads % max(1, dist.n_model) == 0:
            head_axis, seq_axis = dist.model_axis, None
        else:
            head_axis, seq_axis = None, dist.model_axis
    else:
        # batch-1 long context: shard the sequence over everything.
        head_axis = None
        seq_axis = _fit(dist, seq_len,
                        (dist.data_axis, dist.model_axis), None)
    di_axis = _fit(dist, cfg.ssm_d_inner, dist.model_axis, None)

    out = {}
    for i, spec in enumerate(group):
        if spec.kind == "attn":
            kv = P(None, b, seq_axis, head_axis, None)
            out[f"sub{i}"] = {"k": kv, "v": kv}
        else:
            out[f"sub{i}"] = {
                "h": P(None, b, di_axis, None),
                "conv": P(None, b, None, di_axis),
            }
    return out


def enc_kv_spec(cfg: ArchConfig, dist: Dist, batch: int) -> dict:
    b = batch_spec(dist, batch)
    s = P(None, b, None, None, None)
    return {"k": s, "v": s}


def opt_plan(param_specs: dict, opt_shapes: dict, dist: Dist) -> dict:
    """Moment specs mirror param specs; int8 block scales drop the last-axis
    sharding unless the block count still divides it."""

    def moment_spec(pspec: P, mo_shape) -> dict:
        if mo_shape["s"] is None:
            return {"q": pspec, "s": None}
        s_shape = mo_shape["s"].shape
        last = pspec[-1] if len(pspec) else None
        log_domain = len(s_shape) == mo_shape["q"].ndim + 1
        blocks = s_shape[-2] if log_domain else s_shape[-1]
        s_spec = (*pspec[:-1], _fit(dist, blocks, last, None))
        if log_domain:
            s_spec = (*s_spec, None)
        return {"q": pspec, "s": P(*s_spec)}

    def walk(spec_tree, shape_tree):
        if isinstance(spec_tree, P):
            return moment_spec(spec_tree, shape_tree)
        return {k: walk(spec_tree[k], shape_tree[k]) for k in spec_tree}

    return {
        "step": P(),
        "m": walk(param_specs, opt_shapes["m"]),
        "v": walk(param_specs, opt_shapes["v"]),
    }
