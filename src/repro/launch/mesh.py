"""Production meshes (DESIGN.md §4).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the pod axis is outer
data parallelism (or pipeline stages via ``pipeline_over_pod``).

All mesh construction routes through ``make_mesh``, which version-guards
the ``jax.sharding.AxisType`` API: newer JAX releases accept an
``axis_types`` argument (we request Auto axes), older ones (e.g. 0.4.x)
don't have the enum at all and take plain ``jax.make_mesh(shape, axes)``.
"""

from __future__ import annotations

import jax


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-guarded mesh constructor — the ONLY way this repo builds
    meshes (tests/examples included, e.g. a (2,2,2) mini multi-pod)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
