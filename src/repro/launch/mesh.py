"""Production meshes (DESIGN.md §4).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the pod axis is outer
data parallelism (or pipeline stages via ``pipeline_over_pod``).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests/examples (e.g. (2,2,2) mini multi-pod)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
