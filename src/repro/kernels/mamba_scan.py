"""Chunked selective-scan kernel (Mamba1) for falcon-mamba / jamba.

The recurrence  h_t = exp(dt_t ⊙ A)·h_{t-1} + (dt_t·x_t) ⊗ B_t  is
sequential in t but dense over (d_inner, d_state): each step is a
(TD, N) elementwise update — VPU work with perfect (8,128) lane shape when
TD is a multiple of 8 and N = 16 → padded lanes are tolerable since the
(TD, N) update is bandwidth-trivial next to the x/dt/B/C streams.

Grid (B, D/TD, L/TL) with the **sequence axis innermost**: the hidden
state h (TD, N) lives in VMEM scratch and carries across sequence chunks
(TPU grids execute sequentially), resetting at chunk 0. Within a chunk a
``fori_loop`` walks TL steps. Bytes streamed per step ≈ TL·TD·(x,dt,y) +
TL·N·(B,C) — contiguous, double-buffered by the pipeline.

This is the TPU-native answer to the CUDA selective-scan kernel: no warp
shuffles, just VMEM-resident state + chunked streaming (DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,   # inputs
                  y_ref, hout_ref,                             # outputs
                  h_scr, *, tl: int):                          # scratch
    il = pl.program_id(2)
    nl = pl.num_programs(2)

    @pl.when(il == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (TL, TD)
    dt = dt_ref[0].astype(jnp.float32)        # (TL, TD)
    A = a_ref[...].astype(jnp.float32)        # (TD, N)
    Bc = b_ref[0].astype(jnp.float32)         # (TL, N)
    Cc = c_ref[0].astype(jnp.float32)         # (TL, N)
    D = d_ref[...].astype(jnp.float32)        # (1, TD)

    def step(t, carry):
        h, ys = carry
        dt_t = dt[t][:, None]                 # (TD, 1)
        dA = jnp.exp(dt_t * A)                # (TD, N)
        dBx = (dt_t[:, 0] * x[t])[:, None] * Bc[t][None, :]
        h = dA * h + dBx
        y = jnp.sum(h * Cc[t][None, :], axis=1) + D[0] * x[t]   # (TD,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return h, ys

    ys0 = jnp.zeros((tl, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, tl, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(il == nl - 1)
    def _flush():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_l", "interpret"))
def mamba_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, D: jax.Array, *, block_d: int = 512,
               block_l: int = 64, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """x, dt (Bt, L, Dm); A (Dm, N); B, C (Bt, L, N); D (Dm,)
    → (y (Bt, L, Dm), h_final (Bt, Dm, N))."""
    Bt, L, Dm = x.shape
    N = A.shape[1]
    td = min(block_d, Dm)
    tl = min(block_l, L)
    assert Dm % td == 0 and L % tl == 0
    grid = (Bt, Dm // td, L // tl)

    kernel = functools.partial(_mamba_kernel, tl=tl)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tl, td), lambda b, i, j: (b, j, i)),   # x
            pl.BlockSpec((1, tl, td), lambda b, i, j: (b, j, i)),   # dt
            pl.BlockSpec((td, N), lambda b, i, j: (i, 0)),          # A
            pl.BlockSpec((1, tl, N), lambda b, i, j: (b, j, 0)),    # B
            pl.BlockSpec((1, tl, N), lambda b, i, j: (b, j, 0)),    # C
            pl.BlockSpec((1, td), lambda b, i, j: (0, i)),          # D
        ],
        out_specs=[
            pl.BlockSpec((1, tl, td), lambda b, i, j: (b, j, i)),   # y
            pl.BlockSpec((1, td, N), lambda b, i, j: (b, i, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, L, Dm), x.dtype),
            jax.ShapeDtypeStruct((Bt, Dm, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((td, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D.reshape(1, -1))
    return y, h
