"""Tiled flash attention (prefill) — causal / sliding-window / softcap / GQA.

Online-softmax formulation: grid (B, Hq, Sq/TQ, Skv/TK) with the KV axis
innermost; running (m, l, acc) live in VMEM scratch across KV steps and are
flushed to the output block on the last step. GQA is free: the K/V
BlockSpec index map divides the query-head index by the group size, so a
KV head's tile is reused by its whole query group without replication.

Tiles: TQ = TK = 128 (MXU-aligned); head_dim up to 256 resident per tile.
VMEM/step ≈ (TQ + 2·TK)·dh·2 B (bf16) + TQ·dh·4 B (fp32 acc) ≈ 0.4 MB at
dh = 256 — well inside the ~16 MB v5e budget, leaving room for the
double-buffered pipeline.

Sliding-window + causal masks are applied from absolute positions
(``kv_offset`` supports chunked prefill where q starts mid-sequence), and
fully-masked KV tiles short-circuit via ``pl.when`` so the causal upper
triangle and out-of-window bands cost no MXU work — this matters for
gemma2's local layers (window 4096 ≪ 32 k prefill).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, kv_offset: int, tq: int, tk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Absolute positions of this tile pair.
    q_start = iq * tq + kv_offset
    k_start = ik * tk
    # Tile-level visibility test (static bounds → pl.when short-circuit):
    #   causal: earliest q row must not precede the first kv col
    #   window: latest kv col must be within window of the last q row
    visible = True
    if causal:
        visible = visible & (k_start <= q_start + tq - 1)
    if window is not None:
        visible = visible & (k_start + tk - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (TQ, dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (TK, dh)
        v = v_ref[0, 0].astype(jnp.float32)                  # (TK, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = jnp.ones((tq, tk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (TQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (TQ, TK)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "kv_offset", "scale",
    "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, kv_offset: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q (B, Hq, Sq, dh); k, v (B, Hkv, Skv, dh) → (B, Hq, Sq, dh).

    Sq % block_q == 0 and Skv % block_k == 0 (wrapper pads otherwise).
    """
    B, Hq, Sq, dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else dh ** -0.5
    tq, tk = min(block_q, Sq), min(block_k, Skv)
    grid = (B, Hq, Sq // tq, Skv // tk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_offset=kv_offset, tq=tq, tk=tk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, tk, dh), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, tk, dh), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
