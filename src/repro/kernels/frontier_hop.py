"""Fused frontier-hop kernel — one full HNSW beam expansion (§5.3).

``gather_scores`` scores candidate ids the *caller* already materialized,
which forces the beam-search loop to expand ``neighbors[frontier]`` with an
XLA gather first — the candidate ids round-trip through an HBM-resident
(B, F, M) buffer and the embedding rows through a materialized
(B, F·M, d) gather every hop. This kernel fuses the whole hop:

    grid (B, F) — one step per frontier lane. The frontier ids are
    scalar-prefetched, so each step's *neighbor row* arrives via block
    index maps (SMEM copy for DMA addressing + VMEM copy for vector ops)
    before the body runs. The body then issues one async DMA per live
    candidate, pulling its embedding row and its packed validity/category
    word straight from the HBM tables into VMEM scratch, and emits the
    candidate ids, routing scores and result-masked scores for the merge.

Candidate ids therefore never leave the chip: HBM traffic per hop is the
candidate rows actually gathered (counted by the caller as
``rows_gathered``), not O(B·F·M·d) materialization.

Masking contract (shared with ``ref.frontier_hop_ref``):

* a lane is DEAD when its frontier id is INVALID, the neighbor slot is
  INVALID padding, or the query is done (early-exit freeze). Dead lanes
  issue **no DMAs** and emit id = INVALID, scores = -inf — a finished
  query stops costing HBM bandwidth, it doesn't just stop updating bests;
* routing scores mask only dead lanes (tombstones and cross-category
  nodes still route, DiskANN-style);
* result scores additionally mask by the packed ``meta`` word:
  ``meta[i] = category[i]`` for live slots, ``TOMBSTONE`` (-2) for
  removed ones. A candidate qualifies when ``meta != TOMBSTONE`` and the
  query category matches (< 0 = wildcard).

QUANT-AWARE scoring (asymmetric int8): with ``scales`` (N,) the HBM
embedding table is int8 with per-row symmetric scales — each live
candidate's DMA moves d + 4 bytes (int8 row + fp32 scale word) instead
of 4·d, the row casts to fp32 in VMEM and the dot multiplies by the
scale in-kernel. The dequant is fused: no fp32 row ever exists in HBM,
and the scale word is PACKED next to the meta word (one (N, 2) int32
side table, scale bits bitcast into column 1), so the quantized path
keeps the same 2 DMAs per live candidate as the fp32 path — a 4-byte
word would otherwise pay a whole DMA issue/wait of its own. The packing
exists only on the quantized path (selected at trace time); fp32 keeps
its original (N, 1) meta column.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INVALID = -1
TOMBSTONE = -2          # packed meta word for removed (invalid) slots


def _frontier_hop_kernel(frontier_ref,   # scalar-prefetch (B, F) int32
                         done_ref,       # scalar-prefetch (B,) int32
                         qcat_ref,       # scalar-prefetch (B,) int32
                         nbr_smem,       # (1, M) int32 — candidate ids (addresses)
                         nbr_vmem,       # (1, M) int32 — candidate ids (vector)
                         emb_any,        # (N, d) f32/int8, HBM-resident
                         meta_any,       # (N, 1|2) int32, HBM-resident —
                         #                 col 0 meta word; quantized path
                         #                 packs scale bits in col 1
                         q_ref,          # (1, d) f32 query row
                         ids_out, route_out, res_out,      # (1, M) blocks
                         rows_v,         # VMEM (M, d) emb-dtype scratch
                         meta_v,         # VMEM (M, 1|2) int32 scratch
                         sem_rows, sem_meta,               # DMA sems (M,)
                         *, quant: bool):
    b = pl.program_id(0)
    f = pl.program_id(1)
    M = nbr_vmem.shape[1]
    live = (frontier_ref[b, f] >= 0) & (done_ref[b] == 0)

    def _copies(m, cid):
        return (pltpu.make_async_copy(emb_any.at[pl.ds(cid, 1), :],
                                      rows_v.at[pl.ds(m, 1), :],
                                      sem_rows.at[m]),
                pltpu.make_async_copy(meta_any.at[pl.ds(cid, 1), :],
                                      meta_v.at[pl.ds(m, 1), :],
                                      sem_meta.at[m]))

    # Issue every live lane's DMAs back to back, then wait — the copies
    # overlap each other, so the step pays max(row latencies), not the sum.
    for m in range(M):
        cid = nbr_smem[0, m]

        @pl.when(live & (cid >= 0))
        def _issue(m=m, cid=cid):
            row, meta = _copies(m, cid)
            row.start()
            meta.start()
    for m in range(M):
        cid = nbr_smem[0, m]

        @pl.when(live & (cid >= 0))
        def _wait(m=m, cid=cid):
            row, meta = _copies(m, cid)
            row.wait()
            meta.wait()

    ids = nbr_vmem[0, :]                                   # (M,) int32
    lane = live & (ids >= 0)
    # Asymmetric scoring: the stored row (int8 on the quantized path)
    # casts in VMEM, dots against the fp32 query, and the per-row dequant
    # scale — bitcast back out of the packed meta row — multiplies the
    # result after the dot.
    dots = jnp.sum(rows_v[...].astype(jnp.float32)
                   * q_ref[...].astype(jnp.float32), axis=1)   # (M,)
    if quant:
        scale = jax.lax.bitcast_convert_type(meta_v[:, 1], jnp.float32)
        dots = dots * scale
    qc = qcat_ref[b]
    meta = meta_v[:, 0]
    ok = lane & (meta != TOMBSTONE) & ((qc < 0) | (meta == qc))
    ids_out[0, :] = jnp.where(lane, ids, INVALID)
    route_out[0, :] = jnp.where(lane, dots, -jnp.inf)
    res_out[0, :] = jnp.where(ok, dots, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def frontier_hop(emb: jax.Array,        # (N, d) f32 or int8, d % 128 == 0
                 neighbors: jax.Array,  # (N, M) int32, INVALID padded
                 meta: jax.Array,       # (N,) int32 packed valid/category
                 frontier: jax.Array,   # (B, F) int32, INVALID padded
                 queries: jax.Array,    # (B, d) f32
                 query_categories: jax.Array,   # (B,) int32, -1 = wildcard
                 done: jax.Array,       # (B,) int32/bool, 1 = frozen query
                 scales: jax.Array | None = None,   # (N,) f32 when emb int8
                 *, interpret: bool = False
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused beam expansion. Returns (ids, route, res), each (B, F·M):
    candidate ids (INVALID at dead lanes), routing scores (-inf at dead
    lanes only) and result scores (-inf additionally at tombstoned and
    cross-category candidates)."""
    N, d = emb.shape
    M = neighbors.shape[1]
    B, F = frontier.shape
    quant = scales is not None
    meta_col = meta.astype(jnp.int32).reshape(N, 1)
    if quant:
        # Pack the fp32 scale's bits next to the meta word: one (N, 2)
        # side table, one DMA per candidate for both (a lone 4-byte
        # scale transfer would be all DMA overhead, no payload).
        scale_bits = jax.lax.bitcast_convert_type(
            scales.astype(jnp.float32), jnp.int32).reshape(N, 1)
        meta_col = jnp.concatenate([meta_col, scale_bits], axis=1)
    mw = meta_col.shape[1]

    nbr_row = lambda b, f, fr, dn, qc: (jnp.maximum(fr[b, f], 0), 0)
    out_blk = lambda b, f, fr, dn, qc: (b, f)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, F),
        in_specs=[
            # The frontier lane's neighbor row, twice: an SMEM copy whose
            # elements can address the manual HBM DMAs, and a VMEM copy
            # for the vectorized id/mask math.
            pl.BlockSpec((1, M), nbr_row, memory_space=pltpu.SMEM),
            pl.BlockSpec((1, M), nbr_row),
            pl.BlockSpec(memory_space=pltpu.ANY),       # emb (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),       # meta[+scale] (HBM)
            pl.BlockSpec((1, d), lambda b, f, fr, dn, qc: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, M), out_blk),
            pl.BlockSpec((1, M), out_blk),
            pl.BlockSpec((1, M), out_blk),
        ],
        scratch_shapes=[
            pltpu.VMEM((M, d), emb.dtype),
            pltpu.VMEM((M, mw), jnp.int32),
            pltpu.SemaphoreType.DMA((M,)),
            pltpu.SemaphoreType.DMA((M,)),
        ],
    )
    ids, route, res = pl.pallas_call(
        functools.partial(_frontier_hop_kernel, quant=quant),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, F * M), jnp.int32),
            jax.ShapeDtypeStruct((B, F * M), jnp.float32),
            jax.ShapeDtypeStruct((B, F * M), jnp.float32),
        ],
        interpret=interpret,
    )(frontier.astype(jnp.int32), done.astype(jnp.int32),
      query_categories.astype(jnp.int32), neighbors.astype(jnp.int32),
      neighbors.astype(jnp.int32), emb, meta_col, queries)
    return ids, route, res
