"""Decode attention kernel — one new token vs a long KV cache (§ serving).

decode_32k / long_500k lower this shape: q (B, Hq, dh) against
k/v (B, Hkv, S, dh) with ragged valid lengths. The kernel streams the KV
cache in (TK, dh) tiles with online softmax, carrying (m, l, acc) in VMEM
scratch across KV grid steps. Decode is purely HBM-bandwidth-bound
(arithmetic intensity ≈ 1 FLOP/byte), so the tile size just needs to keep
the DMA pipeline busy; TK = 512 rows of bf16 KV ≈ 128 kB/tile at dh = 128.

Ragged batches: tiles fully beyond ``kv_len[b]`` are skipped via
``pl.when`` — a batch with mixed 2 k / 32 k contexts doesn't pay 32 k of
bandwidth for every row (beyond-paper optimization; see EXPERIMENTS §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref,                       # scalar-prefetch (B,) int32
                   q_ref, k_ref, v_ref,           # (1,1,dh), (1,1,TK,dh) ×2
                   o_ref,                         # (1,1,dh)
                   m_scr, l_scr, acc_scr, *,      # VMEM scratch
                   scale: float, softcap: float | None, tk: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    k_start = ik * tk

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                    # (dh,)
        k = k_ref[0, 0].astype(jnp.float32)                    # (TK, dh)
        v = v_ref[0, 0].astype(jnp.float32)                    # (TK, dh)
        s = jnp.einsum("kd,d->k", k, q) * scale                # VPU matvec
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tk,), 0)
        s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev = m_scr[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)                                 # (TK,)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[0, 0] = alpha * l_scr[0, 0] + jnp.sum(p)
        acc_scr[...] = alpha * acc_scr[...] + jnp.einsum("k,kd->d", p, v)[None, :]
        m_scr[0, 0] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        denom = jnp.maximum(l_scr[0, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[0] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "block_k",
                                             "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, softcap: float | None = None,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q (B, Hq, dh); k, v (B, Hkv, S, dh); kv_len (B,) int32 → (B, Hq, dh)."""
    B, Hq, dh = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else dh ** -0.5
    tk = min(block_k, S)
    assert S % tk == 0
    grid = (B, Hq, S // tk)

    kernel = functools.partial(_decode_kernel, scale=scale, softcap=softcap,
                               tk=tk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, j, lens: (b, h, 0)),
            pl.BlockSpec((1, 1, tk, dh), lambda b, h, j, lens: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, tk, dh), lambda b, h, j, lens: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda b, h, j, lens: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
