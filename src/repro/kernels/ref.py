"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function is the direct, unfused mathematical definition — no tiling,
no online softmax, no chunking — used by tests/test_kernels.py to
``assert_allclose`` against the kernels across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dequantize_ref(table: jax.Array, scales: jax.Array | None) -> jax.Array:
    """Per-row symmetric dequant: row i is ``table[i] * scales[i]``.

    The oracle for the quantized data plane: every kernel that *fuses* the
    dequant into its dot product (asymmetric scoring — fp32 query against
    int8 stored rows) must equal the plain fp32 math over this
    materialized table. ``scales`` None = the table is already fp32."""
    t = table.astype(jnp.float32)
    return t if scales is None else t * scales.astype(jnp.float32)[:, None]


def flat_topk_ref(table: jax.Array, valid: jax.Array, queries: jax.Array,
                  scales: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Exact cosine top-1 over the whole table.

    table (N, d) fp32 (rows L2-normalized), valid (N,) bool, queries (B, d).
    Returns (best_score (B,), best_idx (B,) int32); invalid rows excluded.
    With ``scales`` (N,) the table is int8 and row i scores against the
    dequantized ``table[i] * scales[i]``.
    """
    scores = queries.astype(jnp.float32) @ dequantize_ref(table, scales).T
    scores = jnp.where(valid[None, :], scores, -jnp.inf)
    best_idx = jnp.argmax(scores, axis=1).astype(jnp.int32)
    best_score = jnp.take_along_axis(scores, best_idx[:, None].astype(jnp.int32),
                                     axis=1)[:, 0]
    return best_score, best_idx


def flat_topk_masked_ref(table: jax.Array, valid: jax.Array,
                         queries: jax.Array, categories: jax.Array,
                         query_categories: jax.Array,
                         scales: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Category-masked exact top-1 (§5.3): a row qualifies only when valid
    AND same-category as the query (query category < 0 = wildcard)."""
    scores = queries.astype(jnp.float32) @ dequantize_ref(table, scales).T
    ok = valid[None, :] & ((query_categories[:, None] < 0) |
                           (categories[None, :] == query_categories[:, None]))
    scores = jnp.where(ok, scores, -jnp.inf)
    best_idx = jnp.argmax(scores, axis=1).astype(jnp.int32)
    best_score = jnp.take_along_axis(scores, best_idx[:, None].astype(jnp.int32),
                                     axis=1)[:, 0]
    return best_score, best_idx


def gather_scores_ref(table: jax.Array, indices: jax.Array, queries: jax.Array,
                      scales: jax.Array | None = None) -> jax.Array:
    """scores[b,k] = <table[indices[b,k]], queries[b]>; -inf where idx < 0.

    table (N, d), indices (B, K) int32 (may contain -1), queries (B, d).
    With ``scales`` (N,) the table is int8 and the gathered row dequantizes
    through its per-row scale before the dot.
    """
    safe = jnp.maximum(indices, 0)
    vecs = jnp.take(table, safe, axis=0)                        # (B,K,d)
    s = jnp.einsum("bkd,bd->bk", vecs.astype(jnp.float32),
                   queries.astype(jnp.float32))
    if scales is not None:
        s = s * jnp.take(scales.astype(jnp.float32), safe, axis=0)
    return jnp.where(indices < 0, -jnp.inf, s)


def gather_scores_masked_ref(table: jax.Array, indices: jax.Array,
                             queries: jax.Array, slot_categories: jax.Array,
                             query_categories: jax.Array,
                             scales: jax.Array | None = None) -> jax.Array:
    """Category-masked frontier hop: -inf at padding (idx < 0) and where
    the gathered row's category differs from the query's (< 0 = wildcard)."""
    s = gather_scores_ref(table, indices, queries, scales)
    cat = jnp.take(slot_categories, jnp.maximum(indices, 0), axis=0)  # (B,K)
    ok = (query_categories[:, None] < 0) | (cat == query_categories[:, None])
    return jnp.where(ok, s, -jnp.inf)


def frontier_hop_ref(emb: jax.Array, neighbors: jax.Array, meta: jax.Array,
                     frontier: jax.Array, queries: jax.Array,
                     query_categories: jax.Array, done: jax.Array,
                     scales: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused beam expansion (oracle for ``frontier_hop``).

    Expands ``neighbors[frontier]`` to (B, F·M) candidate ids, scores them
    against the queries, and emits (ids, route, res): dead lanes (INVALID
    frontier/neighbor padding, or a done query — the early-exit freeze)
    get id = INVALID and -inf everywhere; result scores additionally mask
    candidates whose packed ``meta`` word (category, or -2 = tombstone)
    does not match the query category (< 0 = wildcard). With ``scales``
    (N,) the embedding table is int8 (per-row symmetric quant).
    """
    B, F = frontier.shape
    nbr = jnp.take(neighbors, jnp.maximum(frontier, 0), axis=0)  # (B,F,M)
    alive = (frontier >= 0)[:, :, None] & \
        (done.astype(jnp.int32) == 0)[:, None, None]
    ids = jnp.where(alive & (nbr >= 0), nbr, -1).reshape(B, -1)
    route = gather_scores_ref(emb, ids, queries, scales)
    m = jnp.take(meta, jnp.maximum(ids, 0), axis=0)              # (B, F·M)
    ok = (ids >= 0) & (m != -2) & \
        ((query_categories[:, None] < 0) | (m == query_categories[:, None]))
    res = jnp.where(ok, route, -jnp.inf)
    return ids, route, res


def scatter_rows_ref(table: jax.Array, rows: jax.Array, vals: jax.Array
                     ) -> jax.Array:
    """Row scatter: out[rows[r]] = vals[r], all other rows unchanged.

    table (N, d); rows (R,) int32 >= 0; vals (R, d). Duplicate row ids must
    carry identical vals rows (matching the kernel's contract)."""
    return table.at[rows].set(vals.astype(table.dtype))


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None, kv_offset: int = 0,
                  scale: float | None = None) -> jax.Array:
    """Full-materialization attention with GQA + masks + softcap.

    q (B, Hq, Sq, dh); k/v (B, Hkv, Skv, dh); Hq % Hkv == 0.
    Query position i attends to kv position j iff
        j <= i + kv_offset                      (causal)
        j >  i + kv_offset - window             (sliding window, if set)
    """
    B, Hq, Sq, dh = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale if scale is not None else dh ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None] + kv_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         kv_len: jax.Array | int | None = None,
                         softcap: float | None = None,
                         scale: float | None = None) -> jax.Array:
    """Single-token decode: q (B, Hq, dh) vs k/v (B, Hkv, S, dh).

    ``kv_len`` masks positions >= kv_len (ragged batches); scalar or (B,).
    """
    B, Hq, dh = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else dh ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if kv_len is not None:
        lens = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
        mask = jnp.arange(S)[None, :] < lens[:, None]          # (B,S)
        logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: jax.Array,
                   h0: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Selective scan (Mamba1), sequential lax.scan oracle.

    x, dt (Bt, L, Dm); A (Dm, N); B, C (Bt, L, N); D (Dm,).
    h_t = exp(dt_t ⊙ A) * h_{t-1} + (dt_t * x_t) ⊗ B_t
    y_t = Σ_n h_t[:, :, n] C_t[n] + D ⊙ x_t
    Returns (y (Bt, L, Dm), h_final (Bt, Dm, N)).
    """
    Bt, L, Dm = x.shape
    N = A.shape[1]
    h = jnp.zeros((Bt, Dm, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A[None, :, :])            # (Bt,Dm,N)
        dBx = (dt_t * x_t)[:, :, None] * B_t[:, None, :]          # (Bt,Dm,N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t) + D[None, :] * x_t
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
