"""Tiled cosine top-1 kernel — the hybrid cache's local search (§5.2).

Streams the HBM-resident embedding table through VMEM in (TN, d) tiles,
scores a resident (B, d) query block on the MXU, and keeps a running
(best_score, best_idx) pair per query in VMEM scratch across grid steps
(the TPU grid is sequential, so scratch acts as the reduction carry).

The reduction is CATEGORY-MASKED (§5.3): each table row carries an int32
category id streamed alongside the valid mask, each query carries one, and
rows from another category are treated exactly like invalid rows — scored
-inf so they can never win the top-1. A query category < 0 is a wildcard
(category-blind scan), which is also the path used when no categories are
supplied, so the masked kernel is the only kernel.

At 1 M × 384 fp32 the table is 1.5 GB: the scan is HBM-bandwidth-bound at
~1.9 ms/batch on v5e (819 GB/s) — which is the paper's "2 ms local search"
budget hit with *brute force*; HNSW beam search (``gather_scores``) cuts
the bytes touched to O(hops · beam · M · d). The category tile adds 4
bytes/row to the 1540-byte row stream (+0.26 % bandwidth).

The scoring is QUANT-AWARE (asymmetric int8): when the table is stored
int8 with a per-row symmetric scale (``scales`` (N,)), the dequant fuses
into the same scan — the int8 tile streams at 1/4 the bytes, casts to
fp32 in VMEM, dots against the fp32 query block on the MXU, and the
per-row scale multiplies the score column *after* the dot (dequant is
linear per row, so no fp32 table ever materializes in HBM). The fp32
path passes scales = 1, so the masked+scaled kernel stays the only
kernel.

Tiling: TN rows of the table per step (multiple of 8 for fp32 sublanes),
d padded to a multiple of 128 (384 = 3×128 natively aligned). B is padded
to a multiple of 8 by the wrapper in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flat_topk_kernel(table_ref, valid_ref, cat_ref,    # table-tile inputs
                      scale_ref,                        # (TN,) dequant scales
                      q_ref, qcat_ref,                  # resident query inputs
                      score_out, idx_out,               # outputs
                      best_s, best_i):                  # VMEM scratch
    step = pl.program_id(0)
    nsteps = pl.num_programs(0)

    @pl.when(step == 0)
    def _init():
        best_s[...] = jnp.full_like(best_s, -jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    tile = table_ref[...].astype(jnp.float32)            # (TN, d); int8→fp32
    q = q_ref[...]                                       # (B, d)
    # MXU: (B, d) x (d, TN) -> (B, TN) in fp32; the per-row dequant scale
    # multiplies the score COLUMN after the dot (dequant is linear per
    # row), so the int8 tile never materializes as fp32 in HBM. fp32
    # tables stream scale = 1 — an exact no-op.
    scores = jax.lax.dot_general(
        q, tile, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    scores = scores * scale_ref[...][None, :]
    valid = valid_ref[...]                               # (TN,) int8 mask
    cat = cat_ref[...]                                   # (TN,) int32
    qcat = qcat_ref[...]                                 # (B,) int32
    ok = (valid[None, :] != 0) & \
        ((qcat[:, None] < 0) | (cat[None, :] == qcat[:, None]))
    scores = jnp.where(ok, scores, -jnp.inf)

    tile_best = jnp.max(scores, axis=1)                  # (B,)
    tile_arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
    TN = tile.shape[0]
    tile_idx = step * TN + tile_arg                      # global row ids

    improved = tile_best > best_s[...]
    best_s[...] = jnp.where(improved, tile_best, best_s[...])
    best_i[...] = jnp.where(improved, tile_idx, best_i[...])

    @pl.when(step == nsteps - 1)
    def _flush():
        score_out[...] = best_s[...]
        idx_out[...] = best_i[...]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def flat_topk(table: jax.Array, valid: jax.Array, queries: jax.Array,
              categories: jax.Array | None = None,
              query_categories: jax.Array | None = None,
              scales: jax.Array | None = None,
              *, block_n: int = 1024, interpret: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """Top-1 cosine search. table (N, d) fp32 — or int8 with ``scales``
    (N,) fp32 per-row symmetric dequant scales — valid (N,) int8/bool,
    queries (B, d) fp32 → (best_score (B,), best_idx (B,) int32).

    ``categories`` (N,) int32 + ``query_categories`` (B,) int32 restrict
    each query's result to its own category (< 0 = wildcard). The pair
    travels together — pass both or neither. Exactly one is a
    ``ValueError``: silently degrading to a category-blind scan would be
    a policy-isolation bypass (cross-category reuse is unsound, §5.4),
    and a lone side would otherwise mask everything to -inf.

    Shape requirements (enforced by the ops.py wrapper): N % block_n == 0,
    d % 128 == 0, B % 8 == 0.
    """
    N, d = table.shape
    B = queries.shape[0]
    assert N % block_n == 0, (N, block_n)
    valid = valid.astype(jnp.int8)
    if (categories is None) != (query_categories is None):
        raise ValueError("flat_topk: categories and query_categories must "
                         "be passed together (got exactly one)")
    if categories is None:
        categories = jnp.full((N,), -1, jnp.int32)
        query_categories = jnp.full((B,), -1, jnp.int32)
    if scales is None:
        scales = jnp.ones((N,), jnp.float32)
    categories = categories.astype(jnp.int32)
    query_categories = query_categories.astype(jnp.int32)
    grid = (N // block_n,)

    score, idx = pl.pallas_call(
        _flat_topk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # table tile
            pl.BlockSpec((block_n,), lambda i: (i,)),       # valid tile
            pl.BlockSpec((block_n,), lambda i: (i,)),       # category tile
            pl.BlockSpec((block_n,), lambda i: (i,)),       # scale tile
            pl.BlockSpec((B, d), lambda i: (0, 0)),         # queries resident
            pl.BlockSpec((B,), lambda i: (0,)),             # query categories
        ],
        out_specs=[
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B,), jnp.int32),
        ],
        interpret=interpret,
    )(table, valid, categories, scales.astype(jnp.float32), queries,
      query_categories)
    return score, idx
