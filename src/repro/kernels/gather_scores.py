"""Scalar-prefetch gather + dot kernel — one HNSW frontier hop (§5.3).

Device beam search expands (B, K = beam·M) candidate node ids per hop and
needs ``scores[b,k] = <emb[idx[b,k]], q[b]>``. On TPU the gather must be
expressed as *block index maps*: the candidate ids are scalar-prefetched
(available before the grid runs) and each grid step DMAs exactly one table
row HBM→VMEM chosen by ``idx_ref`` — the canonical TPU embedding-gather
pattern. Bytes touched: O(B·K·d) instead of the flat scan's O(N·d).

Grid: (B, K). Step (b, k): table row idx[b,k] (1, d) + query row b (1, d)
→ VPU dot → out[b, k]. Tombstones/padding (idx < 0) clamp the DMA to row 0
and the result is masked to -inf in the kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_scores_kernel(idx_ref,               # scalar-prefetched (B, K) int32
                          row_ref, q_ref,        # (1, d) gathered row, (1, d) query
                          out_ref):              # (1, 1)
    b = pl.program_id(0)
    k = pl.program_id(1)
    raw = idx_ref[b, k]
    dot = jnp.sum(row_ref[...].astype(jnp.float32)
                  * q_ref[...].astype(jnp.float32))
    out_ref[0, 0] = jnp.where(raw < 0, -jnp.inf, dot)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_scores(table: jax.Array, indices: jax.Array, queries: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """table (N, d) fp32; indices (B, K) int32 (−1 = padding);
    queries (B, d) fp32 → scores (B, K) fp32 (−inf at padding)."""
    N, d = table.shape
    B, K = indices.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            # Gathered table row: block index chosen by the prefetched ids.
            pl.BlockSpec((1, d), lambda b, k, idx_ref: (jnp.maximum(idx_ref[b, k], 0), 0)),
            pl.BlockSpec((1, d), lambda b, k, idx_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, k, idx_ref: (b, k)),
    )
    return pl.pallas_call(
        _gather_scores_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(indices.astype(jnp.int32), table, queries)
