"""Scalar-prefetch gather + dot kernel — one HNSW frontier hop (§5.3).

Device beam search expands (B, K = beam·M) candidate node ids per hop and
needs ``scores[b,k] = <emb[idx[b,k]], q[b]>``. On TPU the gather must be
expressed as *block index maps*: the candidate ids are scalar-prefetched
(available before the grid runs) and each grid step DMAs exactly one table
row HBM→VMEM chosen by ``idx_ref`` — the canonical TPU embedding-gather
pattern. Bytes touched: O(B·K·d) instead of the flat scan's O(N·d).

Grid: (B, K). Step (b, k): table row idx[b,k] (1, d) + query row b (1, d)
→ VPU dot → out[b, k]. Tombstones/padding (idx < 0) clamp the DMA to row 0
and the result is masked to -inf in the kernel body.

``gather_scores_masked`` additionally fuses the per-query CATEGORY mask
(§5.3) into the same kernel: each grid step also DMAs the gathered row's
int32 category (block-index-mapped off the same prefetched ids, so the
category table is never scanned) and compares it against the query's
category in-kernel. Cross-category candidates score -inf — they can route
the beam but never win result tracking — and the device data plane stays
one kernel: gather + dot + category mask fused.

Both kernels are QUANT-AWARE (asymmetric int8 scoring): with ``scales``
(N,) the table rows are int8 and each step also block-index-maps the
gathered row's fp32 dequant scale off the same prefetched ids, casting
the row in VMEM and multiplying the dot by the scale — the gather moves
d + 4 bytes per candidate instead of 4·d, and no fp32 row ever
round-trips through HBM. The scale operand exists ONLY on the quantized
path (selected at trace time): the fp32 hot loop keeps its original
two-operand grid steps and pays zero extra DMAs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_scores_kernel(idx_ref,               # scalar-prefetched (B, K) int32
                          row_ref, q_ref,        # (1, d) gathered row, (1, d) query
                          out_ref):              # (1, 1)
    b = pl.program_id(0)
    k = pl.program_id(1)
    raw = idx_ref[b, k]
    dot = jnp.sum(row_ref[...].astype(jnp.float32)
                  * q_ref[...].astype(jnp.float32))
    out_ref[0, 0] = jnp.where(raw < 0, -jnp.inf, dot)


def _gather_scores_quant_kernel(idx_ref,         # scalar-prefetched (B, K) int32
                                row_ref,         # (1, d) gathered int8 row
                                scale_ref,       # (1, 1) gathered dequant scale
                                q_ref,           # (1, d) query row
                                out_ref):        # (1, 1)
    b = pl.program_id(0)
    k = pl.program_id(1)
    raw = idx_ref[b, k]
    dot = jnp.sum(row_ref[...].astype(jnp.float32)
                  * q_ref[...].astype(jnp.float32)) * scale_ref[0, 0]
    out_ref[0, 0] = jnp.where(raw < 0, -jnp.inf, dot)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_scores(table: jax.Array, indices: jax.Array, queries: jax.Array,
                  scales: jax.Array | None = None,
                  *, interpret: bool = False) -> jax.Array:
    """table (N, d) fp32 — or int8 with ``scales`` (N,) per-row dequant
    scales — indices (B, K) int32 (−1 = padding); queries (B, d) fp32 →
    scores (B, K) fp32 (−inf at padding)."""
    N, d = table.shape
    B, K = indices.shape

    row_blk = pl.BlockSpec(
        (1, d), lambda b, k, idx_ref: (jnp.maximum(idx_ref[b, k], 0), 0))
    q_blk = pl.BlockSpec((1, d), lambda b, k, idx_ref: (b, 0))
    if scales is None:
        kernel, in_specs, operands = (
            _gather_scores_kernel, [row_blk, q_blk], (table, queries))
    else:
        # Quantized path only: the row's scale shares the row's block
        # index map off the prefetched ids.
        scale_blk = pl.BlockSpec(
            (1, 1), lambda b, k, idx_ref: (jnp.maximum(idx_ref[b, k], 0), 0))
        kernel, in_specs, operands = (
            _gather_scores_quant_kernel, [row_blk, scale_blk, q_blk],
            (table, scales.astype(jnp.float32).reshape(N, 1), queries))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda b, k, idx_ref: (b, k)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(indices.astype(jnp.int32), *operands)


def _gather_scores_masked_kernel(idx_ref,        # scalar-prefetched (B, K) int32
                                 row_ref,        # (1, d) gathered table row
                                 cat_ref,        # (1, 1) gathered row category
                                 q_ref,          # (1, d) query row
                                 qcat_ref,       # (1, 1) query category
                                 out_ref):       # (1, 1)
    b = pl.program_id(0)
    k = pl.program_id(1)
    raw = idx_ref[b, k]
    dot = jnp.sum(row_ref[...].astype(jnp.float32)
                  * q_ref[...].astype(jnp.float32))
    qc = qcat_ref[0, 0]
    ok = (raw >= 0) & ((qc < 0) | (cat_ref[0, 0] == qc))
    out_ref[0, 0] = jnp.where(ok, dot, -jnp.inf)


def _gather_scores_masked_quant_kernel(idx_ref,  # scalar-prefetched (B, K) int32
                                       row_ref,    # (1, d) gathered int8 row
                                       cat_ref,    # (1, 1) gathered category
                                       scale_ref,  # (1, 1) gathered scale
                                       q_ref,      # (1, d) query row
                                       qcat_ref,   # (1, 1) query category
                                       out_ref):   # (1, 1)
    b = pl.program_id(0)
    k = pl.program_id(1)
    raw = idx_ref[b, k]
    dot = jnp.sum(row_ref[...].astype(jnp.float32)
                  * q_ref[...].astype(jnp.float32)) * scale_ref[0, 0]
    qc = qcat_ref[0, 0]
    ok = (raw >= 0) & ((qc < 0) | (cat_ref[0, 0] == qc))
    out_ref[0, 0] = jnp.where(ok, dot, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_scores_masked(table: jax.Array, indices: jax.Array,
                         queries: jax.Array, slot_categories: jax.Array,
                         query_categories: jax.Array,
                         scales: jax.Array | None = None,
                         *, interpret: bool = False) -> jax.Array:
    """Category-masked frontier hop. table (N, d) fp32 — or int8 with
    ``scales`` (N,) per-row dequant scales — indices (B, K) int32 (−1 =
    padding); queries (B, d) fp32; slot_categories (N,) int32;
    query_categories (B,) int32 (−1 = wildcard) → scores (B, K) fp32
    (−inf at padding and at cross-category candidates)."""
    N, d = table.shape
    B, K = indices.shape
    slot_cat = slot_categories.astype(jnp.int32).reshape(N, 1)
    query_cat = query_categories.astype(jnp.int32).reshape(B, 1)

    # Row + its category (+ its scale, quantized path only) share one
    # block index map off the prefetched ids.
    gathered_blk = lambda shape: pl.BlockSpec(
        shape, lambda b, k, idx_ref: (jnp.maximum(idx_ref[b, k], 0), 0))
    in_specs = [gathered_blk((1, d)), gathered_blk((1, 1))]
    operands = [table, slot_cat]
    kernel = _gather_scores_masked_kernel
    if scales is not None:
        in_specs.append(gathered_blk((1, 1)))
        operands.append(scales.astype(jnp.float32).reshape(N, 1))
        kernel = _gather_scores_masked_quant_kernel
    in_specs += [pl.BlockSpec((1, d), lambda b, k, idx_ref: (b, 0)),
                 pl.BlockSpec((1, 1), lambda b, k, idx_ref: (b, 0))]
    operands += [queries, query_cat]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda b, k, idx_ref: (b, k)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(indices.astype(jnp.int32), *operands)
