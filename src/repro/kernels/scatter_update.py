"""Row-scatter update kernel — the device-residency delta flush (§5.2).

The index tables (``emb``, ``neighbors``, ``valid``, ``category``) live
persistently in device HBM; host-side mutations (insert, evict, neighbor
rewires) accumulate in a compact dirty-row log and are applied in place.
A full re-upload is O(capacity·d) HBM traffic per serve step; the scatter
is O(delta·d) — the difference between per-capacity and per-batch sync
cost, which is what keeps the 2 ms local-search budget (§4.4) intact
under a realistic lookup/insert interleave.

Grid: (R,) over delta rows. Step r DMAs the staged row ``vals[r]``
VMEM→HBM into table row ``rows[r]`` — the row ids are scalar-prefetched
(available before the grid runs) and drive the *output* block index map,
the write-side mirror of the gather pattern in ``gather_scores``. The
table operand is aliased to the output (``input_output_aliases``), so
untouched rows are never copied: the kernel is a true in-place HBM
update, not a rebuild.

Contract: row ids must be non-negative, and duplicate ids must carry
identical ``vals`` rows (the grid writes them in order, so identical
payloads make the result deterministic). The ``repro.kernels.ops``
wrapper enforces both when padding the delta to a bucketed size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_rows_kernel(rows_ref,      # scalar-prefetched (R,) int32
                         table_ref,     # (1, d) aliased table row (unread)
                         val_ref,       # (1, d) staged delta row
                         out_ref):      # (1, d) table row rows[r], in place
    del rows_ref, table_ref
    out_ref[...] = val_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_rows(table: jax.Array, rows: jax.Array, vals: jax.Array,
                 *, interpret: bool = False) -> jax.Array:
    """In-place row scatter: ``table[rows[r]] = vals[r]`` for each delta row.

    table (N, d); rows (R,) int32, all >= 0; vals (R, d) same dtype as
    table. Returns the updated table — the input buffer is donated and
    aliased, so on device this touches only the R scattered rows.
    """
    N, d = table.shape
    R = rows.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            # Aliased table operand: block-mapped to the same row the step
            # writes (never read — present only to carry the alias).
            pl.BlockSpec((1, d), lambda r, rows_ref: (rows_ref[r], 0)),
            pl.BlockSpec((1, d), lambda r, rows_ref: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda r, rows_ref: (rows_ref[r], 0)),
    )
    return pl.pallas_call(
        _scatter_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, d), table.dtype),
        input_output_aliases={1: 0},      # table (after the prefetched rows)
        interpret=interpret,
    )(rows.astype(jnp.int32), table, vals.astype(table.dtype))
