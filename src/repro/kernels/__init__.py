"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's data plane (cache similarity search) and the serving substrate
(attention, SSM scan) each get a TPU kernel with explicit BlockSpec VMEM
tiling, a jit'd wrapper in ``ops.py``, and a pure-jnp oracle in ``ref.py``:

    flat_topk        — tiled cosine top-1 + threshold over the cache table,
                       category-masked in-kernel (the hybrid cache's 2 ms
                       local search, §5.2/§5.3)
    frontier_hop     — FUSED beam expansion: scalar-prefetched frontier ids
                       → in-kernel neighbor-row fetch → per-candidate
                       embedding DMAs → masked scores; done queries issue
                       no DMAs (the lookup hot loop, §5.3)
    gather_scores    — scalar-prefetch gather + dot (entry-set scoring);
                       ``gather_scores_masked`` fuses the per-query category
                       mask into the same gather (§5.3)
    flash_attention  — tiled prefill attention (causal / sliding-window /
                       logit softcap / GQA)
    decode_attention — single-token decode against a long KV cache
    mamba_scan       — chunked selective-scan recurrence (Mamba1)

Kernels target TPU (MXU-aligned tiles, VMEM budgets); on this CPU container
they are validated with ``interpret=True`` against the oracles. Model code
paths default to pure-jnp implementations (clean HLO for the dry-run
roofline) and switch to kernels with ``use_pallas=True`` on real TPUs.
"""
