"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * shape hygiene — pad N/B/S/d to kernel tile multiples and slice back;
  * backend dispatch — ``interpret=True`` automatically on CPU (this
    container) so the *same call sites* run on TPU (compiled) and CPU
    (interpreted) without flags;
  * dtype policy — bf16 in / fp32 accumulate for attention; fp32 for cache
    scoring (embeddings are fp32, §5.1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import flat_topk as _ft
from repro.kernels import frontier_hop as _fh
from repro.kernels import gather_scores as _gs
from repro.kernels import mamba_scan as _ms
from repro.kernels import ref as _ref
from repro.kernels import scatter_update as _su


@functools.cache
def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    target = ((n + mult - 1) // mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad, constant_values=value), n


def cache_topk(table: jax.Array, valid: jax.Array, queries: jax.Array,
               categories: jax.Array | None = None,
               query_categories: jax.Array | None = None,
               scales: jax.Array | None = None,
               *, block_n: int = 1024, interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """Cache-table cosine top-1 (the 2 ms local search). Any N, B, d.

    Optional ``categories`` (N,) + ``query_categories`` (B,) int32 restrict
    each query's result to its own category (§5.3); pass both or neither
    (exactly one raises — silent fallback would bypass isolation). Padding
    rows/queries are filled with a category no real query can match.

    Optional ``scales`` (N,) fp32 marks the table as int8 with per-row
    symmetric dequant scales: the kernel fuses the dequant into the scan
    (asymmetric scoring — fp32 queries, int8 rows), streaming ~1/4 the
    table bytes. Padding rows get scale 0 (already excluded by valid=0).
    """
    interpret = _on_cpu() if interpret is None else interpret
    if (categories is None) != (query_categories is None):
        raise ValueError("cache_topk: categories and query_categories must "
                         "be passed together (got exactly one)")
    table, n0 = _pad_to(table, 0, block_n)
    valid = jnp.pad(valid.astype(jnp.int8), (0, table.shape[0] - n0))
    if scales is not None:
        scales = jnp.pad(scales.astype(jnp.float32),
                         (0, table.shape[0] - n0))
    if categories is not None:
        # -2: never equals a real category AND is not the -1 wildcard
        # (pad rows are already excluded by valid=0; this is belt-and-braces).
        categories = jnp.pad(categories.astype(jnp.int32),
                             (0, table.shape[0] - n0), constant_values=-2)
    table, d0 = _pad_to(table, 1, 128)
    queries, _ = _pad_to(queries, 1, 128)
    queries, b0 = _pad_to(queries, 0, 8)
    if query_categories is not None:
        # Query-side padding must be NON-negative: the kernel reads any
        # qcat < 0 as a wildcard (full blind scan on the padded lane).
        # int32 max never equals a real category, so pad lanes match
        # nothing; their outputs are sliced off below regardless.
        query_categories = jnp.pad(query_categories.astype(jnp.int32),
                                   (0, queries.shape[0] - b0),
                                   constant_values=jnp.iinfo(jnp.int32).max)
    score, idx = _ft.flat_topk(table, valid, queries, categories,
                               query_categories, scales, block_n=block_n,
                               interpret=interpret)
    return score[:b0], idx[:b0]


def hop_scores(table: jax.Array, indices: jax.Array, queries: jax.Array,
               slot_categories: jax.Array | None = None,
               query_categories: jax.Array | None = None,
               scales: jax.Array | None = None,
               *, interpret: bool | None = None) -> jax.Array:
    """One HNSW frontier hop: gather + dot. indices (B, K), −1 padded.

    With ``slot_categories`` (N,) + ``query_categories`` (B,) the category
    mask is fused into the gather+dot kernel (one-kernel data plane, §5.3).
    Pass both or neither; exactly one raises (silent fallback to the
    unmasked gather would bypass category isolation).

    With ``scales`` (N,) fp32 the table is int8 (per-row symmetric quant)
    and the dequant fuses into the gather+dot — each candidate moves
    d + 4 bytes instead of 4·d.
    """
    interpret = _on_cpu() if interpret is None else interpret
    if (slot_categories is None) != (query_categories is None):
        raise ValueError("hop_scores: slot_categories and query_categories "
                         "must be passed together (got exactly one)")
    table, _ = _pad_to(table, 1, 128)
    queries, _ = _pad_to(queries, 1, 128)
    if slot_categories is not None and query_categories is not None:
        return _gs.gather_scores_masked(table, indices, queries,
                                        slot_categories, query_categories,
                                        scales, interpret=interpret)
    return _gs.gather_scores(table, indices, queries, scales,
                             interpret=interpret)


def frontier_hop(emb: jax.Array, neighbors: jax.Array, meta: jax.Array,
                 frontier: jax.Array, queries: jax.Array,
                 query_categories: jax.Array, done: jax.Array,
                 scales: jax.Array | None = None,
                 *, impl: str | None = None, interpret: bool | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused HNSW beam expansion: neighbor fetch + embedding gather +
    dot + result mask, driven by the scalar-prefetched frontier ids.

    Returns (candidate ids, routing scores, result scores), each (B, F·M).
    Dead lanes — INVALID frontier/neighbor padding, or a *done* query (the
    early-exit freeze) — emit INVALID / -inf and, on the kernel path,
    issue no gather DMAs at all. ``meta`` is the packed per-slot word
    ``category if valid else -2`` (see kernels/frontier_hop.py). With
    ``scales`` (N,) fp32 the embedding table is int8 and the per-candidate
    DMA + in-kernel dequant move/score d + 4 bytes per row, not 4·d.

    Dispatch (same pattern as ``scatter_rows``): the Pallas kernel on
    compiled backends, the vectorized jnp reference on CPU/interpret —
    ``impl`` ("pallas" | "ref") forces a path for parity tests.
    """
    interpret = _on_cpu() if interpret is None else interpret
    if impl is None:
        impl = "ref" if interpret else "pallas"
    emb, _ = _pad_to(emb, 1, 128)
    queries, _ = _pad_to(queries, 1, 128)
    if impl == "pallas":
        return _fh.frontier_hop(emb, neighbors, meta, frontier, queries,
                                query_categories, done, scales,
                                interpret=interpret)
    return _ref.frontier_hop_ref(emb, neighbors, meta, frontier, queries,
                                 query_categories, done, scales)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows_xla(table: jax.Array, rows: jax.Array, vals: jax.Array
                      ) -> jax.Array:
    # XLA in-place scatter (donated buffer) — what the Pallas kernel lowers
    # to conceptually; used directly where interpret-mode Pallas would only
    # add per-row interpreter overhead (CPU).
    return table.at[rows].set(vals.astype(table.dtype))


def scatter_rows(table: jax.Array, rows: jax.Array, vals: jax.Array,
                 *, interpret: bool | None = None) -> jax.Array:
    """Delta flush: write ``vals[r]`` into ``table[rows[r]]`` in place.

    The device-residency sync primitive (``HNSWIndex.device_tables`` is
    the production caller): the input table buffer is donated and
    aliased, so only the R delta rows move — O(delta·d) HBM traffic
    instead of a full O(N·d) re-upload. Dispatch: the Pallas kernel
    serves lane-aligned 2-D tables (row width a multiple of 128 — the
    embedding table, where ~90 % of the bytes live) on compiled backends;
    1-D flag tables (valid/category, routed through a column view) and
    narrow tables use the XLA in-place scatter, which is already optimal
    for them and avoids off-lane blocks.

    Contract (enforced by callers that pad the delta to a bucket size):
    rows >= 0, duplicate row ids carry identical vals rows.
    """
    interpret = _on_cpu() if interpret is None else interpret
    squeeze = table.ndim == 1
    if squeeze:
        table = table[:, None]
        vals = vals[:, None]
    if interpret or table.shape[1] % 128 != 0:
        out = _scatter_rows_xla(table, rows.astype(jnp.int32), vals)
    else:
        out = _su.scatter_rows(table, rows.astype(jnp.int32), vals)
    return out[:, 0] if squeeze else out


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, kv_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Prefill attention; pads Sq/Skv to tile multiples (mask-safe)."""
    interpret = _on_cpu() if interpret is None else interpret
    sq0, skv0 = q.shape[2], k.shape[2]
    q, _ = _pad_to(q, 2, block_q)
    k, _ = _pad_to(k, 2, block_k)
    v, _ = _pad_to(v, 2, block_k)
    # Padding keys would win softmax mass if unmasked: padded kv positions
    # sit beyond skv0; causal masking handles q-padding rows (garbage rows
    # are sliced off). Non-causal calls mask via a window trick is unsound,
    # so we additionally rely on kv_len semantics: here pad keys score ~0
    # only if causal or skv0 == padded length.
    out = _fa.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, kv_offset=kv_offset,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out[:, :, :sq0, :]


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, softcap: float | None = None,
                     block_k: int = 512, interpret: bool | None = None
                     ) -> jax.Array:
    """Decode one token vs KV cache; ragged kv_len masks padding exactly."""
    interpret = _on_cpu() if interpret is None else interpret
    k, _ = _pad_to(k, 2, block_k)
    v, _ = _pad_to(v, 2, block_k)
    return _dec.decode_attention(q, k, v, kv_len, softcap=softcap,
                                 block_k=block_k, interpret=interpret)


def mamba_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, D: jax.Array, *, block_d: int = 512,
               block_l: int = 64, interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """Selective scan; pads L to block_l (zero dt ⇒ identity steps)."""
    interpret = _on_cpu() if interpret is None else interpret
    L0 = x.shape[1]
    x, _ = _pad_to(x, 1, block_l)
    dt, _ = _pad_to(dt, 1, block_l)   # dt=0 → exp(0·A)=1, dBx=0: state frozen
    B, _ = _pad_to(B, 1, block_l)
    C, _ = _pad_to(C, 1, block_l)
    bd = min(block_d, x.shape[2])
    y, h = _ms.mamba_scan(x, dt, A, B, C, D, block_d=bd, block_l=block_l,
                          interpret=interpret)
    return y[:, :L0], h
