"""Export surfaces for the observability pipeline.

Two text formats over the same data:

* ``prometheus_text`` — Prometheus-style exposition of the metrics
  snapshot (counters/gauges per category, ``_overall`` included) and
  the stage latency histograms (cumulative ``_bucket`` series with
  ``le`` labels, plus ``_sum``/``_count``).
* ``telemetry_report`` — human-readable per-stage p50/p95/p99 table,
  event counts and the span-accounting summary, used by
  ``launch/serve.py --telemetry`` and the bench trace dumps.

Both are deterministic: keys are sorted, floats are rounded, and no
wall-clock reads happen here.
"""

from __future__ import annotations

import math

from repro.obs.trace import (TraceRecorder, coverage_fraction,
                             span_accounting)


def _prom_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"')


def _fmt_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(round(float(v), 9))


def prometheus_text(snapshot: dict | None = None,
                    rec: TraceRecorder | None = None,
                    prefix: str = "repro") -> str:
    """Render a metrics snapshot and/or trace histograms as exposition
    text.  ``snapshot`` is ``MetricsRegistry.snapshot()`` shaped (the
    ``_overall`` row becomes ``category="_overall"``)."""
    lines: list[str] = []
    if snapshot:
        fields = sorted({f for row in snapshot.values() for f in row})
        for f in fields:
            name = f"{prefix}_cache_{f}"
            kind = "gauge" if ("rate" in f or "latency" in f
                               or "seconds" in f or f == "availability"
                               ) else "counter"
            lines.append(f"# TYPE {name} {kind}")
            for cat in sorted(snapshot):
                if f not in snapshot[cat]:
                    continue
                lines.append(f'{name}{{category="{_prom_label(cat)}"}} '
                             f"{_fmt_num(snapshot[cat][f])}")
    if rec is not None:
        from repro.obs.hist import bucket_upper_ms
        name = f"{prefix}_stage_latency_ms"
        lines.append(f"# TYPE {name} histogram")
        for (stage, cat, shard), h in rec.hist.items():
            base = (f'stage="{_prom_label(stage)}",'
                    f'category="{_prom_label(cat)}",shard="{shard}"')
            cum = 0
            for i in sorted(h.counts):
                cum += h.counts[i]
                le = _fmt_num(bucket_upper_ms(i))
                lines.append(f'{name}_bucket{{{base},le="{le}"}} {cum}')
            if not h.counts or bucket_upper_ms(max(h.counts)) != math.inf:
                lines.append(f'{name}_bucket{{{base},le="+Inf"}} {cum}')
            lines.append(f"{name}_sum{{{base}}} {_fmt_num(h.sum_ms)}")
            lines.append(f"{name}_count{{{base}}} {h.count}")
        name = f"{prefix}_events_total"
        lines.append(f"# TYPE {name} counter")
        for ev, n in rec.event_counts().items():
            lines.append(f'{name}{{name="{_prom_label(ev)}"}} {n}')
        lines.append(f"# TYPE {prefix}_spans_opened_total counter")
        lines.append(f"{prefix}_spans_opened_total {rec.opened}")
        lines.append(f"# TYPE {prefix}_spans_closed_total counter")
        lines.append(f"{prefix}_spans_closed_total {rec.closed}")
    return "\n".join(lines) + "\n"


def telemetry_report(rec: TraceRecorder,
                     snapshot: dict | None = None) -> str:
    """Human-readable telemetry summary for ``--telemetry``."""
    acc = span_accounting(rec)
    lines = ["telemetry report",
             f"  spans: opened={acc['opened']} closed={acc['closed']} "
             f"roots={acc['roots']} "
             f"leaf-coverage={coverage_fraction(rec):.3f}"]
    lines.append("  per-stage latency (ms):")
    lines.append(f"    {'stage':<16s} {'count':>7s} {'mean':>9s} "
                 f"{'p50':>9s} {'p95':>9s} {'p99':>9s}")
    for stage in rec.hist.stages():
        h = rec.hist.rollup(stage=stage)
        lines.append(
            f"    {stage:<16s} {h.count:>7d} {h.mean_ms:>9.3f} "
            f"{h.quantile(0.50):>9.3f} {h.quantile(0.95):>9.3f} "
            f"{h.quantile(0.99):>9.3f}")
    evc = rec.event_counts()
    if evc:
        lines.append("  events:")
        for name, n in evc.items():
            lines.append(f"    {name:<24s} {n}")
    if snapshot and "_overall" in snapshot:
        ov = snapshot["_overall"]
        lines.append(
            f"  overall: lookups={ov['lookups']} "
            f"hit_rate={ov['hit_rate']:.3f} "
            f"availability={ov.get('availability', 1.0):.3f} "
            f"degraded_s={ov.get('degraded_seconds', 0.0):.3f}")
    return "\n".join(lines)
