"""Fixed-bucket log-scale latency histograms (no sample storage).

``LatencyHistogram`` is the single latency-distribution primitive for
the repo: benches, the simulator and the trace recorder all feed it
instead of accumulating raw sample lists.  Buckets are fixed at import
time — 8 per octave (growth factor 2^(1/8) ~= 1.09) spanning 1e-3 ms
to ~1e5 ms — so two histograms are always mergeable bucket-by-bucket
and a quantile is reproducible from counts alone.  The exact sum and
count ride along, so ``mean`` has no bucketing error; quantiles carry
at most one bucket width (~9%) of relative error, which is the
resolution the bench gates are written against.

``HistogramSet`` keys histograms by ``(stage, category, shard)`` and
offers roll-ups across any of the three axes; it is the backing store
for the per-stage p50/p95/p99 surfaces in the telemetry report and
the Prometheus exposition.
"""

from __future__ import annotations

import math

# 8 buckets per octave from LO_MS up: bucket i covers
# (LO_MS * G**(i-1), LO_MS * G**i]; bucket 0 is the underflow bucket
# (-inf, LO_MS] and the last bucket is the overflow (everything above
# the top edge lands there).  log2(1e8) * 8 ~= 212.6 -> 214 finite
# edges reach ~1e5 ms.
LO_MS = 1e-3
BUCKETS_PER_OCTAVE = 8
GROWTH = 2.0 ** (1.0 / BUCKETS_PER_OCTAVE)
N_BUCKETS = 216


def bucket_of(ms: float) -> int:
    """Bucket index for a latency in milliseconds."""
    if ms <= LO_MS:
        return 0
    i = 1 + int(math.floor(math.log2(ms / LO_MS) * BUCKETS_PER_OCTAVE))
    # Edge samples: floating-point log2 can land exactly on an edge;
    # nudge down when the computed bucket's lower edge equals ms.
    if i > 0 and LO_MS * GROWTH ** (i - 1) >= ms:
        i -= 1
    return min(i, N_BUCKETS - 1)


def bucket_upper_ms(i: int) -> float:
    """Inclusive upper edge of bucket ``i`` (+inf for the overflow)."""
    if i >= N_BUCKETS - 1:
        return math.inf
    return LO_MS * GROWTH ** i


def _bucket_mid_ms(i: int) -> float:
    """Representative value: geometric midpoint of the bucket."""
    if i == 0:
        return LO_MS
    if i >= N_BUCKETS - 1:
        return LO_MS * GROWTH ** (N_BUCKETS - 2)
    lo = LO_MS * GROWTH ** (i - 1)
    hi = LO_MS * GROWTH ** i
    return math.sqrt(lo * hi)


class LatencyHistogram:
    """Counts-only latency distribution with exact sum/count."""

    __slots__ = ("counts", "count", "sum_ms", "min_ms", "max_ms")

    def __init__(self) -> None:
        self.counts: dict[int, int] = {}
        self.count = 0
        self.sum_ms = 0.0
        self.min_ms = math.inf
        self.max_ms = -math.inf

    def observe(self, ms: float) -> None:
        i = bucket_of(ms)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.count += 1
        self.sum_ms += ms
        if ms < self.min_ms:
            self.min_ms = ms
        if ms > self.max_ms:
            self.max_ms = ms

    @property
    def mean_ms(self) -> float:
        return self.sum_ms / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1]; bucket geometric midpoint, 0.0 when empty."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i in sorted(self.counts):
            seen += self.counts[i]
            if seen >= rank:
                return _bucket_mid_ms(i)
        return _bucket_mid_ms(max(self.counts))

    def percentiles(self, qs=(0.50, 0.95, 0.99)) -> dict[str, float]:
        return {f"p{round(q * 100):d}": self.quantile(q) for q in qs}

    def merge(self, other: "LatencyHistogram") -> None:
        for i, c in other.counts.items():
            self.counts[i] = self.counts.get(i, 0) + c
        self.count += other.count
        self.sum_ms += other.sum_ms
        self.min_ms = min(self.min_ms, other.min_ms)
        self.max_ms = max(self.max_ms, other.max_ms)

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum_ms": round(self.sum_ms, 6),
            "mean_ms": round(self.mean_ms, 6),
            "p50_ms": round(self.quantile(0.50), 6),
            "p95_ms": round(self.quantile(0.95), 6),
            "p99_ms": round(self.quantile(0.99), 6),
            "buckets": {str(i): self.counts[i] for i in sorted(self.counts)},
        }


class HistogramSet:
    """Histograms keyed by ``(stage, category, shard)``."""

    def __init__(self) -> None:
        self._h: dict[tuple[str, str, int], LatencyHistogram] = {}

    def observe(self, stage: str, ms: float, *,
                category: str = "", shard: int = -1) -> None:
        key = (stage, category, shard)
        h = self._h.get(key)
        if h is None:
            h = self._h[key] = LatencyHistogram()
        h.observe(ms)

    def items(self):
        return sorted(self._h.items())

    def __len__(self) -> int:
        return len(self._h)

    def rollup(self, *, stage: str | None = None,
               category: str | None = None,
               shard: int | None = None) -> LatencyHistogram:
        """Merge every histogram matching the given axes (None = any)."""
        out = LatencyHistogram()
        for (st, cat, sh), h in self._h.items():
            if stage is not None and st != stage:
                continue
            if category is not None and cat != category:
                continue
            if shard is not None and sh != shard:
                continue
            out.merge(h)
        return out

    def stages(self) -> list[str]:
        return sorted({st for (st, _, _) in self._h})

    def to_dict(self) -> dict:
        return {f"{st}|{cat}|{sh}": h.to_dict()
                for (st, cat, sh), h in self.items()}
