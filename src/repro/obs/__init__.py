"""Deterministic observability: Clock-timed spans, fixed-bucket
latency histograms, a structured event stream and export surfaces.

See ``docs/ARCHITECTURE.md`` ("Observability") for the span taxonomy
and the empty-recorder parity contract.
"""

from repro.obs.export import prometheus_text, telemetry_report
from repro.obs.hist import HistogramSet, LatencyHistogram
from repro.obs.trace import (NULL_SPAN, Event, Span, TraceRecorder,
                             check_span_accounting, coverage_fraction,
                             span_accounting)

__all__ = [
    "Event", "HistogramSet", "LatencyHistogram", "NULL_SPAN", "Span",
    "TraceRecorder", "check_span_accounting", "coverage_fraction",
    "prometheus_text", "span_accounting", "telemetry_report",
]
