"""Deterministic per-lookup/per-insert tracing on the injectable Clock.

``TraceRecorder`` produces nested spans whose start/duration come from
the same ``Clock`` that charges all simulated latency, so a trace taken
under ``SimClock`` is bit-reproducible run-to-run and CI can gate on
exact span accounting.  The same recorder carries a structured event
stream (faults, failovers, write-behind, migrations, rebalances,
evictions, retries) and feeds the stage/category/shard histogram set
on every span close.

Contract ("empty-recorder parity", mirroring the fault injector's
empty schedule): every instrumented call site goes through a no-op
null span when the recorder is absent, so tracing off leaves counters
and device bytes bit-identical to the untraced build.

Span-accounting invariant (enforced by ``check_span_accounting``):

* every opened span closes (``opened == closed``), including when an
  ``InjectedCrash`` unwinds the stack — spans are context managers;
* under ``SimClock`` with the simulator store stack, all clock charges
  happen inside *leaf* spans, so for every root span the sum of its
  leaf descendants' durations equals the root duration exactly.

Under ``WallClock`` real time accrues between spans, so the equality
becomes a coverage fraction — report it, never assert it.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.obs.hist import HistogramSet

NO_PARENT = -1


@dataclass
class Span:
    span_id: int
    parent_id: int
    stage: str
    category: str
    shard: int
    t0: float
    dur_ms: float | None = None
    attrs: dict = field(default_factory=dict)


@dataclass
class Event:
    name: str
    t: float
    fields: dict


class _SpanHandle:
    """Context manager for one live span; ``set()`` adds attributes."""

    __slots__ = ("_rec", "span")

    def __init__(self, rec: "TraceRecorder", span: Span) -> None:
        self._rec = rec
        self.span = span

    def set(self, **attrs) -> None:
        self.span.attrs.update(attrs)

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._rec._close(self.span)
        return False


class _NullSpan:
    """Shared no-op span: the disabled-tracing hot path."""

    __slots__ = ()

    def set(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class TraceRecorder:
    """Clock-timed span tree + event stream + latency histograms."""

    def __init__(self, clock) -> None:
        self.clock = clock
        self.spans: list[Span] = []
        self.events: list[Event] = []
        self.hist = HistogramSet()
        self.opened = 0
        self.closed = 0
        self._stack: list[int] = []

    # -- spans ---------------------------------------------------------
    def span(self, stage: str, *, category: str = "", shard: int = -1,
             **attrs) -> _SpanHandle:
        parent = self._stack[-1] if self._stack else NO_PARENT
        sp = Span(len(self.spans), parent, stage, category, shard,
                  self.clock.now(), attrs=dict(attrs))
        self.spans.append(sp)
        self._stack.append(sp.span_id)
        self.opened += 1
        return _SpanHandle(self, sp)

    def _close(self, sp: Span) -> None:
        # ``with`` blocks unwind LIFO even under exceptions, so the
        # closing span is always the top of the stack.
        if self._stack and self._stack[-1] == sp.span_id:
            self._stack.pop()
        sp.dur_ms = (self.clock.now() - sp.t0) * 1e3
        self.closed += 1
        self.hist.observe(sp.stage, sp.dur_ms,
                          category=sp.category, shard=sp.shard)

    # -- events & direct histogram feed --------------------------------
    def event(self, name: str, **fields) -> None:
        self.events.append(Event(name, self.clock.now(), dict(fields)))

    def observe_ms(self, stage: str, ms: float, *,
                   category: str = "", shard: int = -1) -> None:
        self.hist.observe(stage, ms, category=category, shard=shard)

    def event_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ev in self.events:
            out[ev.name] = out.get(ev.name, 0) + 1
        return dict(sorted(out.items()))

    # -- export --------------------------------------------------------
    def to_jsonl(self, path) -> int:
        """Dump spans then events, one JSON object per line."""
        n = 0
        with open(path, "w") as f:
            for sp in self.spans:
                f.write(json.dumps(
                    {"type": "span", "id": sp.span_id,
                     "parent": sp.parent_id, "stage": sp.stage,
                     "category": sp.category, "shard": sp.shard,
                     "t0": round(sp.t0, 9),
                     "dur_ms": (None if sp.dur_ms is None
                                else round(sp.dur_ms, 9)),
                     "attrs": sp.attrs}, sort_keys=True) + "\n")
                n += 1
            for ev in self.events:
                f.write(json.dumps(
                    {"type": "event", "name": ev.name,
                     "t": round(ev.t, 9), "fields": ev.fields},
                    sort_keys=True) + "\n")
                n += 1
        return n


# -- span accounting ----------------------------------------------------

def _children_map(spans: list[Span]) -> dict[int, list[Span]]:
    kids: dict[int, list[Span]] = {}
    for sp in spans:
        kids.setdefault(sp.parent_id, []).append(sp)
    return kids


def _leaf_sum_ms(root: Span, kids: dict[int, list[Span]]) -> float:
    """Sum of leaf-descendant durations under ``root`` (iterative)."""
    total = 0.0
    stack = [root]
    while stack:
        sp = stack.pop()
        ch = kids.get(sp.span_id)
        if ch:
            stack.extend(ch)
        elif sp is not root or root.span_id not in kids:
            total += sp.dur_ms or 0.0
    return total


def span_accounting(rec: TraceRecorder, eps_ms: float = 1e-6) -> dict:
    """Summary of the accounting invariant over a finished trace."""
    kids = _children_map(rec.spans)
    roots = kids.get(NO_PARENT, [])
    max_gap = 0.0
    gaps = []
    for root in roots:
        if root.dur_ms is None:
            continue
        gap = abs(_leaf_sum_ms(root, kids) - root.dur_ms)
        max_gap = max(max_gap, gap)
        if gap > eps_ms:
            gaps.append((root.span_id, root.stage, gap))
    return {"opened": rec.opened, "closed": rec.closed,
            "spans": len(rec.spans), "roots": len(roots),
            "max_gap_ms": max_gap, "gapped_roots": gaps}


def check_span_accounting(rec: TraceRecorder,
                          eps_ms: float = 1e-6) -> list[str]:
    """Violations of the accounting invariant; [] when it holds."""
    acc = span_accounting(rec, eps_ms)
    out = []
    if acc["opened"] != acc["closed"]:
        out.append(f"span leak: opened={acc['opened']} "
                   f"closed={acc['closed']}")
    for span_id, stage, gap in acc["gapped_roots"]:
        out.append(f"root span {span_id} ({stage}): leaf durations "
                   f"differ from root by {gap:.6f} ms")
    return out


def coverage_fraction(rec: TraceRecorder) -> float:
    """Leaf time / root time across all roots (WallClock-safe view)."""
    kids = _children_map(rec.spans)
    roots = kids.get(NO_PARENT, [])
    root_ms = sum(r.dur_ms or 0.0 for r in roots)
    if root_ms <= 0.0:
        return 1.0
    leaf_ms = sum(_leaf_sum_ms(r, kids) for r in roots)
    return min(1.0, leaf_ms / root_ms)
