"""Discrete-event serving simulator (reproduces the paper's tables).

Runs a category-heterogeneous query stream (``repro.core.workload``) against
one of three serving stacks on a simulated clock:

    "hybrid" — the paper's architecture: local in-memory HNSW/flat search
               (2 ms), external doc fetch on hit (5 ms), Algorithm 1 policy
               enforcement, category-aware thresholds/TTLs/quotas
    "vdb"    — the baseline: remote vector DB (30 ms search hit-or-miss,
               post-search collection-level threshold, server-side TTL)
    "none"   — no cache: every query pays T_llm

Cache writes go through the unified batched write path
(``SemanticCache.insert_batch``, B=1 per simulated miss) and, with
``use_device``, lookups sync the device-resident index per-delta; the
per-run sync accounting is surfaced as ``SimResult.index_sync``.

Ground truth from the workload generator gives true hit-correctness
(matched intent == query intent → else false positive) and staleness
(content version advanced since caching). Model load can be driven by an
exogenous α(t) profile; observed latencies feed the ``AdaptiveController``
when adaptive policies are enabled (§7.5).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.economics import ResidencyModel
from repro.core.faults import FaultInjector, FaultSchedule
from repro.core.metrics import MetricsRegistry
from repro.core.policy import AdaptiveController, LoadSignal, PolicyEngine
from repro.core.shard import ShardedSemanticCache
from repro.core.storage import (Document, FlakyStore, InMemoryStore,
                                RetryingStore, VectorDBEmulator)
from repro.core.workload import Query, WorkloadGenerator
from repro.obs import NULL_SPAN, LatencyHistogram, TraceRecorder


@dataclass
class SimConfig:
    architecture: str = "hybrid"        # hybrid | vdb | none
    cache_capacity: int = 20000
    index_kind: str = "hnsw"            # hybrid only: hnsw | flat
    use_device: bool = False            # hybrid: device-resident search
                                        # (beam search / flat_topk kernel)
    n_shards: int = 1                   # hybrid: >1 = ShardedSemanticCache
                                        # (quota-byte planner placement)
    search_ms: float = 2.0
    fetch_ms: float = 5.0
    insert_ms: float = 1.0
    vdb_search_ms: float = 30.0
    vdb_threshold: float = 0.85
    vdb_ttl_s: float = 3600.0
    eviction: str = "static"            # hybrid: static | cost_aware
                                        # (core/admission.py scorers)
    adaptive: bool = False
    fp_rate_limit: float = 0.05     # §7.5.6 safety (1.0 disables feedback)
    # exogenous load profile: list of (t_start_s, t_end_s, model, alpha)
    load_spikes: list = field(default_factory=list)
    l1_capacity: int = 0
    seed: int = 0
    # hybrid fault injection (core/faults.py). None = no injector at all
    # — construction is identical to the pre-fault code path, which the
    # bench_faults baseline gate relies on. A FaultSchedule (even an
    # empty one) wires the injector + store retry stack in.
    fault_schedule: FaultSchedule | None = None
    store_retries: int = 3              # RetryingStore bounded attempts
    store_backoff_ms: float = 1.0       # base of the 2^k backoff ladder
    # sharded hybrid only: head-category replication ({cat: k} map or a
    # quota-mass threshold float) and the sustained-outage threshold
    # that triggers OutageRebalance for unreplicated categories. None /
    # None keeps the cache construction identical to the pre-replication
    # path (the bench_faults baseline gate relies on it).
    replication: dict | float | None = None
    rebalance_after_s: float | None = None
    store_budget_ms: float = 50.0       # per-op cumulative latency budget
    write_behind_capacity: int = 1024   # per-shard outage write queue
    # deterministic tracing (repro.obs): wire a TraceRecorder through
    # the whole stack (cache, shards, stores, injector). False keeps
    # every component on the shared NULL_SPAN no-op path — counters and
    # device bytes are bit-identical to the pre-tracing code (the
    # bench_faults parity gate relies on it, same discipline as an
    # absent FaultSchedule).
    trace: bool = False


@dataclass
class SimResult:
    per_category: dict
    overall_hit_rate: float
    mean_latency_ms: float
    p95_latency_ms: float
    model_calls: dict
    model_cost: float
    stale_served: int
    false_positives: int
    n_queries: int
    traffic_to_models: dict              # per model, query counts
    metrics: MetricsRegistry
    # hybrid only: device-sync accounting (full vs delta uploads, bytes
    # moved; summed across shards with a per_shard breakdown when
    # n_shards > 1) — the data-plane cost "Rethinking Caching" argues
    # decides viability alongside hit rate
    index_sync: dict | None = None
    # hybrid only: residency efficiency — mean resident entries sampled
    # once per query (a deterministic counter integral, not wall clock)
    # and hits per resident MB under the ResidencyModel's bytes/entry.
    # This is the unit admission control optimizes: the same hits out of
    # fewer resident bytes (benchmarks/bench_admission.py gates on it).
    mean_resident_entries: float = 0.0
    hits_per_resident_mb: float = 0.0
    # hybrid + fault injection only: availability/degraded accounting —
    # degraded_misses, store_timeouts, write-behind queue counters and
    # the injector's op/visit tallies. None when no injector is wired.
    fault_stats: dict | None = None
    # SimConfig.trace only: the run's TraceRecorder (spans + events +
    # per-stage histograms) for export / span-accounting checks.
    trace: TraceRecorder | None = None

    def summary(self) -> dict:
        return {
            "overall_hit_rate": round(self.overall_hit_rate, 4),
            "mean_latency_ms": round(self.mean_latency_ms, 2),
            "p95_latency_ms": round(self.p95_latency_ms, 2),
            "model_cost": round(self.model_cost, 2),
            "stale_served": self.stale_served,
            "false_positives": self.false_positives,
            "n_queries": self.n_queries,
        }


class ServingSimulator:
    def __init__(self, policies: PolicyEngine, sim: SimConfig,
                 controller: AdaptiveController | None = None):
        self.policies = policies
        self.sim = sim
        self.clock = SimClock()
        self.controller = controller
        if sim.adaptive and controller is None:
            self.controller = AdaptiveController(
                fp_rate_limit=sim.fp_rate_limit)
        if self.controller is not None:
            self.policies.controller = self.controller

        # One recorder shares the sim clock with every traced component;
        # None threads the NULL_SPAN no-op path everywhere.
        self.obs: TraceRecorder | None = \
            TraceRecorder(self.clock) if sim.trace else None

        self.faults: FaultInjector | None = None
        self._retry_stores: list[RetryingStore] = []
        if sim.architecture == "hybrid":
            kw = dict(capacity=sim.cache_capacity, clock=self.clock,
                      index_kind=sim.index_kind, use_device=sim.use_device,
                      search_ms=sim.search_ms, insert_ms=sim.insert_ms,
                      l1_capacity=sim.l1_capacity, seed=sim.seed,
                      eviction=sim.eviction, obs=self.obs)
            if sim.fault_schedule is not None:
                # Fault stack: one shared injector; every shard's doc
                # store becomes RetryingStore(FlakyStore(InMemoryStore))
                # — the injector raises scheduled transients, the retry
                # wrapper absorbs bounded runs with Clock-charged
                # backoff, exhaustion degrades the lookup (StoreTimeout
                # handling in core/cache.py).
                self.faults = FaultInjector(sim.fault_schedule, self.clock,
                                            obs=self.obs)

                def _store(_i: int) -> RetryingStore:
                    s = RetryingStore(FlakyStore(InMemoryStore(),
                                                 self.faults),
                                      clock=self.clock,
                                      retries=sim.store_retries,
                                      backoff_ms=sim.store_backoff_ms,
                                      budget_ms=sim.store_budget_ms,
                                      obs=self.obs)
                    self._retry_stores.append(s)
                    return s

                if sim.n_shards > 1:
                    kw["store_factory"] = _store
                else:
                    kw["store"] = _store(0)
            if sim.n_shards > 1:
                self.cache = ShardedSemanticCache(
                    policies, n_shards=sim.n_shards,
                    faults=self.faults,
                    write_behind_capacity=sim.write_behind_capacity,
                    replication=sim.replication,
                    rebalance_after_s=sim.rebalance_after_s, **kw)
            else:
                self.cache = SemanticCache(policies, **kw)
            # external fetch latency charged here (LatencyModelStore-like)
            self._fetch_ms = sim.fetch_ms
        elif sim.architecture == "vdb":
            self.vdb = VectorDBEmulator(
                dim=384, capacity=sim.cache_capacity, clock=self.clock,
                collection_threshold=sim.vdb_threshold,
                collection_ttl=sim.vdb_ttl_s,
                search_ms=sim.vdb_search_ms, fetch_ms=sim.fetch_ms)
        self.metrics = MetricsRegistry()
        # §7.5.6 monitoring: windowed FP-rate feedback to the controller
        self._fp_window: dict[str, list[int]] = {}
        self.fp_window_size = 50
        # cached ground truth per doc: doc_id -> (intent, version)
        self._truth: dict[int, tuple[int, int]] = {}
        # fallback truth for writes acknowledged WITHOUT a slot (write-
        # behind / fence queues under fault injection): keyed by the
        # response payload, consulted only when a hit's doc_id is
        # unknown — baseline (no-fault) accounting is untouched.
        self._truth_text: dict[tuple[str, str], tuple[int, int]] = {}
        # e2e latency: fixed-bucket log-scale histogram (no per-sample
        # storage) — mean is exact (sum/count), quantiles are bucket
        # midpoints (≤ half a bucket width of relative error).
        self._lat_hist = LatencyHistogram()
        self._model_calls: dict[str, int] = {}
        self._traffic: dict[str, int] = {}
        self._cost = 0.0

    def _span(self, stage: str, **attrs):
        if self.obs is None:
            return NULL_SPAN
        return self.obs.span(stage, **attrs)

    # -- model serving -----------------------------------------------------
    def _alpha(self, model: str) -> float:
        t = self.clock.now()
        for (t0, t1, m, a) in self.sim.load_spikes:
            if m == model and t0 <= t < t1:
                return a
        return 1.0

    def _call_model(self, q: Query) -> float:
        alpha = self._alpha(q.model_name)
        t_ms = q.t_llm_ms * alpha
        with self._span("model_call", category=q.category,
                        model=q.model_name):
            self.clock.advance(t_ms / 1e3)
        self._model_calls[q.model_name] = \
            self._model_calls.get(q.model_name, 0) + 1
        self._cost += q.cost_per_call
        if self.controller is not None:
            # queue depth proxy: spike multiplies effective queueing
            qd = int((alpha - 1.0) * 20)
            self.controller.observe(q.model_name,
                                    LoadSignal(latency_ms=t_ms, queue_depth=qd))
        return t_ms

    # -- one query through the chosen stack ---------------------------------
    def _serve_hybrid(self, q: Query, gen: WorkloadGenerator) -> float:
        # "serve" is the per-query root span: every Clock charge below
        # it (cache stages, doc_fetch, model_call) lands inside a leaf
        # span, so leaf-sum accounting closes exactly under SimClock.
        with self._span("serve", category=q.category):
            return self._serve_hybrid_impl(q, gen)

    def _serve_hybrid_impl(self, q: Query, gen: WorkloadGenerator) -> float:
        t0 = self.clock.now()
        res = self.cache.lookup(q.embedding, q.category)
        st = self.metrics.cat(q.category)
        if res.hit:
            if res.reason != "hit_l1":
                with self._span("doc_fetch", category=q.category):
                    self.clock.advance(self._fetch_ms / 1e3)
            truth = self._truth.get(res.doc_id)
            if truth is None and self.faults is not None:
                truth = self._truth_text.get((q.category, res.response))
            intent, version = truth if truth is not None else (-1, -1)
            is_fp = intent != q.intent_id
            # §7.5.6: feed windowed FP observations back to the controller
            # so relaxation backs off when accuracy degrades.
            if self.controller is not None:
                w = self._fp_window.setdefault(q.category, [])
                w.append(1 if is_fp else 0)
                if len(w) >= self.fp_window_size:
                    self.controller.report_false_positive_rate(
                        q.category, sum(w) / len(w))
                    w.clear()
            if is_fp:
                st.false_positives += 1
                self.cache.metrics.cat(q.category).false_positives += 1
            else:
                st.true_positives += 1
                self.cache.metrics.cat(q.category).true_positives += 1
                cur = gen.version_of(q.category, q.intent_id, self.clock.now())
                if version < cur:
                    st.stale_served += 1
                    self.cache.metrics.cat(q.category).stale_served += 1
        else:
            self._call_model(q)
            slot = self.cache.insert(q.embedding, q.category, q.text,
                                     f"response:{q.text}")
            if slot >= 0:
                # doc_id_of decodes sharded caches' global slot ids too;
                # a replicated write gets the truth recorded under EVERY
                # replica's doc id so failover reads judge identically.
                if hasattr(self.cache, "replica_doc_ids"):
                    for doc_id in self.cache.replica_doc_ids(slot):
                        self._truth[doc_id] = (q.intent_id,
                                               q.content_version)
                else:
                    doc_id = self.cache.doc_id_of(slot)
                    self._truth[doc_id] = (q.intent_id, q.content_version)
            if self.faults is not None:
                # the write may be acknowledged-but-deferred (write-
                # behind / fence) or re-minted under a fresh doc id by a
                # replica catch-up / outage rebuild — the payload-keyed
                # fallback covers every copy whose id truth never saw
                self._truth_text[(q.category, f"response:{q.text}")] = \
                    (q.intent_id, q.content_version)
        return (self.clock.now() - t0) * 1e3

    def _serve_vdb(self, q: Query, gen: WorkloadGenerator) -> float:
        t0 = self.clock.now()
        doc = self.vdb.query(q.embedding)
        st = self.metrics.cat(q.category)
        st.lookups += 1
        if doc is not None:
            st.hits += 1
            intent, version = self._truth.get(("vdb", doc.doc_id),
                                              (-1, -1))
            if intent != q.intent_id:
                st.false_positives += 1
            else:
                st.true_positives += 1
                cur = gen.version_of(q.category, q.intent_id, self.clock.now())
                if version < cur:
                    st.stale_served += 1
        else:
            st.misses += 1
            self._call_model(q)
            self.vdb.insert(q.embedding, Document(
                0, q.text, f"response:{q.text}", 0.0, q.category))
            did = self.vdb._next_doc - 1
            self._truth[("vdb", did)] = (q.intent_id, q.content_version)
        return (self.clock.now() - t0) * 1e3

    def _serve_none(self, q: Query) -> float:
        t0 = self.clock.now()
        self._call_model(q)
        st = self.metrics.cat(q.category)
        st.lookups += 1
        st.misses += 1
        return (self.clock.now() - t0) * 1e3

    # -- main loop -------------------------------------------------------------
    def run(self, gen: WorkloadGenerator, n_queries: int) -> SimResult:
        queries = gen.generate(n_queries)
        resident_integral = 0
        for q in queries:
            # advance the sim clock to the arrival time if ahead
            if q.timestamp > self.clock.now():
                # span-ok: inter-arrival idle, not a serving stage
                self.clock.advance(q.timestamp - self.clock.now())
            self._traffic[q.model_name] = self._traffic.get(q.model_name, 0)
            if self.sim.architecture == "hybrid":
                lat = self._serve_hybrid(q, gen)
                st = self.cache.metrics.cat(q.category)
                resident_integral += len(self.cache)
            elif self.sim.architecture == "vdb":
                lat = self._serve_vdb(q, gen)
            else:
                lat = self._serve_none(q)
            self._lat_hist.observe(lat)
            if self.obs is not None:
                self.obs.observe_ms("e2e", lat, category=q.category)
            self.metrics.cat(q.category).latency_ms_sum += lat
            if self.sim.architecture != "hybrid":
                pass

        reg = (self.cache.metrics if self.sim.architecture == "hybrid"
               else self.metrics)
        mean_resident = 0.0
        hits_per_mb = 0.0
        if self.sim.architecture == "hybrid" and n_queries:
            mean_resident = resident_integral / n_queries
            total_hits = sum(s.hits for s in reg.per_category.values())
            bpe = ResidencyModel(dim=getattr(self.cache, "dim", 384)) \
                .bytes_per_entry()
            resident_mb = mean_resident * bpe / 1e6
            hits_per_mb = total_hits / resident_mb if resident_mb else 0.0
        # merge ground-truth counters into the hybrid registry view
        per_cat = {}
        for name, st in reg.per_category.items():
            d = st.to_dict()
            if self.sim.architecture == "hybrid":
                gt = self.metrics.cat(name)
                d["false_positives"] = gt.false_positives
                d["stale_served"] = gt.stale_served
                tot = gt.false_positives + gt.true_positives
                d["fp_rate"] = round(gt.false_positives / tot, 4) if tot else 0.0
            per_cat[name] = d
        fault_stats = None
        if self.faults is not None:
            per = reg.per_category.values()
            lookups = sum(s.lookups for s in per)
            degraded = sum(s.degraded_misses for s in per)
            fault_stats = {
                "degraded_misses": degraded,
                "store_timeouts": sum(s.store_timeouts for s in per),
                "availability": round(1.0 - degraded / lookups, 4)
                if lookups else 1.0,
                "injector": self.faults.stats(),
            }
            if hasattr(self.cache, "fault_stats"):
                fault_stats["front_door"] = dict(self.cache.fault_stats)
                fault_stats["wb_pending"] = self.cache.wb_pending
                # per-category availability SLO view (sharded only):
                # availability, degraded_misses/seconds, replica count
                fault_stats["slo"] = self.cache.metrics.slo_report()
            if self._retry_stores:
                store = {}
                for s in self._retry_stores:
                    for k, v in s.stats.items():
                        store[k] = store.get(k, 0) + v
                fault_stats["store"] = store
        h = self._lat_hist
        return SimResult(
            per_category=per_cat,
            overall_hit_rate=reg.overall_hit_rate(),
            mean_latency_ms=h.mean_ms,
            p95_latency_ms=h.quantile(0.95),
            model_calls=dict(self._model_calls),
            model_cost=self._cost,
            stale_served=sum(d.get("stale_served", 0)
                             for d in per_cat.values()),
            false_positives=sum(d.get("false_positives", 0)
                                for d in per_cat.values()),
            n_queries=n_queries,
            traffic_to_models=dict(self._model_calls),
            metrics=reg,
            # Both index kinds carry the residency protocol now, and the
            # sharded cache aggregates it (per-shard breakdown included).
            index_sync=(dict(self.cache.sync_stats)
                        if self.sim.architecture == "hybrid" else None),
            mean_resident_entries=mean_resident,
            hits_per_resident_mb=hits_per_mb,
            fault_stats=fault_stats,
            trace=self.obs,
        )
