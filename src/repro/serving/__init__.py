"""Serving layer: the paper's system context.

    simulator — discrete-event cache/LLM latency simulation (paper tables)
    engine    — live batched serving with the semantic cache over real models
    router    — multi-model routing + per-model adaptive policies (§7.5.5)
"""

from repro.serving.simulator import ServingSimulator, SimConfig, SimResult  # noqa: F401
from repro.serving.router import ModelRouter, ModelBackend  # noqa: F401
