"""Live batched serving engine: semantic cache in front of a real JAX model.

The end-to-end path (examples/serve_e2e.py):

    submit(Request) → queue → step():
        embed queries (feature-hash, 384-d)
        cache.lookup_batch with per-request categories  (Algorithm 1)
          — the per-request category vector rides into the index search
            (§5.3), so mixed-category batches resolve to same-category
            matches with no cross-category false misses
        hits  → respond from cache (no model tokens burned)
        misses → batch → prefill → greedy decode loop → respond +
                 ONE cache.insert_batch for the whole batch's write-backs
                 (one store pass, one index delta flush — the device
                 tables sync O(batch) bytes, not O(capacity))

Latency/queue-depth observations feed the ``AdaptiveController`` so cache
policies relax under load (§7.5) — on a real deployment this is the same
code path, just with a bigger mesh under ``Dist``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import SemanticCache
from repro.core.embedding import FeatureHashEmbedder
from repro.core.policy import AdaptiveController, LoadSignal
from repro.core.shard import ShardedSemanticCache
from repro.distributed.fault import StepWatchdog
from repro.models.model import Model
from repro.obs import NULL_SPAN


@dataclass
class Request:
    req_id: int
    text: str
    category: str
    prompt_tokens: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    arrival: float = 0.0


@dataclass
class Response:
    req_id: int
    text: str
    tokens: np.ndarray | None
    cached: bool
    latency_ms: float
    category: str
    reason: str = ""


@dataclass
class EngineStats:
    served: int = 0
    cache_hits: int = 0
    model_tokens: int = 0
    total_latency_ms: float = 0.0
    # per-reason serve counts ("hit", "hit_l1", "model", ...) — with the
    # category-masked index there is no "category_mismatch" miss anymore;
    # cross-category traffic shows up as genuine "no_match"/"model".
    reasons: dict = field(default_factory=dict)
    # device-search data-plane counters (from cache.last_lookup_stats):
    # beam hops run and embedding rows gathered across all lookups — the
    # deterministic cost signal the lookup benchmark gates on.
    search_hops: int = 0
    rows_gathered: int = 0
    # steps the watchdog flagged as stragglers (wall time > factor × the
    # trailing-median step time) — the serving-side liveness signal.
    straggler_steps: int = 0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.served if self.served else 0.0

    def count_reason(self, reason: str) -> None:
        self.reasons[reason] = self.reasons.get(reason, 0) + 1


class ServingEngine:
    """Queue → embed → cache lookup → model on misses → batched
    write-back. ``cache`` is a ``SemanticCache`` or, for multi-shard
    residency, a ``ShardedSemanticCache`` — the fan-out/merge happens
    behind the same lookup_batch/insert_batch API, and
    ``last_lookup_stats`` arrives pre-aggregated across shards so the
    hop/row counters below stay topology-blind."""

    def __init__(self, model: Model, params,
                 cache: SemanticCache | ShardedSemanticCache,
                 *, max_batch: int = 8, prompt_len: int = 64,
                 max_new_tokens: int = 16,
                 controller: AdaptiveController | None = None,
                 model_name: str = "default",
                 watchdog: StepWatchdog | None = None,
                 obs=None):
        self.model = model
        self.params = params
        self.cache = cache
        # Optional TraceRecorder (repro.obs). Share ONE recorder (and
        # one WallClock) with the cache — launch/serve.py does this —
        # so cache stage spans nest under the engine_step root. Wall
        # time is not exhaustively charged, so span accounting reports
        # leaf COVERAGE here, never equality (SimClock-only invariant).
        self.obs = obs
        self.embedder = FeatureHashEmbedder()
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.max_new = max_new_tokens
        self.controller = controller
        self.model_name = model_name
        # Straggler detection on the serve loop itself: every non-empty
        # step() is timed, and steps beyond the watchdog's trailing-
        # median threshold surface as stats.straggler_steps.
        self.watchdog = watchdog if watchdog is not None else StepWatchdog()
        self.queue: list[Request] = []
        self.stats = EngineStats()
        self._next_id = 0

        cfg = model.cfg
        max_len = prompt_len + max_new_tokens

        def generate(params, tokens):
            logits, cache_, kv_len = model.prefill(
                params, {"tokens": tokens}, max_len)

            def body(carry, _):
                cache_, kv_len, tok = carry
                logits, cache_, kv_len = model.decode_step(
                    params, cache_, tok, kv_len)
                tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1
                                 ).astype(jnp.int32)
                return (cache_, kv_len, tok), tok

            tok0 = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1
                              ).astype(jnp.int32)
            (_, _, _), toks = jax.lax.scan(
                body, (cache_, kv_len, tok0), None,
                length=self.max_new - 1)
            return jnp.concatenate([tok0[None], toks], axis=0).T  # (B, new)

        self._generate = jax.jit(generate)

    def _span(self, stage: str, **attrs):
        if self.obs is None:
            return NULL_SPAN
        return self.obs.span(stage, **attrs)

    # ------------------------------------------------------------------ api
    def submit(self, text: str, category: str, prompt_tokens: np.ndarray,
               max_new_tokens: int | None = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(
            req_id=rid, text=text, category=category,
            prompt_tokens=np.asarray(prompt_tokens, np.int32),
            max_new_tokens=max_new_tokens or self.max_new,
            arrival=time.monotonic()))
        return rid

    def step(self) -> list[Response]:
        """Serve one batch from the queue. Returns completed responses."""
        if not self.queue:
            return []
        with self._span("engine_step", batch=min(len(self.queue),
                                                 self.max_batch)):
            return self._step_impl()

    def _step_impl(self) -> list[Response]:
        self.watchdog.step_start()
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        t0 = time.monotonic()

        with self._span("embed", batch=len(batch)):
            embs = self.embedder.embed_batch([r.text for r in batch])
        results = self.cache.lookup_batch(embs, [r.category for r in batch])
        ls = self.cache.last_lookup_stats
        if ls:
            self.stats.search_hops += ls.get("hops", 0)
            self.stats.rows_gathered += ls.get("rows_gathered", 0)

        responses: list[Response] = []
        misses: list[int] = []
        for i, (req, res) in enumerate(zip(batch, results)):
            if res.hit:
                lat = (time.monotonic() - req.arrival) * 1e3
                responses.append(Response(req.req_id, res.response, None,
                                          True, lat, req.category,
                                          reason=res.reason))
                self.stats.served += 1
                self.stats.cache_hits += 1
                self.stats.total_latency_ms += lat
                self.stats.count_reason(res.reason)
            else:
                misses.append(i)

        if misses:
            toks = np.zeros((len(misses), self.prompt_len), np.int32)
            for j, i in enumerate(misses):
                p = batch[i].prompt_tokens[:self.prompt_len]
                toks[j, :len(p)] = p
            with self._span("model_generate", batch=len(misses)):
                out = np.asarray(
                    self._generate(self.params, jnp.asarray(toks)))
            texts = ["tok:" + ",".join(map(str, out[j]))
                     for j in range(len(misses))]
            # one batched write-back for every miss in this step
            self.cache.insert_batch(
                embs[misses], [batch[i].category for i in misses],
                [batch[i].text for i in misses], texts)
            for j, i in enumerate(misses):
                req = batch[i]
                text = texts[j]
                lat = (time.monotonic() - req.arrival) * 1e3
                responses.append(Response(req.req_id, text, out[j], False,
                                          lat, req.category, reason="model"))
                self.stats.served += 1
                self.stats.model_tokens += out.shape[1]
                self.stats.total_latency_ms += lat
                self.stats.count_reason("model")
                if self.controller is not None:
                    self.controller.observe(self.model_name, LoadSignal(
                        latency_ms=lat, queue_depth=len(self.queue)))
        self.watchdog.step_end()
        self.stats.straggler_steps = self.watchdog.straggler_events
        return responses

    def drain(self) -> list[Response]:
        out = []
        while self.queue:
            out.extend(self.step())
        return out
