"""Multi-model routing with per-model adaptive cache policies (§7.5.5).

Each ``ModelBackend`` carries its own load tracker; the router maps
categories to backends and resolves effective cache policies per backend —
Model A under a 3× spike relaxes its categories' thresholds/TTLs while
Model B stays at base policy, steering cache capacity toward the loaded,
expensive model.

Also supports **category-sharded cache groups** (paper §7.4: beyond 10 M
entries, shard by category): the router owns N caches and routes lookups
by category through a ``ShardPlanner`` — quota-byte bin-packing
(core/shard.py), so head categories spread across shards instead of
colliding the way the old crc32-mod hash let them. The hash survives
only as the no-planner fallback.
"""

from __future__ import annotations

from dataclasses import dataclass


from repro.core.cache import SemanticCache
from repro.core.policy import AdaptiveController, LoadSignal, PolicyEngine
from repro.core.shard import ShardPlanner, crc32_shard


@dataclass
class ModelBackend:
    name: str
    t_base_ms: float
    cost_per_call: float
    latency_target_ms: float = 600.0
    queue_target: int = 32
    calls: int = 0
    total_ms: float = 0.0

    def invoke_ms(self, alpha: float = 1.0) -> float:
        self.calls += 1
        t = self.t_base_ms * alpha
        self.total_ms += t
        return t


class ModelRouter:
    def __init__(self, policies: PolicyEngine,
                 backends: list[ModelBackend],
                 controller: AdaptiveController | None = None,
                 n_cache_shards: int = 1,
                 cache_factory=None,
                 planner: ShardPlanner | None = None,
                 shard_capacity: int = 65536):
        self.policies = policies
        self.controller = controller or AdaptiveController()
        self.policies.controller = self.controller
        self.backends = {b.name: b for b in backends}
        for b in backends:
            self.controller.register_model(
                b.name, latency_target_ms=b.latency_target_ms,
                queue_target=b.queue_target)
        self.n_shards = n_cache_shards
        # Placement: quota-byte bin-packing over the registered policies
        # (core/shard.py). A caller-provided planner wins; the crc32 hash
        # remains only as the explicit no-planner fallback
        # (``planner=False`` forces it, for the baseline benchmarks).
        if planner is None and n_cache_shards > 1:
            planner = ShardPlanner.from_policies(
                policies, n_cache_shards, shard_capacity)
        self.planner = planner or None
        if cache_factory is not None:
            self.caches = [cache_factory(i) for i in range(n_cache_shards)]
        else:
            self.caches = []

    # -- category → backend / cache shard -------------------------------------
    def backend_for(self, category: str) -> ModelBackend:
        cfg = self.policies.get(category)
        b = self.backends.get(cfg.model_name)
        if b is None:
            b = next(iter(self.backends.values()))
        return b

    def shard_for(self, category: str) -> int:
        """Cache shard for a category: the quota-byte planner's
        placement (balanced by construction, migration-aware via
        ``planner.assign``); crc32-mod only when no planner exists —
        the legacy hash collides head categories onto one shard."""
        if self.planner is not None:
            return self.planner.shard_of(category)
        return crc32_shard(category, self.n_shards)

    def cache_for(self, category: str) -> SemanticCache | None:
        if not self.caches:
            return None
        return self.caches[self.shard_for(category)]

    # -- load observation ---------------------------------------------------------
    def observe(self, model_name: str, latency_ms: float, queue_depth: int):
        self.controller.observe(model_name,
                                LoadSignal(latency_ms, queue_depth))

    def load_factor(self, model_name: str) -> float:
        return self.controller.load_factor(model_name)

    def effective_policy(self, category: str):
        return self.policies.effective(category)

    # -- reporting ----------------------------------------------------------------
    def report(self) -> dict:
        return {
            name: {"calls": b.calls,
                   "mean_ms": b.total_ms / b.calls if b.calls else 0.0,
                   "load_factor": round(self.load_factor(name), 3),
                   "cost": b.calls * b.cost_per_call}
            for name, b in self.backends.items()
        }
