"""Multi-model routing with per-model adaptive cache policies (§7.5.5).

Each ``ModelBackend`` carries its own load tracker; the router maps
categories to backends and resolves effective cache policies per backend —
Model A under a 3× spike relaxes its categories' thresholds/TTLs while
Model B stays at base policy, steering cache capacity toward the loaded,
expensive model.

Also supports **category-sharded cache groups** (paper §7.4: beyond 10 M
entries, shard by category): the router owns N caches and routes lookups
by category hash, which is how the data-parallel serving groups of the
production mesh each hold a category shard.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.cache import SemanticCache
from repro.core.policy import (AdaptiveController, CategoryConfig,
                               LoadSignal, PolicyEngine)


@dataclass
class ModelBackend:
    name: str
    t_base_ms: float
    cost_per_call: float
    latency_target_ms: float = 600.0
    queue_target: int = 32
    calls: int = 0
    total_ms: float = 0.0

    def invoke_ms(self, alpha: float = 1.0) -> float:
        self.calls += 1
        t = self.t_base_ms * alpha
        self.total_ms += t
        return t


class ModelRouter:
    def __init__(self, policies: PolicyEngine,
                 backends: list[ModelBackend],
                 controller: AdaptiveController | None = None,
                 n_cache_shards: int = 1,
                 cache_factory=None):
        self.policies = policies
        self.controller = controller or AdaptiveController()
        self.policies.controller = self.controller
        self.backends = {b.name: b for b in backends}
        for b in backends:
            self.controller.register_model(
                b.name, latency_target_ms=b.latency_target_ms,
                queue_target=b.queue_target)
        self.n_shards = n_cache_shards
        if cache_factory is not None:
            self.caches = [cache_factory(i) for i in range(n_cache_shards)]
        else:
            self.caches = []

    # -- category → backend / cache shard -------------------------------------
    def backend_for(self, category: str) -> ModelBackend:
        cfg = self.policies.get(category)
        b = self.backends.get(cfg.model_name)
        if b is None:
            b = next(iter(self.backends.values()))
        return b

    def shard_for(self, category: str) -> int:
        import zlib
        return zlib.crc32(category.encode()) % max(1, self.n_shards)

    def cache_for(self, category: str) -> SemanticCache | None:
        if not self.caches:
            return None
        return self.caches[self.shard_for(category)]

    # -- load observation ---------------------------------------------------------
    def observe(self, model_name: str, latency_ms: float, queue_depth: int):
        self.controller.observe(model_name,
                                LoadSignal(latency_ms, queue_depth))

    def load_factor(self, model_name: str) -> float:
        return self.controller.load_factor(model_name)

    def effective_policy(self, category: str):
        return self.policies.effective(category)

    # -- reporting ----------------------------------------------------------------
    def report(self) -> dict:
        return {
            name: {"calls": b.calls,
                   "mean_ms": b.total_ms / b.calls if b.calls else 0.0,
                   "load_factor": round(self.load_factor(name), 3),
                   "cost": b.calls * b.cost_per_call}
            for name, b in self.backends.items()
        }
