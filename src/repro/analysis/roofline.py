"""Three-term roofline from compiled dry-run artifacts (deliverable g).

    compute_s    = HLO_FLOPs / peak_FLOPs          (per chip)
    memory_s     = HLO_bytes / HBM_bw              (per chip)
    collective_s = collective_bytes / link_bw      (per chip)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (post-SPMD =
per-device); collective bytes are parsed from the HLO text by summing
result-shape bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (shapes there are per-device too).
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# e.g.  %ag = bf16[2,512,128]{2,1,0} all-gather(...), or tuple results
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum per-device result bytes per collective kind."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = sum(shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes))
        out[kind] = out.get(kind, 0) + total
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    hlo_flops: float                 # per device
    hlo_bytes: float                 # per device
    collective_bytes: float          # per device
    collectives: dict = field(default_factory=dict)
    model_flops: float = 0.0         # 6·N·D or 2·N·D (global)
    n_devices: int = 256
    param_bytes: float = 0.0         # global (bf16)
    cache_bytes: float = 0.0         # global KV/SSM cache (decode cells)
    kind: str = "train"              # train | prefill | decode

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × devices): remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.n_devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def ideal_step_s(self) -> float:
        """Workload-intrinsic lower bound per device.

        train/prefill: useful-FLOPs compute time (the MFU ideal).
        decode: additionally bounded by one streaming pass over weights +
        KV/SSM state (decode is bandwidth-bound by construction).
        """
        compute = (self.model_flops / self.n_devices) / PEAK_FLOPS
        if self.kind != "decode":
            return compute
        bytes_ideal = (self.param_bytes + self.cache_bytes) / self.n_devices
        return max(compute, bytes_ideal / HBM_BW)

    @property
    def roofline_fraction(self) -> float:
        """ideal_step / roofline step — how close the compiled program is
        to the workload's intrinsic roofline (≈ MFU for train/prefill,
        bandwidth utilization for decode)."""
        if self.step_time_s == 0:
            return 0.0
        return self.ideal_step_s / self.step_time_s

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bottleneck=self.bottleneck,
                 step_time_s=self.step_time_s,
                 ideal_step_s=self.ideal_step_s,
                 useful_flops_fraction=self.useful_flops_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count()
    return (6.0 if shape_kind == "train" else 2.0) * n * tokens


def report_from_dryrun(payload: dict) -> RooflineReport:
    shape = payload["shape"]
    kind = ("train" if "train" in shape
            else "prefill" if "prefill" in shape else "decode")
    parsed = payload.get("hlo_cost")
    if parsed:   # loop-aware measurement (preferred; see hlo_cost.py)
        flops = float(parsed["flops"])
        byts = float(parsed["bytes"])
        coll_bytes = float(parsed["total_collective_bytes"])
        coll = {"bytes": parsed["collective_bytes"],
                "counts": parsed["collective_counts"],
                "total_bytes": coll_bytes}
    else:        # fall back to XLA's single-pass numbers
        cost = payload.get("cost_analysis") or {}
        coll = payload.get("collectives") or {}
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        coll_bytes = float(coll.get("total_bytes", 0.0))
    return RooflineReport(
        arch=payload["arch"], shape=shape, mesh=payload["mesh"],
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=coll_bytes,
        collectives=coll,
        model_flops=float(payload.get("model_flops", 0.0)),
        n_devices=int(payload.get("n_devices", 256)),
        param_bytes=float(payload.get("active_params", 0)) * 2.0,
        cache_bytes=float(payload.get("cache_bytes", 0.0)),
        kind=kind,
    )


def load_reports(path: str) -> list[RooflineReport]:
    import glob
    import os
    reports = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            reports.append(report_from_dryrun(json.load(fh)))
    return reports
