"""Static Pallas footprint estimator: BlockSpec/grid walking, no device.

A Pallas kernel that overflows VMEM fails at *compile* time on real
hardware — but this repo's CI runs the kernels in interpret mode on CPU,
where any block shape "works". A BlockSpec edit that pushes a tile past
the ~16 MB/core VMEM budget (or a scalar-prefetch operand past SMEM)
would therefore sail through every dynamic test and die on first TPU
contact. This module closes that gap statically: it intercepts
``pl.pallas_call`` under ``jax.eval_shape`` (abstract evaluation — no
kernel body ever runs), records each call's grid, BlockSpecs, scratch
shapes and operand avals, and charges every block to the memory space
its spec declares:

* VMEM: block bytes x 2 for grid-blocked operands/outputs (the pipeline
  double-buffers blocks to overlap DMA with compute), x 1 for scratch;
* SMEM: scalar-prefetch operands (they are materialized in scalar
  memory before the grid runs) plus explicit SMEM blocks;
* ANY: HBM-resident — zero on-chip charge (the kernel DMAs rows out of
  it manually, paying VMEM only for its scratch destination);
* semaphores: counted as objects, not bytes.

``check_kernels`` sweeps every production kernel (``flat_topk``,
``gather_scores[_masked]``, ``frontier_hop``, ``scatter_update``)
across the supported shape families — capacity sweep to 1M rows,
d = 384, fp32 and int8+scale operands — and returns a
:class:`~repro.analysis.contracts.Violation` per kernel config whose
estimated footprint exceeds budget. Pure shape arithmetic: safe for CI,
deterministic, and independent of the host's backend.
"""

from __future__ import annotations

import contextlib
import functools
import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import Violation

# Per-core budgets. VMEM is ~16 MB on current TPU generations; SMEM is
# "small" — 1 MiB is the conservative figure we gate scalar-prefetch
# operands against (a frontier table or delta-row list far past that is
# a design bug regardless of the exact hardware limit).
VMEM_BYTES = 16 * 2**20
SMEM_BYTES = 1 * 2**20


def _space(obj) -> str:
    """Normalize a BlockSpec/MemoryRef memory space to one of
    'vmem' | 'smem' | 'any' | 'semaphore'."""
    ms = getattr(obj, "memory_space", None)
    if ms is None:
        return "vmem"
    s = str(ms).lower()
    for key in ("semaphore", "smem", "any", "vmem"):
        if key in s:
            return key
    return "vmem"


def _block_bytes(spec, aval) -> int:
    shape = getattr(spec, "block_shape", None)
    if shape is None:
        shape = aval.shape
    n = 1
    for dim in shape:
        n *= int(dim) if dim is not None else 1
    return n * np.dtype(aval.dtype).itemsize


@dataclass
class KernelFootprint:
    """One captured ``pallas_call``: its static shape facts and the
    VMEM/SMEM bytes the blocks imply."""
    name: str
    grid: tuple
    vmem_bytes: int = 0
    smem_bytes: int = 0
    semaphores: int = 0
    detail: list = field(default_factory=list)

    def _charge(self, label: str, space: str, nbytes: int) -> None:
        if space == "vmem":
            self.vmem_bytes += nbytes
        elif space == "smem":
            self.smem_bytes += nbytes
        self.detail.append((label, space, nbytes))

    def violations(self, target: str, *, vmem_budget: int = VMEM_BYTES,
                   smem_budget: int = SMEM_BYTES) -> list[Violation]:
        out = []
        for space, used, budget in (("VMEM", self.vmem_bytes, vmem_budget),
                                    ("SMEM", self.smem_bytes, smem_budget)):
            if used > budget:
                top = sorted(self.detail, key=lambda t: -t[2])[:3]
                out.append(Violation(
                    "VmemBudget", target,
                    f"kernel '{self.name}' needs {used / 2**20:.2f} MiB "
                    f"{space} (budget {budget / 2**20:.0f} MiB) for grid "
                    f"{self.grid}",
                    "largest blocks: " + ", ".join(
                        f"{l} [{s}] {b / 2**20:.2f} MiB" for l, s, b in top)))
        return out


def _kernel_name(fn) -> str:
    while isinstance(fn, functools.partial):
        fn = fn.func
    return getattr(fn, "__name__", repr(fn))


@contextlib.contextmanager
def capture_pallas_calls():
    """Swap ``pallas_call`` for a recorder while tracing. The fake
    returns zeros of ``out_shape``, so the wrapped computation stays
    traceable under ``jax.eval_shape`` without lowering any kernel —
    the kernel modules resolve ``pl.pallas_call`` at call time, which
    is what makes the module-attribute patch sufficient."""
    import jax.experimental.pallas as pl_mod
    captured: list[KernelFootprint] = []
    real = pl_mod.pallas_call

    def fake_pallas_call(kernel, *, grid_spec=None, grid=None,
                         in_specs=None, out_specs=None, out_shape=None,
                         scratch_shapes=(), **kw):
        n_prefetch = 0
        if grid_spec is not None:
            n_prefetch = getattr(grid_spec, "num_scalar_prefetch", 0)
            grid = grid_spec.grid
            in_specs = grid_spec.in_specs
            out_specs = grid_spec.out_specs
            scratch_shapes = getattr(grid_spec, "scratch_shapes", ())

        def runner(*operands):
            fp = KernelFootprint(name=_kernel_name(kernel),
                                 grid=tuple(grid or ()))
            avals = [jax.ShapeDtypeStruct(jnp.shape(x),
                                          jnp.result_type(x))
                     for x in operands]
            # Scalar-prefetch operands are materialized whole in SMEM
            # before step 0.
            for i, a in enumerate(avals[:n_prefetch]):
                fp._charge(f"prefetch{i}{list(a.shape)}", "smem",
                           math.prod(a.shape)
                           * np.dtype(a.dtype).itemsize)
            specs = jax.tree_util.tree_leaves(
                in_specs, is_leaf=lambda s: hasattr(s, "block_shape"))
            grid_blocked = bool(grid)
            for i, (spec, a) in enumerate(zip(specs, avals[n_prefetch:])):
                space = _space(spec)
                if space == "any":
                    fp.detail.append((f"in{i}[hbm]", "any", 0))
                    continue
                mult = 2 if grid_blocked and space == "vmem" else 1
                fp._charge(f"in{i}{list(a.shape)}", space,
                           mult * _block_bytes(spec, a))
            outs = jax.tree_util.tree_leaves(
                out_shape,
                is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))
            ospecs = jax.tree_util.tree_leaves(
                out_specs, is_leaf=lambda s: hasattr(s, "block_shape"))
            if len(ospecs) < len(outs):
                ospecs = ospecs + [None] * (len(outs) - len(ospecs))
            for i, (spec, a) in enumerate(zip(ospecs, outs)):
                space = _space(spec) if spec is not None else "vmem"
                if space == "any":
                    fp.detail.append((f"out{i}[hbm]", "any", 0))
                    continue
                mult = 2 if grid_blocked and space == "vmem" else 1
                nbytes = (_block_bytes(spec, a) if spec is not None
                          else math.prod(a.shape)
                          * np.dtype(a.dtype).itemsize)
                fp._charge(f"out{i}{list(a.shape)}", space, mult * nbytes)
            for i, sc in enumerate(scratch_shapes or ()):
                space = _space(sc)
                if space == "semaphore":
                    fp.semaphores += 1
                    continue
                shape = getattr(sc, "shape", ())
                dt = getattr(sc, "dtype", jnp.float32)
                fp._charge(f"scratch{i}{list(shape)}", space,
                           math.prod(shape) * np.dtype(dt).itemsize)
            captured.append(fp)
            return [jnp.zeros(s.shape, s.dtype) for s in outs] \
                if isinstance(out_shape, (list, tuple)) else \
                jnp.zeros(out_shape.shape, out_shape.dtype)

        return runner

    pl_mod.pallas_call = fake_pallas_call
    try:
        yield captured
    finally:
        pl_mod.pallas_call = real


def estimate(fn, *args, **kwargs) -> list[KernelFootprint]:
    """Abstractly evaluate ``fn(*args, **kwargs)`` and return the
    footprint of every ``pallas_call`` it issues. ``args`` may be
    arrays or ``ShapeDtypeStruct``s — nothing is computed."""
    with capture_pallas_calls() as captured:
        jax.eval_shape(functools.partial(fn, **kwargs), *args)
    return captured


# ---------------------------------------------------------------------------
# The production sweep.
# ---------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def default_kernel_configs(*, d: int = 384):
    """(name, thunk) per supported kernel shape family. The capacity
    sweep tops out at 1M rows (the paper's flat-scan scale) and covers
    both residency dtypes; thunks call the *real* kernel entry points,
    so BlockSpec edits are picked up automatically."""
    from repro.kernels import flat_topk as FT
    from repro.kernels import frontier_hop as FH
    from repro.kernels import gather_scores as GS
    from repro.kernels import scatter_update as SU

    def _table(N, dtype):
        emb = _sds((N, d), dtype)
        scales = _sds((N,), jnp.float32) if dtype == jnp.int8 else None
        return emb, scales

    configs = []
    for dtype in (jnp.float32, jnp.int8):
        tag = "int8" if dtype == jnp.int8 else "fp32"
        for N in (4096, 65536, 1 << 20):
            for B in (8, 128):
                emb, scales = _table(N, dtype)
                configs.append((
                    f"flat_topk[{tag}] N={N} B={B}",
                    functools.partial(
                        FT.flat_topk, emb, _sds((N,), jnp.int8),
                        _sds((B, d), jnp.float32), _sds((N,), jnp.int32),
                        _sds((B,), jnp.int32), scales)))
        for B, K in ((8, 256), (128, 1024)):
            emb, scales = _table(65536, dtype)
            configs.append((
                f"gather_scores[{tag}] B={B} K={K}",
                functools.partial(
                    GS.gather_scores, emb, _sds((B, K), jnp.int32),
                    _sds((B, d), jnp.float32), scales)))
            configs.append((
                f"gather_scores_masked[{tag}] B={B} K={K}",
                functools.partial(
                    GS.gather_scores_masked, emb, _sds((B, K), jnp.int32),
                    _sds((B, d), jnp.float32), _sds((65536,), jnp.int32),
                    _sds((B,), jnp.int32), scales)))
        for B, F, M in ((8, 32, 32), (128, 32, 32)):
            N = 65536
            emb, scales = _table(N, dtype)
            configs.append((
                f"frontier_hop[{tag}] B={B} F={F} M={M}",
                functools.partial(
                    FH.frontier_hop, emb, _sds((N, M), jnp.int32),
                    _sds((N,), jnp.int32), _sds((B, F), jnp.int32),
                    _sds((B, d), jnp.float32), _sds((B,), jnp.int32),
                    _sds((B,), jnp.int32), scales)))
        for R in (8, 1024, 8192):
            configs.append((
                f"scatter_rows[{tag}] R={R}",
                functools.partial(
                    SU.scatter_rows, _sds((65536, d), dtype),
                    _sds((R,), jnp.int32), _sds((R, d), dtype))))
    return configs


def check_kernels(configs=None, *, vmem_budget: int = VMEM_BYTES,
                  smem_budget: int = SMEM_BYTES
                  ) -> tuple[list[Violation], list[tuple]]:
    """Run the footprint estimator over ``configs`` (default: the full
    production sweep). Returns (violations, report) with report one
    ``(config_name, KernelFootprint)`` per captured kernel launch."""
    configs = default_kernel_configs() if configs is None else configs
    violations: list[Violation] = []
    report: list[tuple] = []
    for name, thunk in configs:
        for fp in estimate(thunk):
            report.append((name, fp))
            violations.extend(fp.violations(
                name, vmem_budget=vmem_budget, smem_budget=smem_budget))
    return violations, report
