"""Roofline + HLO analysis tooling."""
