"""Roofline + HLO analysis tooling and the static hot-path contract
checker (``python -m repro.analysis.check``): HLO/jaxpr lint rules
(``contracts``), Pallas VMEM budget estimation (``vmem``) and the
mirror-coherence AST lint (``mirror_lint``)."""
