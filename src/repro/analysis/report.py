"""Render the dry-run roofline table for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.analysis.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json

from repro.analysis.roofline import load_reports
from repro.configs import skipped_cells


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}µs"


def one_sentence(rep) -> str:
    """What would move the dominant term down."""
    b = rep.bottleneck
    if b == "compute":
        if rep.useful_flops_fraction < 0.30:
            return ("compute-bound with low useful fraction — cut remat "
                    "recompute / duplicate work")
        return "compute-bound near useful FLOPs — increase per-chip batch"
    if b == "memory":
        if rep.kind == "decode":
            return ("HBM-bound on weight+KV streaming — shrink bytes "
                    "touched (KV layout, window slicing, quantized KV)")
        return ("HBM-bound — fuse attention/logit chains (flash kernel) "
                "to stop materializing intermediates")
    return ("collective-bound — reshard to cut all-gather/all-reduce "
            "volume or overlap with compute")


def markdown_table(dirpath: str) -> str:
    reports = load_reports(dirpath)
    lines = [
        "| arch | shape | mesh | compute | memory | collective | bottleneck "
        "| MODEL_FLOPs/HLO | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(reports, key=lambda r: (r.arch, r.shape, r.mesh)):
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {fmt_s(r.compute_s)} "
            f"| {fmt_s(r.memory_s)} | {fmt_s(r.collective_s)} "
            f"| {r.bottleneck} | {r.useful_flops_fraction:.2f} "
            f"| {r.roofline_fraction:.3f} | {one_sentence(r)} |")
    for arch, shape, why in skipped_cells():
        lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | — "
                     f"| SKIP({why}) |")
    return "\n".join(lines)


def summary(dirpath: str) -> dict:
    reports = load_reports(dirpath)
    worst = sorted(reports, key=lambda r: r.roofline_fraction)[:5]
    coll = sorted(reports, key=lambda r: (r.collective_s /
                                          max(1e-12, r.step_time_s)),
                  reverse=True)[:5]
    return {
        "n_cells": len(reports),
        "worst_fraction": [(r.arch, r.shape, r.mesh,
                            round(r.roofline_fraction, 4)) for r in worst],
        "most_collective_bound": [
            (r.arch, r.shape, r.mesh,
             round(r.collective_s / max(1e-12, r.step_time_s), 3))
            for r in coll],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--summary", action="store_true")
    args = ap.parse_args()
    if args.summary:
        print(json.dumps(summary(args.dir), indent=1))
    else:
        print(markdown_table(args.dir))


if __name__ == "__main__":
    main()
