"""Loop-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — a
scanned 95-layer model reports ~1 layer of FLOPs, and collectives inside
the layer scan disappear from naive HLO greps. This analyzer re-walks the
optimized HLO *with loop multiplication*:

  * computations are parsed into op lists with result/operand shapes;
  * ``while`` ops multiply their body+condition totals by the trip count
    XLA annotates in ``backend_config={"known_trip_count":{"n":...}}``;
  * FLOPs come from ``dot``/``convolution`` ops (2 × result × contraction),
    recursing into fusions and called computations;
  * memory bytes are counted at FUSION BOUNDARIES (operands + result of
    top-level ops), which approximates real HBM traffic of fused chains —
    layout no-ops (tuple/bitcast/parameter/get-tuple-element/constant)
    are free;
  * collective bytes/counts are accumulated per kind with multipliers.

Shapes in optimized HLO are post-SPMD = per-device, so all totals are
per-device. This is the measurement backing EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)="
                      r"\{?%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OP_RE = re.compile(r"^\(?[a-z0-9\[\],\s\{\}/_\*]*?\)?\s*"
                    r"([a-z][a-z0-9\-]*)\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
            "after-all", "copy-start", "copy-done", "partition-id",
            "replica-id", "iota", "reshape"}


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """Total (elements, bytes) over all shapes in a type string."""
    elems = total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _bytes_by_dtype(text: str) -> dict[str, int]:
    """Bytes per dtype over all shapes in a type string — the s8-vs-f32
    split the quantized-residency contracts gate on (a quantized trace
    must move its table bytes as s8; an f32 rematerialization of the
    int8 table shows up here as f32 bytes it should not have)."""
    out: dict[str, int] = {}
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[dt] = out.get(dt, 0) + n * _DTYPE_BYTES[dt]
    return out


def _primary_dtype(text: str) -> str | None:
    for dt, _dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            return dt
    return None


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operands: list[str]
    line: str
    trip: int = 1
    calls: list[str] = field(default_factory=list)


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_by_dtype: dict = field(default_factory=dict)
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.bytes_by_dtype.items():
            self.bytes_by_dtype[k] = self.bytes_by_dtype.get(k, 0) + v * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult

    def count_bytes(self, type_text: str) -> float:
        """Charge every shape in ``type_text`` to the total AND to its
        dtype bucket. Returns the bytes charged."""
        nbytes = 0.0
        for dt, v in _bytes_by_dtype(type_text).items():
            self.bytes_by_dtype[dt] = self.bytes_by_dtype.get(dt, 0) + v
            nbytes += v
        self.bytes += nbytes
        return nbytes

    def count_bytes_as(self, nbytes: float, dtype: str | None) -> None:
        """Charge pre-computed bytes to one dtype bucket (partially
        touched operands, where the byte count is not the full shape)."""
        self.bytes += nbytes
        key = dtype or "unknown"
        self.bytes_by_dtype[key] = self.bytes_by_dtype.get(key, 0) + nbytes

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "bytes_by_dtype": dict(self.bytes_by_dtype),
                "collective_bytes": dict(self.collective_bytes),
                "collective_counts": dict(self.collective_counts),
                "total_collective_bytes": self.total_collective_bytes}


def _parse_computations(hlo: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    shapes: dict[str, str] = {}
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if line.endswith("{") and ("->" in line) and "(" in line:
            m = _COMP_HDR_RE.match(line)
            if m:
                name = m.group(1)
                cur = comps.setdefault(name, [])
                continue
        if line == "}" or line.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = everything before the op token
        om = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        kind = om.group(1) if om else "unknown"
        result_type = rhs[:om.start()] if om else rhs
        operands = re.findall(r"%([\w\.\-]+)", rhs[om.end():] if om else "")
        trip = 1
        tm = _TRIP_RE.search(line)
        if tm:
            trip = int(tm.group(1))
        calls = _CALL_RE.findall(line)
        cur.append(Op(name=name, kind=kind, result_type=result_type,
                      operands=operands, line=line, trip=trip, calls=calls))
    return comps


def _dot_flops(op: Op, sym: dict[str, str]) -> float:
    _, _ = sym, op
    res_elems, _ = _shape_elems_bytes(op.result_type)
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if cm and op.operands:
        lhs_type = sym.get(op.operands[0], "")
        dims = []
        for dt, dd in _SHAPE_RE.findall(lhs_type):
            dims = [int(x) for x in dd.split(",") if x]
            break
        for idx in cm.group(1).split(","):
            if idx and dims and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * res_elems * contract


_PARAM_RE = re.compile(r"parameter\((\d+)\)")


def _sliced_param_bytes(op: Op, comps: dict) -> dict[int, int]:
    """Fusion params consumed via dynamic-slice / gather /
    dynamic-update-slice → bytes actually touched per execution."""
    touched: dict[int, int] = {}
    for callee in op.calls:
        ops = comps.get(callee)
        if ops is None:
            continue
        pidx = {}
        for iop in ops:
            pm = _PARAM_RE.search(iop.line)
            if pm and iop.kind == "parameter":
                pidx[iop.name] = int(pm.group(1))
        for iop in ops:
            if iop.kind in ("dynamic-slice", "gather"):
                src = iop.operands[0] if iop.operands else None
                if src in pidx:
                    _, rb = _shape_elems_bytes(iop.result_type)
                    i = pidx[src]
                    touched[i] = touched.get(i, 0) + rb
            elif iop.kind == "dynamic-update-slice":
                src = iop.operands[0] if iop.operands else None
                upd = iop.operands[1] if len(iop.operands) > 1 else None
                if src in pidx:
                    ub = _shape_elems_bytes(
                        _op_type(ops, upd))[1] if upd else 0
                    i = pidx[src]
                    # in-place RMW ≈ 2× the update bytes
                    touched[i] = touched.get(i, 0) + 2 * ub
    return touched


def _op_type(ops: list[Op], name: str | None) -> str:
    for o in ops:
        if o.name == name:
            return o.result_type
    return ""


def analyze(hlo: str, entry: str | None = None) -> Totals:
    comps = _parse_computations(hlo)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([^\s]+)\s*\(", hlo)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, Totals] = {}

    def comp_totals(name: str, stack: tuple = ()) -> Totals:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Totals()
        t = Totals()
        sym = {op.name: op.result_type for op in comps[name]}
        for op in comps[name]:
            if op.kind in FREE_OPS:
                continue
            base = op.kind.replace("-start", "")
            if base in COLLECTIVES:
                rbytes = t.count_bytes(op.result_type)
                t.collective_bytes[base] = \
                    t.collective_bytes.get(base, 0) + rbytes
                t.collective_counts[base] = \
                    t.collective_counts.get(base, 0) + 1
                continue
            if op.kind.endswith("-done"):
                continue
            if op.kind == "while":
                body = Totals()
                for callee in op.calls:
                    body.add(comp_totals(callee, stack + (name,)))
                t.add(body, mult=op.trip)
                continue
            if op.kind in ("fusion", "call", "conditional", "custom-call",
                           "reduce", "sort", "scatter", "map",
                           "select-and-scatter"):
                # boundary bytes: result + operands, with sliced/gathered
                # operands charged at the bytes actually touched (a
                # dynamic-slice fusion inside a scan reads ONE slice per
                # iteration, not the whole stacked tensor).
                t.count_bytes(op.result_type)
                touched = _sliced_param_bytes(op, comps)
                for i, o in enumerate(op.operands):
                    otype = sym.get(o, "")
                    full = _shape_elems_bytes(otype)[1]
                    if i in touched and touched[i] < full:
                        t.count_bytes_as(touched[i], _primary_dtype(otype))
                    else:
                        t.count_bytes(otype)
                # recurse for dots hidden inside (flops only)
                for callee in op.calls:
                    inner = comp_totals(callee, stack + (name,))
                    t.flops += inner.flops
                    for k, v in inner.collective_bytes.items():
                        t.collective_bytes[k] = t.collective_bytes.get(k, 0) + v
                    for k, v in inner.collective_counts.items():
                        t.collective_counts[k] = t.collective_counts.get(k, 0) + v
                continue
            if op.kind in ("dot", "convolution"):
                t.flops += _dot_flops(op, sym)
                t.count_bytes(op.result_type)
                for o in op.operands:
                    t.count_bytes(sym.get(o, ""))
                continue
            # generic op: boundary bytes + 1 flop/elem for arithmetic
            t.count_bytes(op.result_type)
            for o in op.operands:
                t.count_bytes(sym.get(o, ""))
        memo[name] = t
        return t

    # Only memoize per computation — multipliers applied at call sites.
    return comp_totals(entry)


def analyze_compiled(compiled) -> dict:
    return analyze(compiled.as_text()).to_dict()
