"""``python -m repro.analysis.check`` — the static-analysis CI gate.

Applies every hot-path contract to the real system, with zero
wall-clock-dependent assertions (everything is lowered, parsed or
AST-walked — nothing is timed):

1. **HLO contracts** (``contracts.py``) on every {index kind} x
   {resident dtype} cell: the classified-search executable (fused
   Pallas hop forced, as production dispatches on compiled backends)
   and both delta-flush scatter executables, checked for materialized
   embedding gathers, host transfers, dropped donation and int8
   rematerialization.
2. **Compile budget** on the serving tier: a {flat,hnsw} x
   {fp32,int8} x {1,2}-shard sweep of ``ShardedSemanticCache`` serve
   batches B = 1..8, asserting each shard-index family compiled exactly
   one program (bucketing's contract).
3. **Pallas VMEM/SMEM budget** (``vmem.py``): static footprint of
   every production kernel across the supported shape families.
4. **Mirror-coherence lint** (``mirror_lint.py``) over the core
   index/cache/shard modules.
5. **Span-coverage lint** (``span_lint.py``) over the traced serving
   stack: every ``clock.advance`` charge in a traced stage must open a
   span (or carry a ``# span-ok`` pragma), or span accounting silently
   stops closing exactly.

Exit status 0 = every contract holds; 1 = violations (printed one per
line with evidence). CI gates on it in the ``static-analysis`` job.
"""

from __future__ import annotations

import sys

import numpy as np

from repro.analysis import mirror_lint, span_lint, vmem
from repro.analysis.contracts import (CompileBudget, Violation,
                                      collect_compile_census,
                                      collect_hot_path_traces, run_rules)

INDEX_KINDS = ("flat", "hnsw")
EMB_DTYPES = ("float32", "int8")
SHARD_COUNTS = (1, 2)
SERVE_BATCHES = (1, 2, 3, 5, 8)


def _policies():
    from repro.core.policy import CategoryConfig, PolicyEngine
    return PolicyEngine([
        CategoryConfig("a", threshold=0.85, ttl=1e6, quota=0.4),
        CategoryConfig("b", threshold=0.80, ttl=1e6, quota=0.4),
    ])


def check_hlo_contracts(log=print) -> list[Violation]:
    out: list[Violation] = []
    for kind in INDEX_KINDS:
        for dtype in EMB_DTYPES:
            traces = collect_hot_path_traces(kind, dtype)
            viols = run_rules(traces)
            log(f"  {kind}/{dtype}: {len(traces)} traces "
                f"({', '.join(t.name.split(':')[1] for t in traces)}) — "
                f"{len(viols)} violations")
            out.extend(viols)
    return out


def check_compile_budget(log=print) -> list[Violation]:
    from repro.core.shard import ShardedSemanticCache
    out: list[Violation] = []
    rng = np.random.default_rng(0)
    for kind in INDEX_KINDS:
        for dtype in EMB_DTYPES:
            for n_shards in SHARD_COUNTS:
                cache = ShardedSemanticCache(
                    _policies(), dim=384, capacity=256, n_shards=n_shards,
                    index_kind=kind, use_device=True, emb_dtype=dtype,
                    seed=0)
                # Seed a little content so the sweep searches a live
                # index, then serve every queue-drain batch size.
                vecs = rng.standard_normal((8, 384)).astype(np.float32)
                vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
                cats = ["a", "b"] * 4
                cache.insert_batch(vecs, cats, [f"q{i}" for i in range(8)],
                                   [f"r{i}" for i in range(8)])
                census = collect_compile_census(
                    cache, batches=SERVE_BATCHES,
                    name=f"{kind}/{dtype}/shards={n_shards}")
                viols = CompileBudget().check(census)
                log(f"  {census.name}: families="
                    f"{ {k: v for k, v in sorted(census.families.items())} }"
                    f" — {len(viols)} violations")
                out.extend(viols)
    return out


def check_vmem(log=print) -> list[Violation]:
    viols, report = vmem.check_kernels()
    peak = max(report, key=lambda t: t[1].vmem_bytes)
    log(f"  {len(report)} kernel launches estimated; peak VMEM "
        f"{peak[1].vmem_bytes / 2**20:.2f} MiB ({peak[0]}) of "
        f"{vmem.VMEM_BYTES / 2**20:.0f} MiB budget — "
        f"{len(viols)} violations")
    return viols


def check_mirror(log=print) -> list[Violation]:
    paths = mirror_lint.default_paths()
    viols = mirror_lint.lint_paths(paths)
    log(f"  {len(paths)} modules linted "
        f"({', '.join(p.name for p in paths)}) — {len(viols)} violations")
    return viols


def check_spans(log=print) -> list[Violation]:
    paths = span_lint.default_paths()
    viols = span_lint.lint_paths(paths)
    log(f"  {len(paths)} modules linted "
        f"({', '.join(p.name for p in paths)}) — {len(viols)} violations")
    return viols


def main(argv=None) -> int:
    quiet = bool(argv) and "-q" in argv
    log = (lambda *a, **k: None) if quiet else print
    sections = (
        ("HLO contracts (gather / host-transfer / donation / dtype)",
         check_hlo_contracts),
        ("Compile budget (serve-batch bucketing)", check_compile_budget),
        ("Pallas VMEM/SMEM budget", check_vmem),
        ("Mirror-coherence lint", check_mirror),
        ("Span-coverage lint (traced Clock charges)", check_spans),
    )
    violations: list[Violation] = []
    for title, fn in sections:
        log(f"[{title}]")
        violations.extend(fn(log))
    if violations:
        print(f"\nFAIL: {len(violations)} contract violation(s)",
              file=sys.stderr)
        for v in violations:
            print(str(v), file=sys.stderr)
        return 1
    log("\nOK: all hot-path contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
