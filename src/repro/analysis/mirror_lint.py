"""Mirror-coherence AST lint: every host-table write marks dirty rows.

The device-residency protocol (core/hnsw.py) keeps host numpy tables as
the source of truth and a persistent device mirror synced by a dirty-row
delta scatter. The protocol's one unfixable failure mode is a host write
that never lands in the dirty log: the device serves stale rows forever,
and ``tests/test_coherence.py`` can only catch it if its sampled
workload happens to hit the drifted row. This lint closes the bug class
statically: it parses the source and demands that every function writing
a mirror table also marks rows dirty on the same path.

A *write* is a subscript assignment whose base attribute is a mirror
table — ``self.emb[slot] = vec``, ``idx.neighbors[0][slot] = ...``,
``self.slot_inserted[slot] = now`` (the cache-layer aliases of
``index.inserted`` / ``index.category`` count too). A function is
*covered* when it also contains one of:

* a dirty-log call — ``<base>._dirty.add(...)`` / ``._dirty.update(...)``;
* a delegate insert — ``.add_batch(...)`` (the index entry point that
  does its own marking, which is how the cache layer's alias writes
  ride the same delta flush);
* a ``# mirror-ok`` pragma on the write's line, for writes whose
  marking provably happens in every caller (e.g. ``_quantize_slot``,
  which every call site already dirties).

Granularity is deliberately per-function, not per-statement: dataflow
through local views (``row = self.neighbors[l][nb]; row[...] = ...``)
is beyond static subscript matching, and a function that touches the
dirty log at all has demonstrated it knows the protocol. The lint's job
is the function that *never* does — the exact shape of the incoherence
bug.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.contracts import Violation

# Host-side tables with a device mirror (core/hnsw.py) plus the cache
# layer's aliases of them (core/cache.py binds slot_inserted /
# slot_category to the index's inserted / category tables).
MIRROR_TABLES = frozenset({
    "emb", "emb_q", "emb_scale", "valid", "category", "inserted",
    "neighbors", "slot_inserted", "slot_category",
})
DIRTY_METHODS = frozenset({"add", "update"})
DELEGATE_METHODS = frozenset({"add_batch"})
PRAGMA = "# mirror-ok"


def _mirror_table_of(target: ast.expr) -> str | None:
    """The mirror table a subscript-assignment target writes, if any:
    peel subscript layers (``neighbors[l][slot, :]`` nests two) down to
    the base attribute."""
    node = target
    depth = 0
    while isinstance(node, ast.Subscript):
        node = node.value
        depth += 1
    if depth and isinstance(node, ast.Attribute) \
            and node.attr in MIRROR_TABLES:
        return node.attr
    return None


def _is_dirty_marker(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    if not isinstance(fn, ast.Attribute):
        return False
    if fn.attr in DELEGATE_METHODS:
        return True
    return (fn.attr in DIRTY_METHODS
            and isinstance(fn.value, ast.Attribute)
            and fn.value.attr == "_dirty")


def _assign_targets(node: ast.AST):
    if isinstance(node, ast.Assign):
        for t in node.targets:
            yield from (t.elts if isinstance(t, (ast.Tuple, ast.List))
                        else (t,))
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        yield node.target


def lint_source(src: str, filename: str = "<string>") -> list[Violation]:
    """Lint one module's source text. Returns a Violation per mirror
    write in a function with no dirty marking and no pragma."""
    tree = ast.parse(src, filename=filename)
    lines = src.splitlines()
    out: list[Violation] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        writes: list[tuple[str, int]] = []
        covered = False
        for node in ast.walk(fn):
            for target in _assign_targets(node):
                table = _mirror_table_of(target)
                if table is None:
                    continue
                line = lines[node.lineno - 1] if node.lineno <= len(lines) \
                    else ""
                if PRAGMA in line:
                    continue
                writes.append((table, node.lineno))
            if _is_dirty_marker(node):
                covered = True
        if writes and not covered:
            tables = sorted({t for t, _ in writes})
            first = min(ln for _, ln in writes)
            out.append(Violation(
                "MirrorCoherence", f"{filename}:{fn.name}",
                f"writes mirror table(s) {tables} without marking rows "
                f"dirty (`_dirty.add/update`), delegating to add_batch, "
                f"or a `{PRAGMA}` pragma — the device mirror will serve "
                f"stale rows after the next delta flush",
                f"first write at line {first}: "
                f"{lines[first - 1].strip()[:120]}"))
    return out


def default_paths() -> list[Path]:
    core = Path(__file__).resolve().parent.parent / "core"
    return [core / "hnsw.py", core / "cache.py", core / "shard.py"]


def lint_paths(paths=None) -> list[Violation]:
    """Lint every file that touches mirror tables (default: the core
    index / cache / shard modules)."""
    out: list[Violation] = []
    for p in (default_paths() if paths is None else paths):
        p = Path(p)
        out.extend(lint_source(p.read_text(), filename=p.name))
    return out
