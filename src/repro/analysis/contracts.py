"""Static hot-path contract checker: rules over jaxprs and optimized HLO.

The paper's economics — a 2 ms miss cost making long-tail categories
cacheable (break-even 3-5 % instead of 15-20 %) — hold only while the
compiled hot path keeps its structural invariants. Each invariant was
introduced by a specific PR and was, until now, pinned by at most one
scattered dynamic assertion:

* **NoMaterializedGather** (PR 3): the fused frontier-hop path never
  materializes a ``(B, F·M, d)`` embedding gather in XLA — candidate
  rows move as per-candidate kernel DMAs, so HBM traffic is
  O(rows actually gathered), not O(B·F·M·d) per hop.
* **NoHostTransfer**: no host callbacks / infeed / outfeed inside a
  hot-path executable — a host round trip inside the 2 ms budget is a
  silent 10-100x regression that wall-clock CI noise can hide.
* **DonationHonored** (PR 2): the delta-flush scatter really aliases
  its table operand (input donated), so a sync moves O(delta) bytes
  instead of copying the whole O(capacity·d) table every flush.
* **DtypeDiscipline** (PR 4): a quantized trace reads the ``emb_q``
  table *as s8* and never silently rematerializes it as a full fp32
  table before the dot (the dequant must stay fused per-row/tile).
* **CompileBudget** (PR 3): batch bucketing gives ONE executable per
  {index kind, dtype} family across B = 1..max_batch — not one per
  batch size, which would multiply warm-up latency and jit-cache
  footprint under serving traffic.

This module turns those into reusable :class:`Rule` objects over two
target kinds — :class:`HloTrace` (a lowered + compiled hot-path
executable: optimized HLO text parsed with the ``hlo_cost`` analyzer,
plus the StableHLO lowering, which carries the donation attributes that
CPU executables drop) and :class:`CompileCensus` (deterministic
compilation counters observed over a batch-size sweep). It parses, it
never times: every check here is wall-clock independent and safe to
gate CI on. ``python -m repro.analysis.check`` applies the rules to
every real hot path; ``tests/test_contracts.py`` holds the
synthetic-violation fixtures each rule must flag.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from repro.analysis import hlo_cost

# Embedding-payload dtypes: the only dtypes a materialized (B, K, d)
# candidate gather could carry. Index/id gathers (s32) are fine.
_EMB_DTYPES = ("f64", "f32", "bf16", "f16", "s8")

# Host-transfer fingerprints in optimized HLO. ``custom-call`` is NOT
# enough by itself — TopK lowers to a benign custom-call on CPU — so
# custom-call targets are matched against the blocklist below.
_HOST_TRANSFER_OPS = frozenset({
    "outfeed", "infeed", "send", "recv", "send-done", "recv-done",
})
_HOST_CUSTOM_CALL_RE = re.compile(
    r"callback|host|py_func|xla_ffi_python", re.IGNORECASE)
_CUSTOM_CALL_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')

# StableHLO donation attribute: jit donation survives lowering on every
# backend (CPU executables drop the HLO-level input_output_alias, so the
# compiled text cannot be used for this check).
_ALIAS_ATTR_RE = re.compile(r"tf\.aliasing_output|jax\.buffer_donor")
_MAIN_ARG_RE = re.compile(r"%arg(\d+):\s*tensor<[^>]*>\s*(\{[^}]*\})?")


# ---------------------------------------------------------------------------
# Targets.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Violation:
    """One broken contract: which rule, on which target, and the HLO /
    source evidence a reviewer needs to locate the regression."""
    rule: str
    target: str
    message: str
    evidence: str = ""

    def __str__(self) -> str:
        ev = f"\n      {self.evidence}" if self.evidence else ""
        return f"[{self.rule}] {self.target}: {self.message}{ev}"


@dataclass
class HloTrace:
    """One hot-path executable as a static-analysis target.

    ``hlo`` is the optimized HLO text (``lowered.compile().as_text()``)
    — what actually runs, post-fusion. ``stablehlo`` is the lowering
    text (``lowered.as_text()``), kept because donation is recorded
    there as ``tf.aliasing_output`` argument attributes on every
    backend. ``meta`` carries the structural facts rules check against:

      d            lane-padded embedding width of this trace
      capacity     index capacity (full-table row count)
      emb_dtype    "float32" | "int8" — selects DtypeDiscipline
      donated_args tuple of argument indices that MUST be donated
    """
    name: str
    hlo: str = ""
    stablehlo: str = ""
    meta: dict = field(default_factory=dict)
    _comps: dict | None = field(default=None, repr=False)

    def computations(self) -> dict[str, list]:
        """Parsed op lists per HLO computation (hlo_cost's parser)."""
        if self._comps is None:
            self._comps = hlo_cost._parse_computations(self.hlo)
        return self._comps

    def ops(self):
        for ops in self.computations().values():
            yield from ops


@dataclass
class CompileCensus:
    """Compilation counters per executable family, observed over a
    deterministic batch-size sweep (``search_stats["compilations"]``
    counts distinct compiled signatures). Bucketing's contract: each
    family compiles ``expected`` programs no matter how many batch
    sizes it served."""
    name: str
    families: dict[str, int] = field(default_factory=dict)
    expected: int = 1


# ---------------------------------------------------------------------------
# Rule framework.
# ---------------------------------------------------------------------------

class Rule:
    """One hot-path contract. ``target_kind`` selects which targets the
    rule sees; ``check`` returns the violations (empty = contract
    holds). Rules must be pure functions of their target — no clocks,
    no device state — so the checker is deterministic in CI."""

    name = "Rule"
    target_kind: type = HloTrace

    def check(self, target) -> list[Violation]:
        raise NotImplementedError

    def _v(self, target, message: str, evidence: str = "") -> Violation:
        return Violation(self.name, target.name, message, evidence)


def run_rules(targets, rules=None) -> list[Violation]:
    """Apply every rule to every target it understands."""
    rules = DEFAULT_RULES if rules is None else rules
    out: list[Violation] = []
    for t in targets:
        for r in rules:
            if isinstance(t, r.target_kind):
                out.extend(r.check(t))
    return out


# ---------------------------------------------------------------------------
# Rules.
# ---------------------------------------------------------------------------

class NoMaterializedGather(Rule):
    """No XLA-materialized ``(B, K, d)`` embedding gather on a fused
    trace (PR 3's zero-gather invariant, previously a one-off regex in
    tests/test_lookup_pipeline.py). A rank >= 3 gather whose minor dim
    is the trace's embedding width is candidate rows round-tripping
    through HBM — the exact thing the frontier-hop kernel exists to
    avoid."""

    name = "NoMaterializedGather"

    def check(self, trace: HloTrace) -> list[Violation]:
        d = int(trace.meta.get("d", 0))
        out = []
        for op in trace.ops():
            if op.kind != "gather":
                continue
            for dt, dims in hlo_cost._SHAPE_RE.findall(op.result_type):
                if dt not in _EMB_DTYPES:
                    continue
                shape = [int(x) for x in dims.split(",") if x]
                if len(shape) >= 3 and d and shape[-1] == d:
                    out.append(self._v(
                        trace,
                        f"materialized {dt}{shape} embedding gather — "
                        f"candidate rows must move as per-candidate "
                        f"kernel DMAs, not an XLA gather",
                        op.line.strip()[:160]))
        return out


class NoHostTransfer(Rule):
    """No host transfers inside a hot-path executable: infeed/outfeed/
    send/recv ops, or custom-calls into python/host callbacks. One host
    round trip inside the 2 ms search budget silently costs more than
    the entire local search."""

    name = "NoHostTransfer"

    def check(self, trace: HloTrace) -> list[Violation]:
        out = []
        for op in trace.ops():
            if op.kind in _HOST_TRANSFER_OPS:
                out.append(self._v(trace,
                                   f"host-transfer op '{op.kind}' on the "
                                   f"hot path", op.line.strip()[:160]))
            elif op.kind == "custom-call":
                m = _CUSTOM_CALL_TARGET_RE.search(op.line)
                target = m.group(1) if m else ""
                if _HOST_CUSTOM_CALL_RE.search(target):
                    out.append(self._v(
                        trace,
                        f"host-callback custom-call "
                        f"'{target}' on the hot path",
                        op.line.strip()[:160]))
        return out


class DonationHonored(Rule):
    """Every buffer the flush path donates is actually aliased in the
    lowering (``tf.aliasing_output`` on the argument). A dropped alias
    means the 'in-place' delta scatter quietly copies the whole
    O(capacity·d) table every sync — the exact cost delta sync exists
    to avoid — and nothing at runtime would ever notice."""

    name = "DonationHonored"

    def check(self, trace: HloTrace) -> list[Violation]:
        donated = trace.meta.get("donated_args", ())
        if not donated or not trace.stablehlo:
            return []
        m = re.search(r"func\.func public @main\((.*?)\)\s*->",
                      trace.stablehlo, re.S)
        sig = m.group(1) if m else trace.stablehlo
        attrs = {int(i): (a or "") for i, a in _MAIN_ARG_RE.findall(sig)}
        out = []
        for i in donated:
            if not _ALIAS_ATTR_RE.search(attrs.get(i, "")):
                out.append(self._v(
                    trace,
                    f"argument {i} is not donated/aliased in the "
                    f"lowering — the delta flush copies the full table "
                    f"instead of updating in place",
                    f"main arg attrs: {attrs.get(i, '<missing>')!r}"))
        return out


class DtypeDiscipline(Rule):
    """Quantized traces keep the int8 table int8. Two checks, sharing
    the ``hlo_cost`` per-dtype byte accounting with bench_quant's gate:
    (1) no ``convert`` rematerializes a capacity-row fp32 copy of the
    int8 table (per-row/tile converts inside the fused kernels are the
    *intended* dequant and stay untouched); (2) the trace actually
    moves s8 bytes at all — a quantized trace with zero s8 traffic
    means the fp32 control-plane table leaked onto the hot path."""

    name = "DtypeDiscipline"
    # A convert is "full-table" when it covers at least this fraction of
    # capacity rows in ONE op. Tile-streamed dequant (flat_topk converts
    # one block_n = 1024 row tile per loop trip) stays under it as long
    # as traces are collected at capacity >= 2x the largest tile — which
    # is why ``collect_hot_path_traces`` defaults to capacity 4096.
    full_table_frac = 0.5

    def check(self, trace: HloTrace) -> list[Violation]:
        if trace.meta.get("emb_dtype") != "int8":
            return []
        cap = int(trace.meta.get("capacity", 0))
        d = int(trace.meta.get("d", 0))
        out = []
        for op in trace.ops():
            if op.kind != "convert":
                continue
            for dt, dims in hlo_cost._SHAPE_RE.findall(op.result_type):
                if dt not in ("f32", "f64", "bf16", "f16"):
                    continue
                shape = [int(x) for x in dims.split(",") if x]
                if (len(shape) >= 2 and cap and shape[-1] == d
                        and shape[0] >= cap * self.full_table_frac):
                    out.append(self._v(
                        trace,
                        f"silent fp32 materialization: convert -> "
                        f"{dt}{shape} rebuilds the int8 table as fp32 "
                        f"before the dot (dequant must stay fused "
                        f"per-row)", op.line.strip()[:160]))
        split = hlo_cost.analyze(trace.hlo).bytes_by_dtype
        if split.get("s8", 0) == 0:
            out.append(self._v(
                trace,
                "quantized trace moves zero s8 bytes — the int8 "
                "resident table is not on this hot path (fp32 "
                "control plane leaked into the compiled search?)",
                f"bytes_by_dtype: { {k: int(v) for k, v in split.items()} }"))
        return out


class CompileBudget(Rule):
    """One executable per {index kind, dtype} family across the whole
    serve-batch sweep: bucketing pads B to powers of two so B = 1..max
    share one compiled program. families maps family key -> distinct
    compiled signatures observed; each must equal ``expected``."""

    name = "CompileBudget"
    target_kind = CompileCensus

    def check(self, census: CompileCensus) -> list[Violation]:
        out = []
        for fam, n in sorted(census.families.items()):
            if n != census.expected:
                out.append(Violation(
                    self.name, census.name,
                    f"family {fam}: {n} compiled programs over the "
                    f"batch sweep (expected {census.expected}) — "
                    f"batch bucketing regressed",
                    f"families: {census.families}"))
        return out


DEFAULT_RULES: tuple[Rule, ...] = (
    NoMaterializedGather(), NoHostTransfer(), DonationHonored(),
    DtypeDiscipline(), CompileBudget(),
)


# ---------------------------------------------------------------------------
# Trace collection: the real hot paths, lowered the way production
# dispatches them (fused kernels forced so the CPU checker sees the
# same program structure the TPU runs).
# ---------------------------------------------------------------------------

def _unit_rows(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _padded_d(d: int) -> int:
    return d + ((-d) % 128)


def build_index(index_kind: str, emb_dtype: str, *, dim: int = 384,
                capacity: int = 4096, n: int = 64, seed: int = 0):
    """A small populated index of the production shape family (d = 384
    lane-native): capacity only scales table rows, not trace structure,
    so contract checks stay cheap. Capacity must stay >= 2x the largest
    scoring tile (flat_topk's block_n = 1024 rows) so DtypeDiscipline
    can tell tile-streamed dequant from full-table rematerialization."""
    from repro.core.hnsw import FlatIndex, HNSWIndex, HNSWParams
    rng = np.random.default_rng(seed)
    vecs = _unit_rows(rng, n, dim)
    cats = (np.arange(n) % 2).astype(np.int32)
    if index_kind == "flat":
        idx = FlatIndex(dim, capacity, emb_dtype=emb_dtype)
    else:
        idx = HNSWIndex(dim, capacity,
                        params=HNSWParams(M=4, M0=8, beam=8, max_hops=4,
                                          n_entries=4, emb_dtype=emb_dtype),
                        seed=seed)
    idx.add_batch(vecs, cats)
    return idx


def lower_classified_search(index, *, B: int = 8, seed: int = 0,
                            name: str | None = None) -> HloTrace:
    """Lower the index's real classified-search hot path — the fused
    Pallas hop forced for HNSW (the jnp reference is the CPU *oracle*,
    not the production trace) — into an :class:`HloTrace`."""
    import jax.numpy as jnp

    from repro.core import hnsw as H
    rng = np.random.default_rng(seed)
    q = _unit_rows(rng, B, index.dim)
    taus = np.full(B, 0.9, np.float32)
    qcat = (np.arange(B) % 2).astype(np.int32)
    ttls = np.full(B, 60.0, np.float32)
    t = index.device_tables()
    _, Bp, qp, taup, qcp, tp = H._pad_query_batch(q, taus, qcat, ttls)
    meta = {"d": _padded_d(index.dim), "capacity": index.capacity,
            "emb_dtype": index.emb_dtype, "B": Bp}
    if isinstance(index, H.FlatIndex):
        lowered = H._flat_search_classified.lower(
            t["emb"], t["valid"], t["category"], t["inserted"],
            jnp.asarray(qp), jnp.asarray(taup), jnp.asarray(qcp),
            jnp.asarray(tp), jnp.float32(0.0), t.get("scale"))
        label = f"flat_search_classified[{index.emb_dtype}]"
    else:
        lowered = H.beam_search_classified.lower(
            t["emb"], t["neighbors"], t["valid"], t["entries"],
            t["inserted"], jnp.asarray(qp), jnp.asarray(taup),
            jnp.asarray(tp), jnp.float32(0.0), t["category"],
            jnp.asarray(qcp), t.get("scale"), beam=index.p.beam,
            max_hops=index.p.max_hops, hop_impl="fused_pallas")
        label = f"beam_search_classified[{index.emb_dtype}]"
    return HloTrace(name=name or label, hlo=lowered.compile().as_text(),
                    stablehlo=lowered.as_text(), meta=meta)


def lower_delta_flush(index, *, rows: int = 8,
                      name: str | None = None) -> list[HloTrace]:
    """Lower the delta-flush scatters for the index's embedding table:
    the Pallas row-scatter kernel (the lane-aligned production path)
    AND the XLA in-place scatter (the narrow-table / CPU path). Both
    donate the table operand (argument 0) — DonationHonored pins it."""
    import jax

    from repro.kernels import ops as K
    from repro.kernels import scatter_update as SU
    emb = index._emb_tables()["emb"]
    table = jax.ShapeDtypeStruct(emb.shape, emb.dtype)
    ridx = jax.ShapeDtypeStruct((rows,), np.int32)
    vals = jax.ShapeDtypeStruct((rows,) + emb.shape[1:], emb.dtype)
    base = name or f"delta_flush[{index.emb_dtype}]"
    meta = {"d": emb.shape[1], "capacity": index.capacity,
            "emb_dtype": index.emb_dtype, "donated_args": (0,)}
    out = []
    for label, lowered in (
            (f"{base}.pallas",
             SU.scatter_rows.lower(table, ridx, vals, interpret=True)),
            (f"{base}.xla",
             K._scatter_rows_xla.lower(table, ridx, vals))):
        out.append(HloTrace(name=label, hlo=lowered.compile().as_text(),
                            stablehlo=lowered.as_text(), meta=dict(meta)))
    return out


def collect_hot_path_traces(index_kind: str, emb_dtype: str, *,
                            dim: int = 384, capacity: int = 4096,
                            seed: int = 0) -> list[HloTrace]:
    """All HLO-level contract targets for one {index kind, dtype} cell:
    the classified search (the read hot loop) and the delta-flush
    scatters (the write hot loop)."""
    idx = build_index(index_kind, emb_dtype, dim=dim, capacity=capacity,
                      seed=seed)
    prefix = f"{index_kind}/{emb_dtype}"
    traces = [lower_classified_search(
        idx, seed=seed, name=f"{prefix}:search_classified")]
    traces += lower_delta_flush(idx, name=f"{prefix}:delta_flush")
    return traces


def collect_compile_census(cache, *, batches=(1, 2, 3, 5, 8),
                           name: str = "serve") -> CompileCensus:
    """Drive a (possibly sharded) cache through a serve-batch sweep and
    censor each shard-index's compilation counter. Deterministic: the
    counter counts distinct compiled signatures, never wall clock."""
    rng = np.random.default_rng(0)
    cats = sorted(cache.policies.categories())
    for B in batches:
        q = _unit_rows(rng, B, cache.dim)
        cache.lookup_batch(q, [cats[i % len(cats)] for i in range(B)])
    shards = getattr(cache, "shards", None) or [cache]
    families = {}
    for si, shard in enumerate(shards):
        key = (f"{shard.index.__class__.__name__}"
               f"[{shard.index.emb_dtype}] shard{si}")
        families[key] = shard.index.search_stats["compilations"]
    return CompileCensus(name=name, families=families, expected=1)
