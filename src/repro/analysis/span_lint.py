"""Span-coverage AST lint: every traced Clock charge opens a span.

The observability pipeline (repro.obs) carries one invariant the trace
tooling cannot check at runtime: span accounting only closes exactly
when every ``clock.advance`` charge in a traced serving stage happens
inside a leaf span. A charge added OUTSIDE any span doesn't crash
anything — it silently widens the root/leaf gap, and the accounting
gate only catches it on code paths the fault suites happen to drive.
This lint closes the bug class statically, the same way
``mirror_lint`` closes dirty-log omissions: parse the traced modules
and demand that every function charging the clock also opens a span on
the same path.

A *charge* is a call whose attribute chain ends ``.clock.advance(...)``
(``self.clock.advance``, ``self.parent.clock.advance``). A function is
*covered* when it also contains one of:

* a span call — ``<obj>.span(...)`` (the TraceRecorder entry point) or
  ``<obj>._span(...)`` (the NULL_SPAN-returning helper every traced
  component defines);
* a ``# span-ok`` pragma on the charge's line or the line directly
  above it, for charges that are deliberately un-spanned: a store whose
  latency is timed by the CALLER's open span (``LatencyModelStore``,
  ``RetryingStore`` backoff), inter-arrival idle time that is not a
  serving stage, or the untraced VDB baseline.

Granularity is per-function, matching mirror_lint: a function that
opens any span has demonstrated it knows the protocol; the bug shape
is the function that charges the clock and *never* does.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.contracts import Violation

SPAN_METHODS = frozenset({"span", "_span"})
PRAGMA = "# span-ok"


def _is_clock_advance(node: ast.AST) -> bool:
    """``<anything>.clock.advance(...)`` — the attribute chain's last
    two links are what make it a Clock charge."""
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    return (isinstance(fn, ast.Attribute) and fn.attr == "advance"
            and isinstance(fn.value, ast.Attribute)
            and fn.value.attr == "clock")


def _is_span_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    return isinstance(fn, ast.Attribute) and fn.attr in SPAN_METHODS


def _has_pragma(lines: list[str], lineno: int) -> bool:
    """``# span-ok`` on the charge's line or the line directly above
    (long charge expressions push the comment up a line)."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and PRAGMA in lines[ln - 1]:
            return True
    return False


def lint_source(src: str, filename: str = "<string>") -> list[Violation]:
    """Lint one module's source text. Returns a Violation per Clock
    charge in a function with no span call and no pragma."""
    tree = ast.parse(src, filename=filename)
    lines = src.splitlines()
    out: list[Violation] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        charges: list[int] = []
        covered = False
        for node in ast.walk(fn):
            if _is_clock_advance(node):
                if not _has_pragma(lines, node.lineno):
                    charges.append(node.lineno)
            elif _is_span_call(node):
                covered = True
        if charges and not covered:
            first = min(charges)
            out.append(Violation(
                "SpanCoverage", f"{filename}:{fn.name}",
                f"charges the clock (`.clock.advance`) without opening "
                f"a span (`.span`/`._span`) or a `{PRAGMA}` pragma — "
                f"the charge lands outside every leaf span and silently "
                f"breaks exact span accounting",
                f"first charge at line {first}: "
                f"{lines[first - 1].strip()[:120]}"))
    return out


def default_paths() -> list[Path]:
    src = Path(__file__).resolve().parent.parent
    return [src / "core" / "cache.py", src / "core" / "shard.py",
            src / "core" / "storage.py",
            src / "serving" / "simulator.py"]


def lint_paths(paths=None) -> list[Violation]:
    """Lint every traced module (default: the cache/shard/storage/
    simulator stack the TraceRecorder is threaded through)."""
    out: list[Violation] = []
    for p in (default_paths() if paths is None else paths):
        p = Path(p)
        out.extend(lint_source(p.read_text(), filename=p.name))
    return out
