"""Steady-state serving cost of the device-resident index (delta sync).

The paper's break-even argument (§4.4, §6) prices a local lookup at 2 ms;
the seed gave that back under any realistic lookup/insert interleave by
re-uploading the FULL index tables to device after every write
(O(capacity·d) per serve step). This bench measures the steady state the
delta protocol targets: batched lookups interleaved with batched miss
write-backs, swept across cache capacities.

    delta — dirty rows applied with the in-place scatter (the default):
            per-step sync cost must be O(batch), so step time stays ~flat
            as capacity grows
    full  — rebuild_threshold < 0 forces the seed's full re-upload per
            step: the O(capacity) contrast

Emits CSV rows and ``results/BENCH_serve.json`` with per-(capacity, mode)
hit rate, p50/p99 step latency and bytes synced per step, plus the
``delta_p50_flatness`` ratio (max/min p50 across the capacity sweep) that
CI's smoke job tracks.

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, index_meta, write_bench_json
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.embedding import SyntheticCategorySpace
from repro.core.policy import CategoryConfig, PolicyEngine
from repro.obs import LatencyHistogram

CAPACITIES = (4096, 8192, 16384, 32768)         # 8x sweep
QUICK_CAPACITIES = (4096, 16384)                # 4x sweep (CI smoke)


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("steady", threshold=0.88, ttl=1e9, quota=1.0),
    ])


def _run_one(capacity: int, mode: str, *, steps: int, batch: int,
             prefill: int, warmup: int, seed: int,
             tag: str = "step") -> dict:
    rng = np.random.default_rng(seed)
    sp = SyntheticCategorySpace(name="steady", n_centers=200_000,
                                sigma=0.015, loose_frac=0.0, seed=seed)
    cache = SemanticCache(_policies(), capacity=capacity, clock=SimClock(),
                          index_kind="hnsw", use_device=True, seed=seed)
    if mode == "full":
        cache.index.p.rebuild_threshold = -1.0   # seed behavior: always full

    # Prefill the working set (intents 0..prefill-1), then one lookup to
    # pay the initial upload + beam-search compile outside the timed loop.
    ids = np.arange(prefill)
    embs = np.stack([sp.sample(int(i), rng) for i in ids])
    cache.insert_batch(embs, ["steady"] * prefill,
                       [f"q{i}" for i in ids], [f"r{i}" for i in ids])
    cache.lookup_batch(embs[:batch], ["steady"] * batch)

    next_intent = prefill
    last_bytes = cache.index.sync_stats["bytes_synced"]
    # fixed-bucket log-scale histograms (repro.obs) — no sample storage;
    # quantiles are bucket midpoints, means exact from sum/count
    step_h, sync_h = LatencyHistogram(), LatencyHistogram()
    step_bytes, hits, lookups = [], 0, 0
    for s in range(warmup + steps):
        # half the batch revisits cached intents (hits), half is new
        # traffic (misses -> one batched write-back)
        hot = rng.integers(0, prefill, batch // 2)
        cold = np.arange(next_intent, next_intent + batch - batch // 2)
        next_intent += len(cold)
        q = np.stack([sp.sample(int(i), rng)
                      for i in np.concatenate([hot, cold])])
        cats = ["steady"] * batch

        t0 = time.perf_counter()
        results = cache.lookup_batch(q, cats)
        miss = [i for i, r in enumerate(results) if not r.hit]
        if miss:
            cache.insert_batch(q[miss], [cats[i] for i in miss],
                               [f"mq{s}_{i}" for i in miss],
                               [f"mr{s}_{i}" for i in miss])
        # Flush the step's writes here so the sync cost is attributed to
        # the step that produced it (and timed on its own: the sync is
        # what the capacity sweep is ABOUT — total step time on a 1-CPU
        # container is dominated by host graph wiring + its noise).
        t1 = time.perf_counter()
        cache.index.device_tables()
        t2 = time.perf_counter()

        if s >= warmup:
            step_h.observe((t2 - t0) * 1e3)
            sync_h.observe((t2 - t1) * 1e3)
            synced = cache.index.sync_stats["bytes_synced"]
            step_bytes.append(synced - last_bytes)
            hits += batch - len(miss)
            lookups += batch
        last_bytes = cache.index.sync_stats["bytes_synced"]

    out = {
        "capacity": capacity,
        "mode": mode,
        "hit_rate": round(hits / max(1, lookups), 4),
        "p50_step_ms": round(step_h.quantile(0.50), 3),
        "p99_step_ms": round(step_h.quantile(0.99), 3),
        "p50_sync_ms": round(sync_h.quantile(0.50), 3),
        "p99_sync_ms": round(sync_h.quantile(0.99), 3),
        "bytes_synced_per_step": int(np.mean(step_bytes)),
        "full_uploads": cache.index.sync_stats["full_uploads"]
        - (1 if mode == "delta" else 0),      # initial upload not steady
        "delta_updates": cache.index.sync_stats["delta_updates"],
        # emb_dtype + per-row byte costs: keeps bytes-synced comparable
        # across resident dtypes in the perf trajectory.
        **index_meta(cache.index),
    }
    emit(f"serve.{tag}.{mode}.cap{capacity}", step_h.mean_ms * 1e3,
         p50_ms=out["p50_step_ms"], p99_ms=out["p99_step_ms"],
         sync_ms=out["p50_sync_ms"], hit_rate=out["hit_rate"],
         sync_bytes=out["bytes_synced_per_step"])
    return out


def run(capacities=CAPACITIES, steps: int = 30, batch: int = 16,
        prefill: int = 1500, warmup: int = 5, seed: int = 0,
        modes=("delta", "full"), repeats: int = 1,
        out_dir: str = "results") -> dict:
    # Throwaway process warm-up (BLAS threads, page cache, jit caches):
    # without it the sweep's first configuration measures the process, not
    # the capacity.
    _run_one(min(capacities), modes[0], steps=3, batch=batch,
             prefill=min(200, prefill), warmup=2, seed=seed, tag="warmup")
    # Best-of-N sweeps: shared-machine load drifts on a timescale longer
    # than one run, so per-config medians of a single sweep measure the
    # neighbor's workload; the min over repeated sweeps is robust.
    best: dict = {}
    for rep in range(repeats):
        for m in modes:
            for c in capacities:
                r = _run_one(c, m, steps=steps, batch=batch,
                             prefill=prefill, warmup=warmup, seed=seed,
                             tag=f"step{rep}" if repeats > 1 else "step")
                key = (m, c)
                if key not in best or r["p50_step_ms"] < \
                        best[key]["p50_step_ms"]:
                    best[key] = r
    runs = [best[(m, c)] for m in modes for c in capacities]
    payload = {
        "batch": batch, "steps": steps, "prefill": prefill,
        "repeats": repeats, "capacities": list(capacities), "runs": runs,
    }
    for mode in modes:
        p50 = [r["p50_step_ms"] for r in runs if r["mode"] == mode]
        sy = [r["p50_sync_ms"] for r in runs if r["mode"] == mode]
        by = [r["bytes_synced_per_step"] for r in runs if r["mode"] == mode]
        payload[f"{mode}_p50_flatness"] = round(max(p50) / max(min(p50),
                                                              1e-9), 3)
        payload[f"{mode}_sync_flatness"] = round(max(sy) / max(min(sy),
                                                              1e-9), 3)
        payload[f"{mode}_bytes_ratio"] = round(max(by) / max(min(by), 1), 3)
    if "delta" in modes:
        emit("serve.delta_flatness", 0.0,
             step_ratio=payload["delta_p50_flatness"],
             sync_ratio=payload["delta_sync_flatness"],
             bytes_ratio=payload["delta_bytes_ratio"],
             sweep=f"{min(capacities)}-{max(capacities)}")
    write_bench_json("serve", payload, out_dir=out_dir,
                     config={"batch": batch, "steps": steps,
                             "prefill": prefill, "repeats": repeats,
                             "capacities": list(capacities),
                             "modes": list(modes), "seed": seed})
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 capacities (4x), fewer steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--prefill", type=int, default=None)
    ap.add_argument("--modes", default="delta,full")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless delta-mode bytes synced "
                         "per step are flat across the capacity sweep "
                         "(the O(delta) acceptance gate; byte counts are "
                         "deterministic, so the bound is tight)")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    if args.quick:
        caps, steps, prefill, warmup, reps = QUICK_CAPACITIES, 12, 600, 3, 1
    else:
        caps, steps, prefill, warmup, reps = CAPACITIES, 30, 1500, 5, 2
    payload = run(capacities=caps,
                  steps=steps if args.steps is None else args.steps,
                  batch=args.batch,
                  prefill=prefill if args.prefill is None else args.prefill,
                  warmup=warmup, repeats=reps,
                  modes=tuple(args.modes.split(",")), out_dir=args.out)
    if args.check:
        ratio = payload.get("delta_bytes_ratio")
        if ratio is None or ratio > 1.5:
            raise SystemExit(
                f"O(delta) sync regression: delta-mode bytes synced per "
                f"step vary {ratio}x across the capacity sweep "
                f"(expected ~1.0 — per-step sync must not scale with "
                f"cache capacity)")
        print(f"# check ok: delta bytes ratio {ratio} across "
              f"{min(caps)}-{max(caps)}")


if __name__ == "__main__":
    main()
