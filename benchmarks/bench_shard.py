"""Sharded cache tier: placement balance + steady-state fan-out cost.

Two phases, gated ONLY on deterministic counters (wall-clock on this
container drifts ~30 %, so latency is reported but never gated):

    placement — fill a sharded cache from the Table-1 workload mix
                (traffic-proportional inserts, quota-capped) under the
                quota-byte ``ShardPlanner`` and under the crc32-mod
                baseline; the planner's resident-byte imbalance
                (max/mean shard bytes) must be STRICTLY better — crc32
                piles the head categories onto one shard (83 % of quota
                bytes on one of two shards).
    steady    — lookup/insert interleave through the fan-out path across
                a total-capacity sweep: per-shard bytes synced per step
                must stay flat (each shard's delta sync is O(its share
                of the batch), independent of how large the tier grows),
                and per-shard compilations must equal 1 (the bucketed
                sub-batches every fan-out produces reuse one compiled
                program per shard).

Emits CSV rows and ``results/BENCH_shard.json`` (CI smoke runs
``--quick --check``).

    PYTHONPATH=src python -m benchmarks.bench_shard [--quick] [--check]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, index_meta, write_bench_json
from repro.core.clock import SimClock
from repro.core.embedding import SyntheticCategorySpace
from repro.core.policy import CategoryConfig, PolicyEngine, paper_policies
from repro.core.shard import CRC32Planner, ShardPlanner, ShardedSemanticCache
from repro.core.workload import TABLE1_WORKLOAD

DIM = 96
CAPACITIES = (2048, 8192, 32768)        # 16x sweep
QUICK_CAPACITIES = (2048, 8192)         # 4x sweep (CI smoke)


# ---------------------------------------------------------------------------
# Phase 1: placement balance on the Table-1 workload.
# ---------------------------------------------------------------------------

def _fill_table1(cache, n_inserts: int, seed: int) -> None:
    """Traffic-proportional inserts (quotas cap the heads, as they would
    in steady state): each category receives share × n_inserts distinct
    intents in interleaved chunks."""
    rng = np.random.default_rng(seed)
    spaces = {s.name: SyntheticCategorySpace(
        name=s.name, n_centers=max(s.pool_size, n_inserts), sigma=0.01,
        loose_frac=0.0, dim=DIM, seed=s.seed) for s in TABLE1_WORKLOAD}
    todo = {s.name: int(s.traffic_share * n_inserts)
            for s in TABLE1_WORKLOAD}
    next_intent = {s.name: 0 for s in TABLE1_WORKLOAD}
    chunk = 256
    while any(v > 0 for v in todo.values()):
        for name in todo:
            n = min(chunk, todo[name])
            if n == 0:
                continue
            todo[name] -= n
            lo = next_intent[name]
            next_intent[name] += n
            embs = np.stack([spaces[name].sample(lo + i, rng)
                             for i in range(n)])
            cache.insert_batch(embs, [name] * n,
                               [f"{name}:q{lo + i}" for i in range(n)],
                               [f"{name}:r{lo + i}" for i in range(n)])


def _imbalance(per_shard_bytes: list[int]) -> float:
    mean = sum(per_shard_bytes) / len(per_shard_bytes)
    return max(per_shard_bytes) / mean if mean > 0 else 1.0


def run_placement(n_shards: int = 2, capacity: int = 4096,
                  seed: int = 0) -> dict:
    """Resident-byte spread: quota-byte planner vs the crc32 baseline,
    measured from actually-resident entries (not just the plan)."""
    results = {}
    for kind in ("planner", "crc32"):
        policies = PolicyEngine(paper_policies())
        planner = (None if kind == "planner"
                   else CRC32Planner(n_shards))
        cache = ShardedSemanticCache(policies, dim=DIM, capacity=capacity,
                                     n_shards=n_shards, clock=SimClock(),
                                     index_kind="flat", planner=planner,
                                     seed=seed)
        _fill_table1(cache, n_inserts=capacity, seed=seed)
        rep = cache.shard_report()
        rbytes = [r["resident_bytes"] for r in rep]
        results[kind] = {
            "per_shard_resident_bytes": rbytes,
            "per_shard_entries": [r["entries"] for r in rep],
            "imbalance": round(_imbalance(rbytes), 4),
            "assignments": (dict(cache.planner.assignments)
                            if kind == "planner" else
                            {s.name: cache.planner.shard_of(s.name)
                             for s in TABLE1_WORKLOAD}),
        }
        emit(f"shard.placement.{kind}.n{n_shards}", 0.0,
             imbalance=results[kind]["imbalance"],
             entries=sum(results[kind]["per_shard_entries"]))
    results["planned_imbalance"] = round(ShardPlanner.from_policies(
        PolicyEngine(paper_policies()), n_shards, capacity,
        dim=DIM).imbalance(), 4)
    return results


# ---------------------------------------------------------------------------
# Phase 2: steady-state fan-out across a capacity sweep.
# ---------------------------------------------------------------------------

def _steady_policies(names) -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig(n, threshold=0.88, ttl=1e9,
                       quota=0.9 / len(names)) for n in names])


def run_steady_one(capacity: int, n_shards: int, *, steps: int,
                   warmup: int, prefill: int, seed: int) -> dict:
    """One capacity point: fixed-composition fan-out batches (each shard
    sees a constant sub-batch size → exactly one compiled program per
    shard), half revisits / half fresh traffic per category."""
    names = [f"s{i}" for i in range(2 * n_shards)]   # two categories/shard
    rng = np.random.default_rng(seed)
    spaces = {n: SyntheticCategorySpace(name=n, n_centers=500_000,
                                        sigma=0.015, loose_frac=0.0,
                                        dim=DIM, seed=seed + k)
              for k, n in enumerate(names)}
    cache = ShardedSemanticCache(_steady_policies(names), dim=DIM,
                                 capacity=capacity, n_shards=n_shards,
                                 clock=SimClock(), index_kind="hnsw",
                                 use_device=True, seed=seed)
    per_cat = prefill // len(names)
    for n in names:
        embs = np.stack([spaces[n].sample(i, rng) for i in range(per_cat)])
        cache.insert_batch(embs, [n] * per_cat,
                           [f"{n}:q{i}" for i in range(per_cat)],
                           [f"{n}:r{i}" for i in range(per_cat)])

    def make_batch(step: int):
        """4 queries per category: 2 revisits + 2 fresh — composition
        constant, so every shard's padded sub-batch shape repeats."""
        embs, cats = [], []
        for n in names:
            hot = rng.integers(0, per_cat, 2)
            cold = [per_cat + 2 * step, per_cat + 2 * step + 1]
            for i in np.concatenate([hot, cold]):
                embs.append(spaces[n].sample(int(i), rng))
                cats.append(n)
        return np.stack(embs), cats

    # Priming round: initial full upload + the one compile, outside the
    # measured steady state.
    q, cats = make_batch(0)
    cache.lookup_batch(q, cats)

    last = [s.index.sync_stats["bytes_synced"] for s in cache.shards]
    shard_bytes = [[] for _ in range(n_shards)]
    step_ms, hits, lookups = [], 0, 0
    for s in range(warmup + steps):
        q, cats = make_batch(s + 1)
        t0 = time.perf_counter()
        results = cache.lookup_batch(q, cats)
        miss = [i for i, r in enumerate(results) if not r.hit]
        if miss:
            cache.insert_batch(q[miss], [cats[i] for i in miss],
                               [f"mq{s}_{i}" for i in miss],
                               [f"mr{s}_{i}" for i in miss])
        for sh in cache.shards:     # attribute the step's writes to it
            sh.index.device_tables()
        t1 = time.perf_counter()
        if s >= warmup:
            step_ms.append((t1 - t0) * 1e3)
            for k, sh in enumerate(cache.shards):
                now = sh.index.sync_stats["bytes_synced"]
                shard_bytes[k].append(now - last[k])
            hits += len(results) - len(miss)
            lookups += len(results)
        last = [sh.index.sync_stats["bytes_synced"] for sh in cache.shards]

    out = {
        "capacity": capacity,
        "n_shards": n_shards,
        "hit_rate": round(hits / max(1, lookups), 4),
        "p50_step_ms": round(float(np.percentile(step_ms, 50)), 3),
        "per_shard_bytes_per_step": [int(np.mean(b)) for b in shard_bytes],
        "per_shard_compilations": [s.index.search_stats["compilations"]
                                   for s in cache.shards],
        "per_shard_full_uploads": [s.index.sync_stats["full_uploads"]
                                   for s in cache.shards],
        **index_meta(cache.shards[0].index, n_shards=n_shards),
    }
    emit(f"shard.steady.n{n_shards}.cap{capacity}",
         float(np.mean(step_ms)) * 1e3,
         p50_ms=out["p50_step_ms"], hit_rate=out["hit_rate"],
         sync_bytes=sum(out["per_shard_bytes_per_step"]),
         compilations=max(out["per_shard_compilations"]))
    return out


def run(capacities=CAPACITIES, n_shards: int = 2, steps: int = 12,
        warmup: int = 3, prefill: int = 600, seed: int = 0,
        out_dir: str = "results") -> dict:
    placement = run_placement(n_shards=n_shards, seed=seed)
    runs = [run_steady_one(c, n_shards, steps=steps, warmup=warmup,
                           prefill=prefill, seed=seed) for c in capacities]
    # Per-shard flatness across the sweep: shard k's delta bytes/step at
    # the largest capacity vs the smallest (deterministic counters).
    flatness = []
    for k in range(n_shards):
        per_cap = [r["per_shard_bytes_per_step"][k] for r in runs]
        flatness.append(round(max(per_cap) / max(min(per_cap), 1), 3))
    payload = {
        "n_shards": n_shards, "capacities": list(capacities),
        "steps": steps, "prefill": prefill,
        "placement": placement,
        "steady": runs,
        "per_shard_bytes_flatness": flatness,
        "max_compilations": max(max(r["per_shard_compilations"])
                                for r in runs),
    }
    emit("shard.gates", 0.0,
         planner_imbalance=placement["planner"]["imbalance"],
         crc32_imbalance=placement["crc32"]["imbalance"],
         bytes_flatness=max(flatness),
         compilations=payload["max_compilations"])
    write_bench_json("shard", payload, out_dir=out_dir)
    return payload


def check(payload: dict) -> None:
    """The deterministic acceptance gates (CI smoke)."""
    pl = payload["placement"]
    if not pl["planner"]["imbalance"] < pl["crc32"]["imbalance"]:
        raise SystemExit(
            f"placement regression: planner imbalance "
            f"{pl['planner']['imbalance']} not better than crc32 "
            f"{pl['crc32']['imbalance']} on the Table-1 workload")
    if max(payload["per_shard_bytes_flatness"]) > 1.5:
        raise SystemExit(
            f"fan-out sync regression: per-shard bytes/step vary "
            f"{payload['per_shard_bytes_flatness']}x across the "
            f"capacity sweep (expected ~1.0 — a shard's delta sync "
            f"must not scale with total tier capacity)")
    if payload["max_compilations"] != 1:
        raise SystemExit(
            f"bucketing regression: a shard compiled "
            f"{payload['max_compilations']} programs for the "
            f"fixed-composition fan-out (expected exactly 1)")
    print(f"# check ok: planner {pl['planner']['imbalance']} < crc32 "
          f"{pl['crc32']['imbalance']}, bytes flatness "
          f"{payload['per_shard_bytes_flatness']}, 1 compile/shard")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 capacities, fewer steps")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the placement/flatness/"
                         "compilation gates hold")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    if args.quick:
        caps, steps, warmup, prefill, shards = \
            QUICK_CAPACITIES, 8, 2, 400, 2
    else:
        caps, steps, warmup, prefill, shards = CAPACITIES, 12, 3, 600, 4
    payload = run(capacities=caps, n_shards=args.shards or shards,
                  steps=steps, warmup=warmup, prefill=prefill,
                  out_dir=args.out)
    if args.check:
        check(payload)


if __name__ == "__main__":
    main()
