"""Fault-injected degraded-mode serving: availability under shard
outages, store retry/backoff, and the no-fault parity baseline.

Gated ONLY on deterministic counters (the FaultSchedule is data, the
clock is simulated — no fire-time randomness, no wall time):

    baseline     — the SAME run three ways: no injector at all
                   (fault_schedule=None), an EMPTY FaultSchedule (the
                   whole fault stack wired in but inert), and the empty
                   schedule again. All hit/miss/sync counters must be
                   EXACTLY identical: the fault layer is provably free
                   when nothing is scheduled, and runs are reproducible.
    shard_outage — two scheduled outage windows on a 2-shard tier.
                   Down-shard lookups are counted ``degraded_misses``
                   (an availability loss, never a hit-rate denominator
                   leak); down-shard writes park in the bounded
                   write-behind queue and MUST fully replay after
                   recovery (wb_pending == 0, zero acknowledged-write
                   loss). Accounting: hits + misses + degraded ==
                   lookups per category and overall.
    store_flaky  — scheduled transient runs on the doc store's get
                   path: a short run the RetryingStore's bounded
                   Clock-charged backoff absorbs (retries > 0), and a
                   long run that exhausts the retry budget and degrades
                   the would-be hit to a ``store_timeout`` miss
                   (timeouts > 0, entry stays resident, accounting
                   still closes).

Full mode re-runs the outage scenario on the hnsw index (same gates)
to cover the delta-synced device path under degradation.

Emits CSV rows and ``results/BENCH_faults.json`` (CI smoke runs
``--quick --check``).

    PYTHONPATH=src python -m benchmarks.bench_faults [--quick] [--check]
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, write_bench_json
from repro.core.faults import FaultSchedule
from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import scenario_generator
from repro.serving.simulator import ServingSimulator, SimConfig

CAPACITY = 4000
SCENARIO = "flash_crowd"        # two categories -> both shards exercised
# Outage windows in simulated seconds (flash_crowd streams ~30 qps, so
# n=2000 spans ~67 s): each shard goes down once, recovers with plenty
# of post-window traffic to drain the write-behind queues.
OUTAGES = [(5.0, 20.0, 0), (30.0, 40.0, 1)]
# Store-transient runs over the *get* op index (hit-path doc fetches):
# ops 10-11 are a short run absorbed by retries=3; ops 40-49 are a long
# run that exhausts the ladder at least once before healing.
FLAKY_GETS = (FaultSchedule.op_range(10, 2) | FaultSchedule.op_range(40, 10))


def run_scenario(*, schedule: FaultSchedule | None, n: int,
                 n_shards: int = 2, index_kind: str = "flat",
                 seed: int = 0) -> dict:
    """One deterministic simulator run; returns the gate counters."""
    pol = PolicyEngine(paper_policies())
    sim = ServingSimulator(pol, SimConfig(
        architecture="hybrid", cache_capacity=CAPACITY,
        index_kind=index_kind, n_shards=n_shards, seed=seed,
        fault_schedule=schedule))
    res = sim.run(scenario_generator(SCENARIO, seed=seed), n)
    per = res.metrics.per_category
    out = {
        "n_queries": n, "n_shards": n_shards, "index_kind": index_kind,
        "lookups": sum(s.lookups for s in per.values()),
        "hits": sum(s.hits for s in per.values()),
        "misses": sum(s.misses for s in per.values()),
        "degraded_misses": sum(s.degraded_misses for s in per.values()),
        "store_timeouts": sum(s.store_timeouts for s in per.values()),
        "hit_rate": round(res.overall_hit_rate, 4),
        "sync": dict(res.index_sync or {}),
        "per_category": {
            name: {"lookups": s.lookups, "hits": s.hits,
                   "misses": s.misses, "degraded": s.degraded_misses}
            for name, s in per.items()},
    }
    if res.fault_stats is not None:
        out["fault"] = res.fault_stats
    return out


def run(n: int = 5000, seed: int = 0, sweep: bool = True,
        out_dir: str = "results") -> dict:
    # Baseline parity: no injector vs empty schedule vs empty again.
    base = run_scenario(schedule=None, n=n, seed=seed)
    inert = run_scenario(schedule=FaultSchedule(), n=n, seed=seed)
    inert2 = run_scenario(schedule=FaultSchedule(), n=n, seed=seed)
    emit("faults.baseline.no_injector", 0.0, hit_rate=base["hit_rate"],
         hits=base["hits"], misses=base["misses"])
    emit("faults.baseline.empty_schedule", 0.0, hit_rate=inert["hit_rate"],
         hits=inert["hits"], misses=inert["misses"])

    outage = run_scenario(
        schedule=FaultSchedule(shard_outages=list(OUTAGES)), n=n, seed=seed)
    emit("faults.shard_outage", 0.0, hit_rate=outage["hit_rate"],
         degraded=outage["degraded_misses"],
         availability=outage["fault"]["availability"],
         wb_replayed=outage["fault"]["front_door"]["wb_replayed"],
         wb_pending=outage["fault"]["wb_pending"])

    flaky = run_scenario(
        schedule=FaultSchedule(store_get_failures=FLAKY_GETS), n=n,
        seed=seed)
    emit("faults.store_flaky", 0.0, hit_rate=flaky["hit_rate"],
         timeouts=flaky["store_timeouts"],
         get_retries=flaky["fault"]["store"]["get_retries"],
         backoff_ms=round(flaky["fault"]["store"]["backoff_ms_charged"], 3))

    payload = {
        "n_queries": n, "seed": seed, "scenario": SCENARIO,
        "capacity": CAPACITY, "outage_windows": [list(w) for w in OUTAGES],
        "baseline": {"no_injector": base, "empty_schedule": inert,
                     "empty_schedule_rerun": inert2},
        "shard_outage": outage,
        "store_flaky": flaky,
    }
    if sweep:
        # Same outage gates on the delta-synced hnsw device path.
        hnsw = run_scenario(
            schedule=FaultSchedule(shard_outages=list(OUTAGES)), n=n,
            index_kind="hnsw", seed=seed)
        payload["shard_outage_hnsw"] = hnsw
        emit("faults.shard_outage.hnsw", 0.0, hit_rate=hnsw["hit_rate"],
             degraded=hnsw["degraded_misses"],
             wb_pending=hnsw["fault"]["wb_pending"])
    write_bench_json("faults", payload, out_dir=out_dir)
    return payload


def _check_accounting(name: str, r: dict) -> None:
    if r["hits"] + r["misses"] + r["degraded_misses"] != r["lookups"]:
        raise SystemExit(
            f"accounting leak ({name}): hits {r['hits']} + misses "
            f"{r['misses']} + degraded {r['degraded_misses']} != "
            f"lookups {r['lookups']}")
    if r["lookups"] != r["n_queries"]:
        raise SystemExit(
            f"accounting leak ({name}): {r['lookups']} lookups != "
            f"{r['n_queries']} queries issued")
    for cat, c in r["per_category"].items():
        if c["hits"] + c["misses"] + c["degraded"] != c["lookups"]:
            raise SystemExit(
                f"accounting leak ({name}/{cat}): "
                f"{c['hits']}+{c['misses']}+{c['degraded']} != "
                f"{c['lookups']}")


def check(payload: dict) -> None:
    """The deterministic acceptance gates (CI smoke)."""
    base = payload["baseline"]["no_injector"]
    inert = payload["baseline"]["empty_schedule"]
    inert2 = payload["baseline"]["empty_schedule_rerun"]
    for k in ("lookups", "hits", "misses", "hit_rate", "sync",
              "per_category"):
        if base[k] != inert[k]:
            raise SystemExit(
                f"fault layer not free: empty-schedule {k} {inert[k]!r} "
                f"!= no-injector baseline {base[k]!r}")
        if inert[k] != inert2[k]:
            raise SystemExit(
                f"non-deterministic run: {k} differs across identical "
                f"empty-schedule runs")

    outages = [("shard_outage", payload["shard_outage"])]
    if "shard_outage_hnsw" in payload:
        outages.append(("shard_outage_hnsw", payload["shard_outage_hnsw"]))
    for name, r in outages:
        _check_accounting(name, r)
        if r["degraded_misses"] <= 0:
            raise SystemExit(
                f"{name}: outage windows never degraded a lookup "
                f"(degraded_misses == 0) — injector not consulted")
        fd = r["fault"]["front_door"]
        if fd["wb_enqueued"] <= 0 or fd["wb_replayed"] != fd["wb_enqueued"]:
            raise SystemExit(
                f"{name}: write-behind replay incomplete — enqueued "
                f"{fd['wb_enqueued']}, replayed {fd['wb_replayed']} "
                f"(acknowledged-write loss)")
        if r["fault"]["wb_pending"] != 0:
            raise SystemExit(
                f"{name}: write-behind queue never drained "
                f"(wb_pending == {r['fault']['wb_pending']})")
        if not 0.0 < r["fault"]["availability"] < 1.0:
            raise SystemExit(
                f"{name}: availability {r['fault']['availability']} "
                f"not in (0, 1) despite scheduled outage windows")

    flaky = payload["store_flaky"]
    _check_accounting("store_flaky", flaky)
    st = flaky["fault"]["store"]
    if flaky["store_timeouts"] <= 0 or st.get("get_timeouts", 0) <= 0:
        raise SystemExit(
            "store_flaky: the long transient run never exhausted the "
            "retry budget (store_timeouts == 0)")
    if st["get_retries"] <= 0 or st["backoff_ms_charged"] <= 0.0:
        raise SystemExit(
            "store_flaky: bounded retries never fired / no backoff "
            "charged — the short transient run was not absorbed")
    print(f"# check ok: baseline bit-identical, outage degraded "
          f"{payload['shard_outage']['degraded_misses']} lookups at "
          f"availability {payload['shard_outage']['fault']['availability']}"
          f" with full write-behind replay, store path absorbed "
          f"{st['get_retries']} retries and degraded "
          f"{flaky['store_timeouts']} timeouts")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer queries, flat index only")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the parity / accounting / "
                         "replay / retry gates hold")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    n = 2000 if args.quick else 5000
    payload = run(n=n, sweep=not args.quick, out_dir=args.out)
    if args.check:
        check(payload)


if __name__ == "__main__":
    main()
