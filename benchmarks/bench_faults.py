"""Fault-injected degraded-mode serving: availability under shard
outages, store retry/backoff, and the no-fault parity baseline.

Gated ONLY on deterministic counters (the FaultSchedule is data, the
clock is simulated — no fire-time randomness, no wall time):

    baseline     — the SAME run three ways: no injector at all
                   (fault_schedule=None), an EMPTY FaultSchedule (the
                   whole fault stack wired in but inert), and the empty
                   schedule again. All hit/miss/sync counters must be
                   EXACTLY identical: the fault layer is provably free
                   when nothing is scheduled, and runs are reproducible.
    shard_outage — two scheduled outage windows on a 2-shard tier.
                   Down-shard lookups are counted ``degraded_misses``
                   (an availability loss, never a hit-rate denominator
                   leak); down-shard writes park in the bounded
                   write-behind queue and MUST fully replay after
                   recovery (wb_pending == 0, zero acknowledged-write
                   loss). Accounting: hits + misses + degraded ==
                   lookups per category and overall.
    store_flaky  — scheduled transient runs on the doc store's get
                   path: a short run the RetryingStore's bounded
                   Clock-charged backoff absorbs (retries > 0), and a
                   long run that exhausts the retry budget and degrades
                   the would-be hit to a ``store_timeout`` miss
                   (timeouts > 0, entry stays resident, accounting
                   still closes).

    replication  — availability-vs-outage-duration curves on the head
                   category's PRIMARY shard, three mitigation modes per
                   duration: ``replicated`` (conversational_chat on 2
                   shards — availability MUST be 1.0 with zero degraded
                   misses, failover_reads > 0 and replica_divergence
                   == 0), ``rebalance`` (no replicas, but a sustained
                   outage past ``rebalance_after_s`` evacuates the
                   category via the journaled OutageRebalance — its
                   degraded window must be bounded by the threshold, not
                   the outage), and ``unmitigated`` (PR-8 behavior: the
                   degraded window IS the outage window). A no-replica
                   parity pair (replication=None vs an empty {} map)
                   must be counter-identical: the replication layer is
                   provably free when nothing is replicated.

Full mode re-runs the outage scenario on the hnsw index (same gates)
to cover the delta-synced device path under degradation, and the
replicated scenario on hnsw as well.

Emits CSV rows and ``results/BENCH_faults.json`` (CI smoke runs
``--quick --check``).

    PYTHONPATH=src python -m benchmarks.bench_faults [--quick] [--check]
"""

from __future__ import annotations

import argparse
import os

from benchmarks.common import emit, write_bench_json
from repro.core.faults import FaultSchedule
from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import scenario_generator
from repro.obs import (check_span_accounting, coverage_fraction,
                       span_accounting)
from repro.serving.simulator import ServingSimulator, SimConfig

CAPACITY = 4000
SCENARIO = "flash_crowd"        # two categories -> both shards exercised
# Outage windows in simulated seconds (flash_crowd streams ~30 qps, so
# n=2000 spans ~67 s): each shard goes down once, recovers with plenty
# of post-window traffic to drain the write-behind queues.
OUTAGES = [(5.0, 20.0, 0), (30.0, 40.0, 1)]
# Store-transient runs over the *get* op index (hit-path doc fetches):
# ops 10-11 are a short run absorbed by retries=3; ops 40-49 are a long
# run that exhausts the ladder at least once before healing.
FLAKY_GETS = (FaultSchedule.op_range(10, 2) | FaultSchedule.op_range(40, 10))
# Replication scenario family: conversational_chat (the flash_crowd head
# category) lives on shard 1; outages of swept duration hit that primary
# so the three mitigation modes separate cleanly. The bounded-window
# gate allows one op of accrual granularity past the threshold.
REPLICATION = {"conversational_chat": 2}
REBALANCE_AFTER_S = 5.0
OUTAGE_T0 = 5.0
REPL_DURATIONS = [5.0, 10.0, 20.0]      # full sweep; quick keeps [10.0]
WINDOW_SLACK_S = 1.5


def run_scenario(*, schedule: FaultSchedule | None, n: int,
                 n_shards: int = 2, index_kind: str = "flat",
                 seed: int = 0,
                 replication: dict | float | None = None,
                 rebalance_after_s: float | None = None,
                 trace: bool = False,
                 trace_jsonl: str | None = None) -> dict:
    """One deterministic simulator run; returns the gate counters.
    ``trace=True`` wires the repro.obs TraceRecorder through the whole
    stack and attaches span-accounting / event-attribution gate data
    under ``"trace"`` (and optionally dumps the raw trace as JSONL)."""
    pol = PolicyEngine(paper_policies())
    sim = ServingSimulator(pol, SimConfig(
        architecture="hybrid", cache_capacity=CAPACITY,
        index_kind=index_kind, n_shards=n_shards, seed=seed,
        fault_schedule=schedule, replication=replication,
        rebalance_after_s=rebalance_after_s, trace=trace))
    res = sim.run(scenario_generator(SCENARIO, seed=seed), n)
    per = res.metrics.per_category
    # the aggregate row is computed once by the registry (summed
    # counters, recomputed rates) instead of hand-summing here
    ov = res.metrics.snapshot()["_overall"]
    out = {
        "n_queries": n, "n_shards": n_shards, "index_kind": index_kind,
        "lookups": ov["lookups"],
        "hits": ov["hits"],
        "misses": ov["misses"],
        "degraded_misses": ov["degraded_misses"],
        "store_timeouts": ov["store_timeouts"],
        "hit_rate": round(res.overall_hit_rate, 4),
        "sync": dict(res.index_sync or {}),
        "per_category": {
            name: {"lookups": s.lookups, "hits": s.hits,
                   "misses": s.misses, "degraded": s.degraded_misses}
            for name, s in per.items()},
    }
    if res.fault_stats is not None:
        out["fault"] = res.fault_stats
    if trace:
        rec = res.trace
        acct = span_accounting(rec)
        # degraded-window attribution: every degraded second the metrics
        # accrued must be explained by a degraded_accrue event
        accrued: dict[str, float] = {}
        for ev in rec.events:
            if ev.name == "degraded_accrue":
                c = ev.fields.get("category", "")
                accrued[c] = accrued.get(c, 0.0) \
                    + float(ev.fields.get("seconds", 0.0))
        attribution = {
            name: round(accrued.get(name, 0.0) / s.degraded_seconds, 6)
            for name, s in per.items() if s.degraded_seconds > 0}
        out["trace"] = {
            "opened": acct["opened"], "closed": acct["closed"],
            "roots": acct["roots"],
            "max_gap_ms": acct["max_gap_ms"],
            "violations": check_span_accounting(rec),
            "leaf_coverage": round(coverage_fraction(rec), 6),
            "events": rec.event_counts(),
            "degraded_attribution": attribution,
        }
        if trace_jsonl:
            os.makedirs(os.path.dirname(trace_jsonl) or ".", exist_ok=True)
            out["trace"]["jsonl_lines"] = rec.to_jsonl(trace_jsonl)
            out["trace"]["jsonl_path"] = trace_jsonl
    return out


def run(n: int = 5000, seed: int = 0, sweep: bool = True,
        out_dir: str = "results") -> dict:
    # Baseline parity: no injector vs empty schedule vs empty again.
    base = run_scenario(schedule=None, n=n, seed=seed)
    inert = run_scenario(schedule=FaultSchedule(), n=n, seed=seed)
    inert2 = run_scenario(schedule=FaultSchedule(), n=n, seed=seed)
    emit("faults.baseline.no_injector", 0.0, hit_rate=base["hit_rate"],
         hits=base["hits"], misses=base["misses"])
    emit("faults.baseline.empty_schedule", 0.0, hit_rate=inert["hit_rate"],
         hits=inert["hits"], misses=inert["misses"])

    outage = run_scenario(
        schedule=FaultSchedule(shard_outages=list(OUTAGES)), n=n, seed=seed)
    emit("faults.shard_outage", 0.0, hit_rate=outage["hit_rate"],
         degraded=outage["degraded_misses"],
         availability=outage["fault"]["availability"],
         wb_replayed=outage["fault"]["front_door"]["wb_replayed"],
         wb_pending=outage["fault"]["wb_pending"])

    flaky = run_scenario(
        schedule=FaultSchedule(store_get_failures=FLAKY_GETS), n=n,
        seed=seed)
    emit("faults.store_flaky", 0.0, hit_rate=flaky["hit_rate"],
         timeouts=flaky["store_timeouts"],
         get_retries=flaky["fault"]["store"]["get_retries"],
         backoff_ms=round(flaky["fault"]["store"]["backoff_ms_charged"], 3))

    # Tracing gates: the SAME runs with the TraceRecorder wired in must
    # be counter-identical (observation changes nothing), close span
    # accounting exactly (every opened span closes, leaf sums equal root
    # durations under the sim clock), and attribute every degraded
    # second to named degraded_accrue events. The outage run's raw
    # trace is dumped as the CI JSONL artifact.
    traced = run_scenario(
        schedule=FaultSchedule(shard_outages=list(OUTAGES)), n=n,
        seed=seed, trace=True,
        trace_jsonl=os.path.join(out_dir, "TRACE_faults.jsonl"))
    emit("faults.traced_outage", 0.0, spans=traced["trace"]["opened"],
         roots=traced["trace"]["roots"],
         violations=len(traced["trace"]["violations"]),
         coverage=traced["trace"]["leaf_coverage"])
    traced_reb = run_scenario(
        schedule=FaultSchedule(
            shard_outages=[(OUTAGE_T0, OUTAGE_T0 + 10.0, 1)]),
        n=n, seed=seed, rebalance_after_s=REBALANCE_AFTER_S, trace=True)
    traced_flaky = run_scenario(
        schedule=FaultSchedule(store_get_failures=FLAKY_GETS), n=n,
        seed=seed, trace=True)

    payload = {
        "n_queries": n, "seed": seed, "scenario": SCENARIO,
        "capacity": CAPACITY, "outage_windows": [list(w) for w in OUTAGES],
        "baseline": {"no_injector": base, "empty_schedule": inert,
                     "empty_schedule_rerun": inert2},
        "shard_outage": outage,
        "store_flaky": flaky,
        "traced_outage": traced,
        "traced_rebalance": traced_reb,
        "traced_flaky": traced_flaky,
        "replication": run_replication(
            n=n, seed=seed,
            durations=REPL_DURATIONS if sweep else [10.0]),
    }
    if sweep:
        # Same outage gates on the delta-synced hnsw device path.
        hnsw = run_scenario(
            schedule=FaultSchedule(shard_outages=list(OUTAGES)), n=n,
            index_kind="hnsw", seed=seed)
        payload["shard_outage_hnsw"] = hnsw
        emit("faults.shard_outage.hnsw", 0.0, hit_rate=hnsw["hit_rate"],
             degraded=hnsw["degraded_misses"],
             wb_pending=hnsw["fault"]["wb_pending"])
        # Replicated failover on the device-synced index too.
        repl_hnsw = run_scenario(
            schedule=FaultSchedule(
                shard_outages=[(OUTAGE_T0, OUTAGE_T0 + 10.0, 1)]),
            n=n, index_kind="hnsw", seed=seed,
            replication=dict(REPLICATION))
        payload["replication"]["hnsw"] = repl_hnsw
        emit("faults.replication.hnsw", 0.0,
             chat_availability=repl_hnsw["fault"]["slo"]
             ["conversational_chat"]["availability"],
             failover=repl_hnsw["fault"]["front_door"]["failover_reads"],
             divergence=repl_hnsw["fault"]["front_door"]
             ["replica_divergence"])
    write_bench_json("faults", payload, out_dir=out_dir,
                     config={"n_queries": n, "seed": seed,
                             "scenario": SCENARIO, "capacity": CAPACITY,
                             "sweep": sweep,
                             "outage_windows": [list(w) for w in OUTAGES]})
    return payload


def run_replication(*, n: int, seed: int, durations: list) -> dict:
    """Availability-vs-outage-duration curves, three mitigation modes
    per duration, plus the no-replica parity pair."""
    curve = []
    for d in durations:
        win = [(OUTAGE_T0, OUTAGE_T0 + d, 1)]
        repl = run_scenario(
            schedule=FaultSchedule(shard_outages=list(win)), n=n,
            seed=seed, replication=dict(REPLICATION))
        bounded = run_scenario(
            schedule=FaultSchedule(shard_outages=list(win)), n=n,
            seed=seed, rebalance_after_s=REBALANCE_AFTER_S)
        plain = run_scenario(
            schedule=FaultSchedule(shard_outages=list(win)), n=n,
            seed=seed)
        row = {"outage_s": d, "replicated": repl, "rebalance": bounded,
               "unmitigated": plain}
        curve.append(row)
        for mode, r in (("replicated", repl), ("rebalance", bounded),
                        ("unmitigated", plain)):
            chat = r["fault"]["slo"]["conversational_chat"]
            emit(f"faults.replication.{mode}", float(d),
                 chat_availability=chat["availability"],
                 chat_degraded_s=chat["degraded_seconds"],
                 failover=r["fault"]["front_door"]["failover_reads"],
                 rebalances=r["fault"]["front_door"]["outage_rebalances"])
    # Parity pair: an empty replication MAP must be counter-identical to
    # replication=None — the replication layer is free when unused.
    win = [(OUTAGE_T0, OUTAGE_T0 + durations[0], 1)]
    parity_none = run_scenario(
        schedule=FaultSchedule(shard_outages=list(win)), n=n, seed=seed)
    parity_empty = run_scenario(
        schedule=FaultSchedule(shard_outages=list(win)), n=n, seed=seed,
        replication={})
    return {"rebalance_after_s": REBALANCE_AFTER_S,
            "replication": dict(REPLICATION),
            "curve": curve,
            "no_replica_parity": {"none": parity_none,
                                  "empty_map": parity_empty}}


def _check_accounting(name: str, r: dict) -> None:
    if r["hits"] + r["misses"] + r["degraded_misses"] != r["lookups"]:
        raise SystemExit(
            f"accounting leak ({name}): hits {r['hits']} + misses "
            f"{r['misses']} + degraded {r['degraded_misses']} != "
            f"lookups {r['lookups']}")
    if r["lookups"] != r["n_queries"]:
        raise SystemExit(
            f"accounting leak ({name}): {r['lookups']} lookups != "
            f"{r['n_queries']} queries issued")
    for cat, c in r["per_category"].items():
        if c["hits"] + c["misses"] + c["degraded"] != c["lookups"]:
            raise SystemExit(
                f"accounting leak ({name}/{cat}): "
                f"{c['hits']}+{c['misses']}+{c['degraded']} != "
                f"{c['lookups']}")


def check(payload: dict) -> None:
    """The deterministic acceptance gates (CI smoke)."""
    base = payload["baseline"]["no_injector"]
    inert = payload["baseline"]["empty_schedule"]
    inert2 = payload["baseline"]["empty_schedule_rerun"]
    for k in ("lookups", "hits", "misses", "hit_rate", "sync",
              "per_category"):
        if base[k] != inert[k]:
            raise SystemExit(
                f"fault layer not free: empty-schedule {k} {inert[k]!r} "
                f"!= no-injector baseline {base[k]!r}")
        if inert[k] != inert2[k]:
            raise SystemExit(
                f"non-deterministic run: {k} differs across identical "
                f"empty-schedule runs")

    outages = [("shard_outage", payload["shard_outage"])]
    if "shard_outage_hnsw" in payload:
        outages.append(("shard_outage_hnsw", payload["shard_outage_hnsw"]))
    for name, r in outages:
        _check_accounting(name, r)
        if r["degraded_misses"] <= 0:
            raise SystemExit(
                f"{name}: outage windows never degraded a lookup "
                f"(degraded_misses == 0) — injector not consulted")
        fd = r["fault"]["front_door"]
        if fd["wb_enqueued"] <= 0 or fd["wb_replayed"] != fd["wb_enqueued"]:
            raise SystemExit(
                f"{name}: write-behind replay incomplete — enqueued "
                f"{fd['wb_enqueued']}, replayed {fd['wb_replayed']} "
                f"(acknowledged-write loss)")
        if r["fault"]["wb_pending"] != 0:
            raise SystemExit(
                f"{name}: write-behind queue never drained "
                f"(wb_pending == {r['fault']['wb_pending']})")
        if not 0.0 < r["fault"]["availability"] < 1.0:
            raise SystemExit(
                f"{name}: availability {r['fault']['availability']} "
                f"not in (0, 1) despite scheduled outage windows")

    check_replication(payload["replication"])
    check_tracing(payload)

    flaky = payload["store_flaky"]
    _check_accounting("store_flaky", flaky)
    st = flaky["fault"]["store"]
    if flaky["store_timeouts"] <= 0 or st.get("get_timeouts", 0) <= 0:
        raise SystemExit(
            "store_flaky: the long transient run never exhausted the "
            "retry budget (store_timeouts == 0)")
    if st["get_retries"] <= 0 or st["backoff_ms_charged"] <= 0.0:
        raise SystemExit(
            "store_flaky: bounded retries never fired / no backoff "
            "charged — the short transient run was not absorbed")
    curve = payload["replication"]["curve"]
    print(f"# check ok: baseline bit-identical, outage degraded "
          f"{payload['shard_outage']['degraded_misses']} lookups at "
          f"availability {payload['shard_outage']['fault']['availability']}"
          f" with full write-behind replay, store path absorbed "
          f"{st['get_retries']} retries and degraded "
          f"{flaky['store_timeouts']} timeouts; replication held "
          f"availability 1.0 across {len(curve)} outage durations "
          f"(failover, zero divergence) and self-healing bounded the "
          f"unreplicated window; tracing was counter-free with "
          f"{payload['traced_outage']['trace']['opened']} spans closed "
          f"exactly and degraded windows fully attributed")


def check_tracing(payload: dict) -> None:
    """Deterministic tracing gates: tracing is observation only, span
    accounting closes exactly, degraded windows are fully attributed."""
    # 1) tracing-on counters bit-identical to the untraced outage run
    tr, base = payload["traced_outage"], payload["shard_outage"]
    for k in ("lookups", "hits", "misses", "degraded_misses",
              "store_timeouts", "hit_rate", "sync", "per_category",
              "fault"):
        if tr[k] != base[k]:
            raise SystemExit(
                f"tracing not free: traced outage {k} {tr[k]!r} != "
                f"untraced {base[k]!r}")
    for name in ("traced_outage", "traced_rebalance", "traced_flaky"):
        t = payload[name]["trace"]
        # 2) span accounting closes exactly (SimClock)
        if t["violations"]:
            raise SystemExit(
                f"{name}: span accounting violated — "
                f"{t['violations'][:3]}")
        if t["opened"] != t["closed"]:
            raise SystemExit(
                f"{name}: span leak — {t['opened']} opened, "
                f"{t['closed']} closed")
        # 3) every degraded second explained by degraded_accrue events
        for cat, frac in t["degraded_attribution"].items():
            if frac < 0.95:
                raise SystemExit(
                    f"{name}: only {frac:.1%} of {cat}'s degraded "
                    f"window attributed to degraded_accrue events "
                    f"(need >= 95%)")
        # 4) one degraded_miss event per degraded_miss counter tick
        deg_ev = t["events"].get("degraded_miss", 0)
        if deg_ev != payload[name]["degraded_misses"]:
            raise SystemExit(
                f"{name}: {deg_ev} degraded_miss events != "
                f"{payload[name]['degraded_misses']} counted")
    if payload["traced_rebalance"]["trace"]["events"] \
            .get("rebalance_step", 0) <= 0:
        raise SystemExit(
            "traced_rebalance: OutageRebalance ran but emitted no "
            "rebalance_step events")
    if payload["traced_flaky"]["trace"]["events"].get("store_retry", 0) <= 0:
        raise SystemExit(
            "traced_flaky: transient store runs absorbed but no "
            "store_retry events on the stream")
    if payload["traced_outage"]["trace"].get("jsonl_lines", 0) <= 0:
        raise SystemExit("traced_outage: empty JSONL trace artifact")


def check_replication(rep: dict) -> None:
    """Deterministic replication / self-healing gates."""
    for row in rep["curve"]:
        d = row["outage_s"]
        runs = [(f"replicated@{d}", row["replicated"]),
                (f"rebalance@{d}", row["rebalance"]),
                (f"unmitigated@{d}", row["unmitigated"])]
        if "hnsw" in rep and d == 10.0:
            runs.append(("replicated.hnsw", rep["hnsw"]))
        for name, r in runs:
            _check_accounting(name, r)
            if r["fault"]["wb_pending"] != 0:
                raise SystemExit(f"{name}: write-behind never drained")
        for name, r in runs:
            if not name.startswith("replicated"):
                continue
            chat = r["fault"]["slo"]["conversational_chat"]
            fd = r["fault"]["front_door"]
            if chat["availability"] != 1.0 or chat["degraded_misses"] != 0:
                raise SystemExit(
                    f"{name}: replicated category degraded under a "
                    f"single-shard outage (availability "
                    f"{chat['availability']}, degraded "
                    f"{chat['degraded_misses']}) — failover broken")
            if fd["failover_reads"] <= 0:
                raise SystemExit(
                    f"{name}: availability held but failover_reads == 0 "
                    f"— the outage never exercised the replica path")
            if fd["replica_divergence"] != 0:
                raise SystemExit(
                    f"{name}: replicas diverged "
                    f"({fd['replica_divergence']} observed drift events)")
        chat_b = row["rebalance"]["fault"]["slo"]["conversational_chat"]
        chat_u = row["unmitigated"]["fault"]["slo"]["conversational_chat"]
        if d > rep["rebalance_after_s"] + WINDOW_SLACK_S:
            bound = rep["rebalance_after_s"] + WINDOW_SLACK_S
            if chat_b["degraded_seconds"] > bound:
                raise SystemExit(
                    f"rebalance@{d}: degraded window "
                    f"{chat_b['degraded_seconds']}s exceeds "
                    f"rebalance_after_s bound {bound}s — self-healing "
                    f"never cut the outage short")
            fd_b = row["rebalance"]["fault"]["front_door"]
            if fd_b["outage_rebalances"] <= 0:
                raise SystemExit(
                    f"rebalance@{d}: window bounded but no "
                    f"OutageRebalance ran — bound is accidental")
            if fd_b["reabsorbed_categories"] <= 0:
                raise SystemExit(
                    f"rebalance@{d}: evacuated categories never "
                    f"re-absorbed after recovery")
            if chat_u["degraded_seconds"] <= chat_b["degraded_seconds"]:
                raise SystemExit(
                    f"unmitigated@{d}: degraded window "
                    f"{chat_u['degraded_seconds']}s not longer than the "
                    f"rebalanced run's {chat_b['degraded_seconds']}s")
        if chat_u["degraded_misses"] <= 0:
            raise SystemExit(
                f"unmitigated@{d}: outage on the head category's "
                f"primary never degraded a lookup")
    par = rep["no_replica_parity"]
    for k in ("lookups", "hits", "misses", "degraded_misses", "hit_rate",
              "sync", "per_category"):
        if par["none"][k] != par["empty_map"][k]:
            raise SystemExit(
                f"replication layer not free: empty-map {k} "
                f"{par['empty_map'][k]!r} != replication=None "
                f"{par['none'][k]!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer queries, flat index only")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the parity / accounting / "
                         "replay / retry gates hold")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    n = 2000 if args.quick else 5000
    payload = run(n=n, sweep=not args.quick, out_dir=args.out)
    if args.check:
        check(payload)


if __name__ == "__main__":
    main()
