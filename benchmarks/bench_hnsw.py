"""§7.4 HNSW scaling: search latency vs index size (log n expected).

Paper quotes 2–3 ms at 1 M entries, 5–8 ms at 10 M (production CPUs).
This container is 1 CPU core, so we sweep to 10^5 and report the curve +
a fitted per-doubling increment; the jitted flat scan is included as the
O(n) contrast (its TPU roofline version appears in EXPERIMENTS §Perf).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_callable
from repro.core.hnsw import FlatIndex, HNSWIndex, INVALID


def run(sizes=(2000, 8000, 32000, 100000), seed: int = 0):
    rng = np.random.default_rng(seed)
    lat = {}
    for n in sizes:
        vecs = rng.standard_normal((n, 384)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        idx = HNSWIndex.bulk_build(vecs, seed=seed)
        q = vecs[rng.integers(0, n, 8)]
        taus = np.full(8, 0.9, np.float32)
        us = time_callable(lambda: idx.search_host(q[:1], taus[:1]), iters=15)
        lat[n] = us
        emit(f"hnsw.search.n{n}", us, entries=n)
        flat = FlatIndex(384, n + 8)
        flat.emb[:n] = vecs
        flat.valid[:n] = True
        flat._n = n
        us_flat = time_callable(lambda: flat.search_host(q, taus),
                                iters=10) / 8
        emit(f"hnsw.flat_contrast.n{n}", us_flat, entries=n)
    # growth per doubling (log-n signature: roughly constant increment)
    ns = sorted(lat)
    incs = [(lat[b] - lat[a]) / max(1e-9, np.log2(b / a))
            for a, b in zip(ns, ns[1:])]
    emit("hnsw.us_per_doubling", float(np.mean(incs)),
         increments=";".join(f"{x:.1f}" for x in incs))
    run_mixed_category()


def run_mixed_category(n: int = 2000, n_clusters: int = 100, seed: int = 3):
    """§5.3 false-miss scenario at the index level: two categories
    interleave inside the same clusters, queries sit ON a category-0 point
    but ask for category 1. Category-blind top-1 returns the cross-category
    point (→ post-hoc reject = false miss); masked search must find the
    same-cluster category-1 point. Reported for host and device paths,
    plus the latency cost of masking."""
    from repro.core.embedding import SyntheticCategorySpace
    rng = np.random.default_rng(seed)
    # same generator as bench_longtail's scenario, so hit rates compare:
    # σ=0.015 → intra-cluster cos ≈ 0.92 (paraphrase-tight), τ=0.85 passes
    sp = SyntheticCategorySpace(name="mixed", n_centers=n_clusters,
                                sigma=0.015, loose_frac=0.0, seed=seed)
    vecs = sp.sample_batch(rng.integers(0, n_clusters, n), rng)

    idx = HNSWIndex(384, n + 64, seed=seed)
    for j, v in enumerate(vecs):
        idx.add(v, category=j % 2)

    B = 64
    picks = rng.choice(np.arange(0, n, 2), B, replace=False)   # category 0
    q = vecs[picks]
    qc = np.ones(B, np.int32)                                  # want cat 1
    taus = np.full(B, 0.85, np.float32)

    # seed behavior: global top-1, reject cross-category
    gi, _ = idx.search_host(q, taus)
    seed_hits = int(np.sum((gi != INVALID) &
                           (idx.category[np.maximum(gi, 0)] == 1)))
    hi, _ = idx.search_host(q, taus, categories=qc)
    di, _ = idx.search_batch(q, taus, categories=qc)
    emit("hnsw.mixed.seed_global_nn", 0.0, hit_rate=seed_hits / B)
    emit("hnsw.mixed.masked_host", 0.0,
         hit_rate=float(np.mean(hi != INVALID)))
    emit("hnsw.mixed.masked_device", 0.0,
         hit_rate=float(np.mean(di != INVALID)))

    us_blind = time_callable(lambda: idx.search_host(q, taus), iters=5) / B
    us_mask = time_callable(
        lambda: idx.search_host(q, taus, categories=qc), iters=5) / B
    emit("hnsw.mixed.mask_overhead", us_mask,
         blind_us=us_blind, overhead_pct=(us_mask / max(us_blind, 1e-9) - 1)
         * 100)


if __name__ == "__main__":
    run()
