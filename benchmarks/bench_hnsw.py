"""§7.4 HNSW scaling: search latency vs index size (log n expected).

Paper quotes 2–3 ms at 1 M entries, 5–8 ms at 10 M (production CPUs).
This container is 1 CPU core, so we sweep to 10^5 and report the curve +
a fitted per-doubling increment; the jitted flat scan is included as the
O(n) contrast (its TPU roofline version appears in EXPERIMENTS §Perf).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_callable
from repro.core.hnsw import FlatIndex, HNSWIndex


def run(sizes=(2000, 8000, 32000, 100000), seed: int = 0):
    rng = np.random.default_rng(seed)
    lat = {}
    for n in sizes:
        vecs = rng.standard_normal((n, 384)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        idx = HNSWIndex.bulk_build(vecs, seed=seed)
        q = vecs[rng.integers(0, n, 8)]
        taus = np.full(8, 0.9, np.float32)
        us = time_callable(lambda: idx.search_host(q[:1], taus[:1]), iters=15)
        lat[n] = us
        emit(f"hnsw.search.n{n}", us, entries=n)
        flat = FlatIndex(384, n + 8)
        flat.emb[:n] = vecs
        flat.valid[:n] = True
        flat._n = n
        us_flat = time_callable(lambda: flat.search_host(q, taus),
                                iters=10) / 8
        emit(f"hnsw.flat_contrast.n{n}", us_flat, entries=n)
    # growth per doubling (log-n signature: roughly constant increment)
    ns = sorted(lat)
    incs = [(lat[b] - lat[a]) / max(1e-9, np.log2(b / a))
            for a, b in zip(ns, ns[1:])]
    emit("hnsw.us_per_doubling", float(np.mean(incs)),
         increments=";".join(f"{x:.1f}" for x in incs))


if __name__ == "__main__":
    run()
