"""§3.1 threshold sweep: false-positive / false-negative rates by density.

Paper: at τ=0.80 the dense code space matches semantically different
queries (≈15 % false matches); τ=0.90 reduces that to ≈3 %. Sparse spaces
invert: τ=0.80 misses valid paraphrases that τ=0.75 captures.

Method: cache 400 intents per space, then query (a) new paraphrases of
cached intents (should hit — misses are false negatives) and (b) queries
from *uncached* intents (should miss — hits are false positives).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.embedding import make_dense_space, make_sparse_space
from repro.core.policy import CategoryConfig, PolicyEngine


def measure(space, tau: float, n_cached: int = 400, n_probe: int = 500,
            cached_frac: float = 0.6, seed: int = 0):
    """Mixed query stream: ``cached_frac`` of probes target cached intents.

    FP = wrong-intent hits / probes (the paper's "false matches");
    FN = misses on paraphrases of cached intents / cached-intent probes.
    """
    rng = np.random.default_rng(seed)
    eng = PolicyEngine([CategoryConfig("c", threshold=tau, ttl=1e9,
                                       quota=1.0)])
    cache = SemanticCache(eng, capacity=2 * n_cached, clock=SimClock(),
                          index_kind="flat")
    slot_intent = {}
    for i in range(n_cached):
        slot = cache.insert(space.sample(i, rng), "c", f"q{i}", f"r{i}")
        slot_intent[slot] = i
    fp = fn = n_cached_probes = 0
    for _ in range(n_probe):
        if rng.random() < cached_frac:
            intent = int(rng.integers(0, n_cached))
            n_cached_probes += 1
        else:
            intent = int(rng.integers(space.n_centers // 2, space.n_centers))
        res = cache.lookup(space.sample(intent, rng), "c")
        if res.hit and slot_intent.get(res.slot) != intent:
            fp += 1
        if not res.hit and intent < n_cached:
            fn += 1
    return fp / n_probe, fn / max(1, n_cached_probes)


def run():
    dense = make_dense_space(seed=21)
    sparse = make_sparse_space(seed=22)
    for name, space, taus in (
            ("dense_code", dense, (0.80, 0.85, 0.90, 0.95)),
            ("sparse_chat", sparse, (0.70, 0.75, 0.80, 0.85))):
        for tau in taus:
            fp, fn = measure(space, tau)
            emit(f"thresholds.{name}.tau{tau:.2f}", 0.0,
                 false_positive_rate=fp, false_negative_rate=fn)
    emit("thresholds.paper_anchor", 0.0,
         note="dense tau0.80 should FP>10pct; tau0.90 FP<5pct; "
              "sparse tau0.80 FN high; tau0.75 FN low")


if __name__ == "__main__":
    run()
