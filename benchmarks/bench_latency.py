"""§5.2 expected-latency comparison at the paper's operating point.

Paper example: at 20 % hit rate, hybrid averages 0.2·7 + 0.8·2 = 3.0 ms of
cache overhead vs vector-DB 0.2·35 + 0.8·30 = 31 ms. We reproduce both
analytically and from the discrete-event simulator (cache overhead only,
then end-to-end including model calls).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.serving.simulator import ServingSimulator, SimConfig


def run(n_queries: int = 4000, seed: int = 7):
    # analytic §5.2 example
    h = 0.2
    hybrid_ms = h * (2 + 5) + (1 - h) * 2
    vdb_ms = h * (30 + 5) + (1 - h) * 30
    emit("latency.analytic.hybrid", hybrid_ms * 1e3, hit_rate=h,
         paper_value_ms=3.0)
    emit("latency.analytic.vdb", vdb_ms * 1e3, hit_rate=h,
         paper_value_ms=31.0)

    results = {}
    for arch in ("hybrid", "vdb", "none"):
        eng = PolicyEngine(paper_policies())
        gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=seed)
        sim = ServingSimulator(eng, SimConfig(architecture=arch,
                                              cache_capacity=12000,
                                              index_kind="flat"))
        res = sim.run(gen, n_queries)
        results[arch] = res
        # cache overhead per query = end-to-end − model time share
        emit(f"latency.e2e.{arch}", res.mean_latency_ms * 1e3,
             p95_ms=res.p95_latency_ms, hit_rate=res.overall_hit_rate,
             model_cost=res.model_cost,
             false_positives=res.false_positives)
    speedup = (results["none"].mean_latency_ms
               / results["hybrid"].mean_latency_ms)
    emit("latency.hybrid_speedup_vs_none", 0.0, speedup=speedup)


if __name__ == "__main__":
    run()
