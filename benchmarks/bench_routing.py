"""§7.5.5 multi-model routing: per-model adaptation steers cache value.

Paper example: Model A (o1, $0.10, 500 ms) under 3× spike vs Model B
(gpt-4o-mini, $0.01, 150 ms) idle → cache hits on A save 10× latency and
10× cost; per-model policies relax A only.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.policy import CategoryConfig, PolicyEngine
from repro.serving.router import ModelBackend, ModelRouter


def run():
    policies = PolicyEngine([
        CategoryConfig("complex_code", threshold=0.90, ttl=7 * 86400,
                       quota=0.4, delta_max=0.05, tau_min=0.80,
                       model_name="o1", expected_tllm_ms=500.0),
        CategoryConfig("simple_chat", threshold=0.75, ttl=6 * 3600,
                       quota=0.2, delta_max=0.10, tau_min=0.68,
                       model_name="gpt4o_mini", expected_tllm_ms=150.0),
    ])
    router = ModelRouter(policies, [
        ModelBackend("o1", t_base_ms=500.0, cost_per_call=0.10,
                     latency_target_ms=600, queue_target=32),
        ModelBackend("gpt4o_mini", t_base_ms=150.0, cost_per_call=0.01,
                     latency_target_ms=300, queue_target=32),
    ])
    tau_a0 = router.effective_policy("complex_code").threshold
    tau_b0 = router.effective_policy("simple_chat").threshold

    # 3× spike on o1; gpt4o_mini idle
    for _ in range(64):
        router.observe("o1", latency_ms=1500.0, queue_depth=96)
        router.observe("gpt4o_mini", latency_ms=140.0, queue_depth=1)

    tau_a1 = router.effective_policy("complex_code").threshold
    tau_b1 = router.effective_policy("simple_chat").threshold
    ttl_a1 = router.effective_policy("complex_code").ttl
    emit("routing.per_model_adaptation", 0.0,
         lambda_o1=router.load_factor("o1"),
         lambda_mini=router.load_factor("gpt4o_mini"),
         tau_o1_before=tau_a0, tau_o1_after=tau_a1,
         tau_mini_before=tau_b0, tau_mini_after=tau_b1,
         ttl_o1_days_after=ttl_a1 / 86400)
    # per-hit value ratio during the spike (paper: 10× latency, 10× cost)
    save_a = 1500.0 - 7.0
    save_b = 150.0 - 7.0
    emit("routing.per_hit_value", 0.0,
         latency_ratio=save_a / save_b, cost_ratio=0.10 / 0.01)
    # category→shard routing (§7.4 sharding by category)
    router2 = ModelRouter(policies, [ModelBackend("m", 100.0, 0.01)],
                          n_cache_shards=4)
    shards = {c: router2.shard_for(c)
              for c in ("complex_code", "simple_chat")}
    emit("routing.category_shards", 0.0, **shards)


if __name__ == "__main__":
    run()
