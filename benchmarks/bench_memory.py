"""§5.1/§7.4 memory accounting: in-memory bytes/entry vs externalized docs.

Paper: ~2 KB/entry in-memory (1.5 KB embedding + graph + 112 B metadata)
vs tens of KB with full documents inline; overhead ≈ 5 % of baseline.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.embedding import make_dense_space
from repro.core.policy import CategoryConfig, PolicyEngine


def run(n: int = 2000, doc_bytes: int = 8000, seed: int = 0):
    rng = np.random.default_rng(seed)
    space = make_dense_space(seed=31)
    eng = PolicyEngine([CategoryConfig("c", threshold=0.9, ttl=1e9,
                                       quota=1.0)])
    cache = SemanticCache(eng, capacity=n + 8, clock=SimClock(),
                          index_kind="hnsw")
    body = "x" * doc_bytes
    for i in range(n):
        cache.insert(space.sample(i, rng), "c", f"query {i}", body)
    rep = cache.memory_report()
    emit("memory.per_entry", 0.0, **rep)
    inline = rep["in_memory_bytes_per_entry"] + rep["external_doc_bytes_per_entry"]
    emit("memory.reduction_vs_inline_docs", 0.0,
         hybrid_bytes=rep["in_memory_bytes_per_entry"],
         inline_bytes=inline,
         reduction=1 - rep["in_memory_bytes_per_entry"] / inline,
         overhead_fraction=rep["metadata_overhead_bytes"]
         / rep["in_memory_bytes_per_entry"])
    # capacity projection for one v5e host (paper §7.4 scaling discussion)
    for ram_gb in (8, 64):
        emit(f"memory.capacity_at_{ram_gb}GB", 0.0,
             hybrid_entries=int(ram_gb * 1e9
                                / rep["in_memory_bytes_per_entry"]),
             inline_entries=int(ram_gb * 1e9 / inline))


if __name__ == "__main__":
    run()
