"""§5.1/§7.4 memory accounting: in-memory bytes/entry vs externalized.

Paper: ~2 KB/entry in-memory (1.5 KB embedding + graph + 112 B metadata)
vs tens of KB with full documents inline; overhead ≈ 5 % of baseline.

Reported PER CATEGORY and under BOTH resident dtypes (fp32 and int8
quantized residency): each category row shows its resident bytes and the
headroom left under its quota ceiling, so the §5.4 quota math is visible
in byte terms — the int8 tier holds ~4x the entries per quota byte.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.economics import residency_capacity_table
from repro.core.embedding import make_dense_space
from repro.core.policy import CategoryConfig, PolicyEngine


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("code", threshold=0.90, ttl=1e9, quota=0.5,
                       priority=4.0),
        CategoryConfig("chat", threshold=0.80, ttl=1e9, quota=0.3),
        CategoryConfig("legal", threshold=0.85, ttl=1e9, quota=0.2,
                       priority=2.0),
    ])


def run(n: int = 2000, doc_bytes: int = 8000, seed: int = 0):
    rng = np.random.default_rng(seed)
    space = make_dense_space(seed=31)
    cats = ["code", "chat", "legal"]
    body = "x" * doc_bytes
    for emb_dtype in ("float32", "int8"):
        cache = SemanticCache(_policies(), capacity=n + 8, clock=SimClock(),
                              index_kind="hnsw", emb_dtype=emb_dtype)
        for i in range(n):
            cache.insert(space.sample(i, rng), cats[i % 3], f"query {i}",
                         body)
        rep = cache.memory_report()
        emit(f"memory.{emb_dtype}.per_entry", 0.0, **rep)
        inline = (rep["in_memory_bytes_per_entry"]
                  + rep["external_doc_bytes_per_entry"])
        emit(f"memory.{emb_dtype}.reduction_vs_inline_docs", 0.0,
             hybrid_bytes=rep["in_memory_bytes_per_entry"],
             inline_bytes=inline,
             reduction=1 - rep["in_memory_bytes_per_entry"] / inline,
             overhead_fraction=rep["metadata_overhead_bytes"]
             / rep["in_memory_bytes_per_entry"])
        # Per-category residency + quota headroom (the §5.4 quota split
        # in byte terms, per resident dtype).
        for cat, row in cache.category_memory_report().items():
            emit(f"memory.{emb_dtype}.cat.{cat}", 0.0, **row)
        # capacity projection for one v5e host (paper §7.4 scaling):
        # resident_entries budgets the device/search tier (what the
        # quantized shrink multiplies); host_entries budgets host numpy,
        # which under int8 residency ALSO carries the fp32 control plane.
        for ram_gb in (8, 64):
            emit(f"memory.{emb_dtype}.capacity_at_{ram_gb}GB", 0.0,
                 resident_entries=int(ram_gb * 1e9
                                      / rep["in_memory_bytes_per_entry"]),
                 host_entries=int(ram_gb * 1e9
                                  / rep["host_bytes_per_entry"]),
                 inline_entries=int(ram_gb * 1e9 / inline))
    # Model-side quota table (core/economics.ResidencyModel): what each
    # category quota holds out of a fixed budget under either dtype.
    tab = residency_capacity_table(
        budget_mb=1024.0,
        quotas={c: _policies().get(c).quota for c in cats})
    for dt, row in tab["dtypes"].items():
        emit(f"memory.quota_table.{dt}", 0.0,
             bytes_per_entry=row["bytes_per_entry"],
             entries_per_mb=row["entries_per_mb"],
             **{f"quota_{c}": v for c, v in row["quota_entries"].items()})


if __name__ == "__main__":
    run()
