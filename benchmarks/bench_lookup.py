"""Lookup-side data plane: the per-request hot loop, counter-gated.

The paper's economics hinge on the in-memory search staying ~2 ms
(break-even at 3-5 % hit rate vs 15-20 % for a 30 ms remote search); this
bench tracks the lookup path the way the serve bench tracks the write
path. Wall-clock p50/p99 are *reported* (vs capacity and batch size), but
every acceptance gate rides DETERMINISTIC counters — this container has
~30 % wall-clock noise:

    compilations  — bucketed batch shapes: one compiled program must
                    serve every engine drain size B = 1..max_batch
    hops          — beam hops actually run (early exit working)
    rows_gathered — embedding rows fetched per query; the done-query
                    freeze means a query that hits its τ early STOPS
                    issuing gather DMAs, so easy (cache-hit) traffic must
                    gather strictly fewer rows than miss traffic

Emits CSV rows and ``results/BENCH_lookup.json``; ``--check`` is the CI
smoke gate.

    PYTHONPATH=src python -m benchmarks.bench_lookup [--quick] [--check]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, index_meta, write_bench_json
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.embedding import SyntheticCategorySpace
from repro.core.policy import CategoryConfig, PolicyEngine
from repro.obs import LatencyHistogram

CAPACITIES = (4096, 8192, 16384)
QUICK_CAPACITIES = (2048, 8192)
MAX_BATCH = 8                   # the engine's default queue-drain ceiling


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("lookup", threshold=0.88, ttl=1e9, quota=1.0),
    ])


def _build_cache(capacity: int, prefill: int, seed: int
                 ) -> tuple[SemanticCache, SyntheticCategorySpace]:
    rng = np.random.default_rng(seed)
    sp = SyntheticCategorySpace(name="lookup", n_centers=200_000,
                                sigma=0.015, loose_frac=0.0, seed=seed)
    cache = SemanticCache(_policies(), capacity=capacity, clock=SimClock(),
                          index_kind="hnsw", use_device=True, seed=seed)
    embs = np.stack([sp.sample(i, rng) for i in range(prefill)])
    cache.insert_batch(embs, ["lookup"] * prefill,
                       [f"q{i}" for i in range(prefill)],
                       [f"r{i}" for i in range(prefill)])
    return cache, sp


def _run_capacity(capacity: int, *, prefill: int, lookups_per_batch: int,
                  repeats: int, seed: int) -> dict:
    cache, sp = _build_cache(capacity, prefill, seed)
    rng = np.random.default_rng(seed + 1)
    runs = []
    # Batch-size sweep 1..MAX_BATCH: ONE compilation must serve them all
    # (bucketing pads to the 8-lane sublane minimum). Wall clock is
    # best-of-``repeats`` per the container-noise note; counters are
    # deterministic and taken from the first pass.
    for batch in sorted({1, 2, 3, MAX_BATCH // 2, MAX_BATCH}):
        q = np.stack([sp.sample(int(i), rng)
                      for i in rng.integers(0, prefill, batch)])
        cache.lookup_batch(q, ["lookup"] * batch)          # warm the shape
        stats0 = dict(cache.last_lookup_stats)
        best = None
        for _ in range(repeats):
            # fixed-bucket log-scale histogram (repro.obs): quantiles
            # are bucket midpoints, no per-sample storage
            h = LatencyHistogram()
            for _i in range(lookups_per_batch):
                t0 = time.perf_counter()
                res = cache.lookup_batch(q, ["lookup"] * batch)
                h.observe((time.perf_counter() - t0) * 1e3)
            cur = {"p50_ms": round(h.quantile(0.50), 3),
                   "p99_ms": round(h.quantile(0.99), 3)}
            if best is None or cur["p50_ms"] < best["p50_ms"]:
                best = cur
        hit_rate = float(np.mean([r.hit for r in res]))
        row = {
            "capacity": capacity, "batch": batch,
            "hit_rate": round(hit_rate, 3),
            "hops": stats0["hops"],
            "rows_per_query": round(stats0["rows_gathered"] / batch, 1),
            "compilations": cache.index.search_stats["compilations"],
            **best,
        }
        runs.append(row)
        emit(f"lookup.cap{capacity}.b{batch}", row["p50_ms"] * 1e3,
             p99_ms=row["p99_ms"], hops=row["hops"],
             rows_per_q=row["rows_per_query"],
             compilations=row["compilations"], hit_rate=row["hit_rate"])
    compilations = cache.index.search_stats["compilations"]

    # Done-query freeze: exact cached vectors reach τ immediately and must
    # stop issuing gather DMAs, so their rows-gathered-per-query sits far
    # below miss traffic that walks the beam to convergence. Both counts
    # are deterministic (same graph, same queries).
    B = MAX_BATCH
    easy = np.stack([sp.sample(int(i), rng)
                     for i in rng.integers(0, prefill, B)])
    hard = rng.standard_normal((B, easy.shape[1])).astype(np.float32)
    hard /= np.linalg.norm(hard, axis=1, keepdims=True)
    cache.lookup_batch(easy, ["lookup"] * B)
    rows_easy = cache.last_lookup_stats["rows_gathered"] / B
    hops_easy = cache.last_lookup_stats["hops"]
    cache.lookup_batch(hard, ["lookup"] * B)
    rows_hard = cache.last_lookup_stats["rows_gathered"] / B
    hops_hard = cache.last_lookup_stats["hops"]
    freeze = {"capacity": capacity, "batch": B,
              "rows_per_query_easy": round(rows_easy, 1),
              "rows_per_query_hard": round(rows_hard, 1),
              "hops_easy": int(hops_easy), "hops_hard": int(hops_hard)}
    emit(f"lookup.freeze.cap{capacity}", 0.0, **{
        k: v for k, v in freeze.items() if k != "capacity"})
    return {"runs": runs, "freeze": freeze, "compilations": compilations,
            "index": index_meta(cache.index)}


def run(capacities=CAPACITIES, prefill: int = 1000,
        lookups_per_batch: int = 20, repeats: int = 2, seed: int = 0,
        out_dir: str = "results") -> dict:
    payload = {"max_batch": MAX_BATCH, "prefill": prefill,
               "capacities": list(capacities), "runs": [], "freeze": [],
               "compilations_per_capacity": {}}
    for cap in capacities:
        r = _run_capacity(cap, prefill=min(prefill, cap // 2),
                          lookups_per_batch=lookups_per_batch,
                          repeats=repeats, seed=seed)
        payload["runs"].extend(r["runs"])
        payload["freeze"].append(r["freeze"])
        payload["compilations_per_capacity"][str(cap)] = r["compilations"]
        # emb_dtype + per-row byte costs: keeps rows-gathered comparable
        # across resident dtypes in the perf trajectory.
        payload["index"] = r["index"]
    write_bench_json("lookup", payload, out_dir=out_dir,
                     config={"prefill": prefill,
                             "lookups_per_batch": lookups_per_batch,
                             "repeats": repeats, "seed": seed,
                             "capacities": list(capacities)})
    return payload


def check(payload: dict) -> None:
    """The counter gates (deterministic — no wall-clock tolerance)."""
    for cap, n in payload["compilations_per_capacity"].items():
        if n != 1:
            raise SystemExit(
                f"bucketing regression: capacity {cap} compiled {n} "
                f"programs for batch sizes 1..{payload['max_batch']} "
                f"(expected 1 — bucketed batch shapes)")
    for f in payload["freeze"]:
        if not f["rows_per_query_easy"] < f["rows_per_query_hard"]:
            raise SystemExit(
                f"done-query freeze regression at capacity "
                f"{f['capacity']}: easy traffic gathered "
                f"{f['rows_per_query_easy']} rows/query vs "
                f"{f['rows_per_query_hard']} for miss traffic — finished "
                f"queries are still issuing gathers")
    print(f"# check ok: 1 compilation serves B=1..{payload['max_batch']} "
          f"at every capacity; freeze cuts rows/query "
          + ", ".join(f"{f['rows_per_query_hard']}→"
                      f"{f['rows_per_query_easy']} (cap {f['capacity']})"
                      for f in payload["freeze"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 capacities, fewer timed lookups")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the deterministic gates "
                         "hold: one compilation per capacity across the "
                         "batch sweep, and easy (early-finish) traffic "
                         "gathers fewer rows/query than miss traffic")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    if args.quick:
        payload = run(capacities=QUICK_CAPACITIES, prefill=600,
                      lookups_per_batch=8, repeats=1, out_dir=args.out)
    else:
        payload = run(out_dir=args.out)
    if args.check:
        check(payload)


if __name__ == "__main__":
    main()
