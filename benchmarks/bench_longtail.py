"""Table 1: long-tail hit-rate distribution + per-architecture viability.

Runs the calibrated heterogeneous workload through the hybrid cache and
reports per-category hit rates, then classifies viability under the
vector-DB and hybrid cost models using the *measured* hit rates.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.economics import HYBRID_COSTS, VDB_COSTS, category_economics, \
    workload_report
from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.serving.simulator import ServingSimulator, SimConfig

PAPER_TABLE1 = {   # category -> (traffic %, paper hit rate %)
    "code_generation": (35, 55), "api_documentation": (25, 45),
    "conversational_chat": (15, 12), "financial_data": (10, 8),
    "legal_queries": (8, 10), "medical_queries": (4, 6),
    "specialized_domains": (3, 7),
}


def run(n_queries: int = 8000, seed: int = 42):
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=seed)
    sim = ServingSimulator(eng, SimConfig(architecture="hybrid",
                                          cache_capacity=12000,
                                          index_kind="flat"))
    res = sim.run(gen, n_queries)
    rows = []
    for spec in TABLE1_WORKLOAD:
        d = res.per_category[spec.name]
        paper_traffic, paper_hit = PAPER_TABLE1[spec.name]
        econ = category_economics(spec.name, spec.traffic_share,
                                  d["hit_rate"], spec.t_llm_ms)
        rows.append(econ)
        emit(f"table1.{spec.name}",
             d["mean_latency_ms"] * 1e3,
             hit_rate=d["hit_rate"], paper_hit_rate=paper_hit / 100,
             traffic=spec.traffic_share,
             vdb_viable=econ.vdb_viable, hybrid_viable=econ.hybrid_viable,
             vdb_breakeven=econ.vdb_break_even,
             hybrid_breakeven=econ.hybrid_break_even)
    rep = workload_report(rows)
    emit("table1.coverage", 0.0,
         vdb_coverage=rep["coverage_vdb"],
         hybrid_coverage=rep["coverage_hybrid"],
         mean_latency_none=rep["mean_latency_none_ms"],
         mean_latency_vdb=rep["mean_latency_vdb_ms"],
         mean_latency_hybrid=rep["mean_latency_hybrid_ms"],
         overall_hit_rate=res.overall_hit_rate)


if __name__ == "__main__":
    run()
