"""Table 1: long-tail hit-rate distribution + per-architecture viability.

Runs the calibrated heterogeneous workload through the hybrid cache and
reports per-category hit rates, then classifies viability under the
vector-DB and hybrid cost models using the *measured* hit rates.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cache import SemanticCache
from repro.core.economics import category_economics, workload_report
from repro.core.embedding import SyntheticCategorySpace
from repro.core.hnsw import INVALID
from repro.core.policy import CategoryConfig, PolicyEngine, paper_policies
from repro.core.workload import (TABLE1_WORKLOAD, WorkloadGenerator,
                                 scenario_generator)
from repro.serving.simulator import ServingSimulator, SimConfig

PAPER_TABLE1 = {   # category -> (traffic %, paper hit rate %)
    "code_generation": (35, 55), "api_documentation": (25, 45),
    "conversational_chat": (15, 12), "financial_data": (10, 8),
    "legal_queries": (8, 10), "medical_queries": (4, 6),
    "specialized_domains": (3, 7),
}

# Scenario sweep reported next to Table 1: temporal shapes the paper's
# steady-state table can't show — bursty on/off arrival phases and a
# flash-crowd spike concentrating traffic on one hot intent.
SCENARIO_SWEEP = ("bursty", "flash_crowd", "power_law", "uniform_tail")


def run_scenarios(n_queries: int = 4000, seed: int = 42) -> dict:
    """Per-scenario hit rates through the same hybrid stack as Table 1
    (same capacity / flat index), emitted alongside the table rows."""
    out = {}
    for name in SCENARIO_SWEEP:
        eng = PolicyEngine(paper_policies())
        sim = ServingSimulator(eng, SimConfig(architecture="hybrid",
                                              cache_capacity=12000,
                                              index_kind="flat", seed=seed))
        res = sim.run(scenario_generator(name, seed=seed), n_queries)
        per = {c: d["hit_rate"] for c, d in res.per_category.items()}
        out[name] = res.overall_hit_rate
        emit(f"table1.scenario.{name}", 0.0,
             hit_rate=res.overall_hit_rate,
             p95_latency_ms=res.p95_latency_ms,
             **{f"hit_{c}": v for c, v in sorted(per.items())})
    return out


def run_mixed_category(n_intents: int = 300, head_paraphrases: int = 3,
                       seed: int = 7):
    """Mixed-category false-miss scenario (§5.3): a dense head category and
    a sparse tail category INTERLEAVE in one embedding space (paraphrases
    of the same intents). For a tail query the global nearest neighbor is
    usually a head entry; the seed behavior (global top-1 + post-hoc
    category reject) turns those into false misses, while category-masked
    search returns the tail entry sitting one position behind."""
    eng = PolicyEngine([
        CategoryConfig("head", threshold=0.88, ttl=1e6, quota=0.75,
                       priority=2.0),
        CategoryConfig("tail", threshold=0.80, ttl=1e6, quota=0.25),
    ])
    cap = n_intents * (head_paraphrases + 1) + 64
    cache = SemanticCache(eng, capacity=cap, index_kind="flat")
    rng = np.random.default_rng(seed)
    sp = SyntheticCategorySpace(name="shared", n_centers=n_intents,
                                sigma=0.012, center_spread=0.25,
                                loose_frac=0.0, seed=seed)
    for i in range(n_intents):
        for r in range(head_paraphrases):
            cache.insert(sp.sample(i, rng), "head", f"h{i}.{r}", f"hr{i}")
        cache.insert(sp.sample(i, rng), "tail", f"t{i}", f"tr{i}")

    q = sp.sample_batch(np.arange(n_intents), rng)
    tau = eng.effective("tail").threshold
    taus = np.full(n_intents, tau, np.float32)

    # Seed behavior, emulated: category-blind global nearest, then reject
    # cross-category matches (the deleted "category_mismatch" miss path).
    gi, _ = cache.index.search_host(q, taus)
    tail_cid = eng.category_id("tail")
    seed_hits = int(np.sum((gi != INVALID) &
                           (cache.slot_category[np.maximum(gi, 0)]
                            == tail_cid)))

    # Category-masked search (live behavior).
    res = cache.lookup_batch(q, ["tail"] * n_intents)
    masked_hits = sum(r.hit for r in res)

    emit("longtail.mixed.masked_hit_rate", 0.0,
         hit_rate=masked_hits / n_intents)
    emit("longtail.mixed.seed_global_nn_hit_rate", 0.0,
         hit_rate=seed_hits / n_intents)
    emit("longtail.mixed.false_misses_rescued", 0.0,
         rescued=masked_hits - seed_hits, n=n_intents)
    assert masked_hits >= seed_hits
    return masked_hits / n_intents, seed_hits / n_intents


def run(n_queries: int = 8000, seed: int = 42):
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=seed)
    sim = ServingSimulator(eng, SimConfig(architecture="hybrid",
                                          cache_capacity=12000,
                                          index_kind="flat"))
    res = sim.run(gen, n_queries)
    rows = []
    for spec in TABLE1_WORKLOAD:
        d = res.per_category[spec.name]
        paper_traffic, paper_hit = PAPER_TABLE1[spec.name]
        econ = category_economics(spec.name, spec.traffic_share,
                                  d["hit_rate"], spec.t_llm_ms)
        rows.append(econ)
        emit(f"table1.{spec.name}",
             d["mean_latency_ms"] * 1e3,
             hit_rate=d["hit_rate"], paper_hit_rate=paper_hit / 100,
             traffic=spec.traffic_share,
             vdb_viable=econ.vdb_viable, hybrid_viable=econ.hybrid_viable,
             vdb_breakeven=econ.vdb_break_even,
             hybrid_breakeven=econ.hybrid_break_even)
    rep = workload_report(rows)
    emit("table1.coverage", 0.0,
         vdb_coverage=rep["coverage_vdb"],
         hybrid_coverage=rep["coverage_hybrid"],
         mean_latency_none=rep["mean_latency_none_ms"],
         mean_latency_vdb=rep["mean_latency_vdb_ms"],
         mean_latency_hybrid=rep["mean_latency_hybrid_ms"],
         overall_hit_rate=res.overall_hit_rate)
    run_scenarios()
    run_mixed_category()


if __name__ == "__main__":
    run()
