"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
Output: ``name,us_per_call,derived`` CSV rows (stdout).

    bench_longtail    — Table 1 (long-tail hit rates + viability)
    bench_breakeven   — §4.4/§5.5 eqs (1)–(5), measured local search
    bench_latency     — §5.2 expected latency (3.0 ms vs 31 ms)
    bench_thresholds  — §3.1 density ↔ threshold FP/FN rates
    bench_memory      — §5.1/§7.4 bytes/entry accounting
    bench_hnsw        — §7.4 index scaling curve
    bench_adaptive    — §7.5 load-adaptive traffic reduction (9–17 %)
    bench_routing     — §7.5.5 multi-model per-hit value
    bench_kernels     — kernel microbench + TPU roofline projections
    bench_serve       — steady-state device-sync cost: O(delta) vs
                        O(capacity) across a cache-capacity sweep
    bench_lookup      — lookup hot-loop p50/p99 vs capacity and batch
                        size, counter-gated (bucketing, done-query freeze)
    bench_quant       — quantized residency: fp32 vs int8 byte ratios
                        (resident / synced / gathered, ~4x), counter-gated
    bench_shard       — sharded tier: planner-vs-crc32 placement balance
                        on Table 1 + per-shard sync flatness across a
                        capacity sweep, counter-gated
    bench_admission   — admission gate + cost-aware eviction: hit rate
                        per resident byte on the scenario matrix,
                        counter-gated (uniform_tail improves strictly,
                        power_law head untouched)
    bench_faults      — fault-injected degraded serving: shard-outage
                        availability + write-behind replay, store
                        retry/backoff/timeouts, counter-gated (empty
                        schedule bit-identical to no injector)
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_adaptive, bench_admission, bench_breakeven,
                        bench_faults, bench_hnsw, bench_kernels,
                        bench_latency, bench_longtail, bench_lookup,
                        bench_memory, bench_quant, bench_routing,
                        bench_serve, bench_shard, bench_thresholds)

ALL = {
    "longtail": bench_longtail.run,
    "breakeven": bench_breakeven.run,
    "latency": bench_latency.run,
    "thresholds": bench_thresholds.run,
    "memory": bench_memory.run,
    "hnsw": bench_hnsw.run,
    "adaptive": bench_adaptive.run,
    "routing": bench_routing.run,
    "kernels": bench_kernels.run,
    "serve": bench_serve.run,
    "lookup": bench_lookup.run,
    "quant": bench_quant.run,
    "shard": bench_shard.run,
    "admission": bench_admission.run,
    "faults": bench_faults.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (default: all)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            ALL[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
