"""Admission control + cost-aware eviction: hit rate per resident byte.

Gated ONLY on deterministic counters (hits, misses, admission skips,
resident-entry integrals — never wall clock):

    uniform_tail — conversational chat over a 50 k-intent uniform pool
                   with a small persistent hot set. Unconditional
                   admission churns the category quota on entries that
                   never re-hit; admit-on-2nd-touch must STRICTLY
                   improve hits per resident MB.
    power_law    — pure Zipf code traffic. The admission config only
                   gates the chat category, so hit/miss counters must be
                   EXACTLY identical with admission on and off — the
                   head workload is provably untouched.
    accounting   — per run: category lookups sum to queries issued and
                   hits + misses == lookups (admission skips are an
                   insert-side counter, not a hit-rate denominator leak).

Full mode adds the scenario-matrix sweep (every scenario × eviction
policy, reported) and the eviction contrast: overcommitted quotas at
tight capacity, the one regime where capacity — not per-category
quota — picks cross-category victims, so static (priority) and
cost_aware (tllm per byte) genuinely diverge; gated on cost_aware not
regressing model cost.

Emits CSV rows and ``results/BENCH_admission.json`` (CI smoke runs
``--quick --check``).

    PYTHONPATH=src python -m benchmarks.bench_admission [--quick] [--check]
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, write_bench_json
from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import SCENARIO_NAMES, scenario_generator
from repro.serving.simulator import ServingSimulator, SimConfig

CAPACITY = 4000
# The category the admission gate is enabled for: Table 1's uniform-
# repetition shape, where unconditional admission wastes the most bytes.
GATED_CATEGORY = "conversational_chat"
# Eviction-contrast regime: quotas overcommitted to 1.0 so CAPACITY
# arbitrates across categories — the only regime where static (priority-
# ranked) and cost_aware (tllm-per-byte-ranked) victim orderings can
# differ, since within one category both scorers rank identically
# (per-category factors are constants).
CONTRAST_SCENARIO = "stale_burst"
CONTRAST_CAPACITY = 500


def run_scenario(name: str, *, admission: bool, eviction: str = "static",
                 n: int = 5000, capacity: int = CAPACITY,
                 seed: int = 0, overcommit: bool = False) -> dict:
    """One deterministic simulator run; returns the gate counters."""
    pol = PolicyEngine(paper_policies())
    if admission:
        pol.update(GATED_CATEGORY, admit_after=2)
    if overcommit:
        for c in pol.categories():
            pol.update(c, quota=1.0)
    sim = ServingSimulator(pol, SimConfig(
        architecture="hybrid", cache_capacity=capacity, index_kind="flat",
        eviction=eviction, seed=seed))
    res = sim.run(scenario_generator(name, seed=seed), n)
    per = res.metrics.per_category
    lookups = sum(s.lookups for s in per.values())
    hits = sum(s.hits for s in per.values())
    misses = sum(s.misses for s in per.values())
    skips = sum(s.admission_skips for s in per.values())
    return {
        "scenario": name, "admission": admission, "eviction": eviction,
        "n_queries": n, "lookups": lookups, "hits": hits, "misses": misses,
        "admission_skips": skips,
        "hit_rate": round(res.overall_hit_rate, 4),
        "mean_resident_entries": round(res.mean_resident_entries, 1),
        "hits_per_resident_mb": round(res.hits_per_resident_mb, 3),
        "stale_served": res.stale_served,
        "model_cost": round(res.model_cost, 2),
    }


def run(n: int = 5000, capacity: int = CAPACITY, seed: int = 0,
        sweep: bool = True, out_dir: str = "results") -> dict:
    # Gate runs: uniform_tail and power_law, admission off vs on.
    gate = {}
    for scen in ("uniform_tail", "power_law"):
        for adm in (False, True):
            r = run_scenario(scen, admission=adm, n=n, capacity=capacity,
                             seed=seed)
            gate[f"{scen}.{'on' if adm else 'off'}"] = r
            emit(f"admission.{scen}.{'on' if adm else 'off'}", 0.0,
                 hit_rate=r["hit_rate"],
                 hits_per_mb=r["hits_per_resident_mb"],
                 resident=r["mean_resident_entries"],
                 skips=r["admission_skips"])
    # Reported sweep: every scenario × eviction policy (admission on).
    matrix = []
    if sweep:
        for scen in SCENARIO_NAMES:
            for ev in ("static", "cost_aware"):
                r = run_scenario(scen, admission=True, eviction=ev,
                                 n=n, capacity=capacity, seed=seed)
                matrix.append(r)
                emit(f"admission.matrix.{scen}.{ev}", 0.0,
                     hit_rate=r["hit_rate"],
                     hits_per_mb=r["hits_per_resident_mb"])
    # Eviction contrast (full mode): overcommitted quotas at tight
    # capacity, where capacity — not quota — picks cross-category
    # victims and the scorers genuinely diverge.
    contrast = {}
    if sweep:
        for ev in ("static", "cost_aware"):
            r = run_scenario(CONTRAST_SCENARIO, admission=True, eviction=ev,
                             n=n, capacity=CONTRAST_CAPACITY, seed=seed,
                             overcommit=True)
            contrast[ev] = r
            emit(f"admission.contrast.{CONTRAST_SCENARIO}.{ev}", 0.0,
                 hit_rate=r["hit_rate"], model_cost=r["model_cost"])
    payload = {
        "n_queries": n, "capacity": capacity, "seed": seed,
        "gated_category": GATED_CATEGORY,
        "gate": gate,
        "scenario_matrix": matrix,
        "eviction_contrast": contrast,
    }
    write_bench_json("admission", payload, out_dir=out_dir)
    return payload


def check(payload: dict) -> None:
    """The deterministic acceptance gates (CI smoke)."""
    g = payload["gate"]
    off, on = g["uniform_tail.off"], g["uniform_tail.on"]
    if not on["hits_per_resident_mb"] > off["hits_per_resident_mb"]:
        raise SystemExit(
            f"admission regression: uniform_tail hits/resident-MB "
            f"{on['hits_per_resident_mb']} (admission on) not strictly "
            f"better than {off['hits_per_resident_mb']} (off)")
    if on["admission_skips"] <= 0:
        raise SystemExit(
            "admission gate never fired on the uniform tail "
            "(admission_skips == 0) — the sketch is not being consulted")
    p_off, p_on = g["power_law.off"], g["power_law.on"]
    for k in ("lookups", "hits", "misses"):
        if p_off[k] != p_on[k]:
            raise SystemExit(
                f"power_law perturbed by admission config: {k} "
                f"{p_off[k]} (off) != {p_on[k]} (on) — the gate must "
                f"only touch {payload['gated_category']}")
    contrast = payload.get("eviction_contrast") or {}
    if contrast:
        st, ca = contrast["static"], contrast["cost_aware"]
        # cost_aware exists to minimize model spend per resident byte;
        # under capacity-arbitrated eviction it must not cost MORE than
        # the priority heuristic (deterministic counter comparison).
        if ca["model_cost"] > st["model_cost"]:
            raise SystemExit(
                f"cost_aware eviction regressed model cost under "
                f"capacity pressure: {ca['model_cost']} > "
                f"{st['model_cost']} (static)")
    for run_name, r in g.items():
        if r["lookups"] != r["n_queries"]:
            raise SystemExit(
                f"accounting leak ({run_name}): {r['lookups']} lookups "
                f"!= {r['n_queries']} queries issued")
        if r["hits"] + r["misses"] != r["lookups"]:
            raise SystemExit(
                f"accounting leak ({run_name}): hits {r['hits']} + "
                f"misses {r['misses']} != lookups {r['lookups']}")
    print(f"# check ok: uniform_tail {off['hits_per_resident_mb']} -> "
          f"{on['hits_per_resident_mb']} hits/MB "
          f"({on['admission_skips']} skips), power_law identical, "
          f"counters sum to queries")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer queries, gate scenarios only")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the hits-per-byte / "
                         "head-unchanged / accounting gates hold")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    n = 2000 if args.quick else 5000
    payload = run(n=n, sweep=not args.quick, out_dir=args.out)
    if args.check:
        check(payload)


if __name__ == "__main__":
    main()
