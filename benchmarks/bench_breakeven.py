"""§4.4/§5.5 break-even analysis, with the local-search cost MEASURED.

The 2 ms hybrid miss cost is the paper's calibration; here we also measure
what this container actually achieves for the in-memory search (host HNSW
and jitted flat scan) and derive break-even hit rates from both the
paper's constants and the measured cost.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_callable
from repro.core.economics import CostModel, HYBRID_COSTS, VDB_COSTS
from repro.core.hnsw import FlatIndex, HNSWIndex


def run(n_entries: int = 20000, seed: int = 0):
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((n_entries, 384)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    hnsw = HNSWIndex.bulk_build(vecs, seed=seed)
    flat = FlatIndex(384, n_entries + 8)
    for v in vecs:
        flat.add(v)
    q = vecs[rng.integers(0, n_entries, 16)]
    B = q.shape[0]
    taus = np.full(B, 0.9, np.float32)

    us_hnsw = time_callable(lambda: hnsw.search_host(q[:1], taus[:1]), iters=20)
    us_flat = time_callable(lambda: flat.search_host(q, taus), iters=20) / B
    # Batched device-style search (jitted beam search), amortized over the
    # ACTUAL query-batch size. search_batch returns device arrays, so the
    # timed call must block — otherwise it measures dispatch, not search.
    jax.block_until_ready(hnsw.search_batch(q, taus))  # compile
    us_beam = time_callable(
        lambda: jax.block_until_ready(hnsw.search_batch(q, taus)),
        iters=10) / B

    emit("breakeven.local_search.hnsw_host", us_hnsw, entries=n_entries)
    emit("breakeven.local_search.flat_np", us_flat, entries=n_entries)
    emit("breakeven.local_search.beam_jax", us_beam, entries=n_entries,
         batch=B)

    for t_llm, tag in ((200.0, "fast_model"), (500.0, "slow_model")):
        for model, name in ((VDB_COSTS, "vdb"), (HYBRID_COSTS, "hybrid")):
            be = model.break_even_hit_rate(t_llm)
            emit(f"breakeven.{name}.{tag}", model.search_ms * 1e3,
                 t_llm_ms=t_llm, break_even=be)
        measured = CostModel("measured", search_ms=us_hnsw / 1e3,
                             hit_fetch_ms=5.0)
        emit(f"breakeven.measured.{tag}", us_hnsw,
             t_llm_ms=t_llm, break_even=measured.break_even_hit_rate(t_llm))
    # ratios the paper quotes: 15× (fast) / 10× (slow) reduction
    emit("breakeven.reduction_factor", 0.0,
         fast=VDB_COSTS.break_even_hit_rate(200.0)
         / HYBRID_COSTS.break_even_hit_rate(200.0),
         slow=VDB_COSTS.break_even_hit_rate(500.0)
         / HYBRID_COSTS.break_even_hit_rate(500.0))


if __name__ == "__main__":
    run()
