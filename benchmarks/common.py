"""Shared benchmark helpers. Every bench emits ``name,us_per_call,derived``
CSV rows via ``emit`` (derived = semicolon-separated key=value pairs)."""

from __future__ import annotations

import time


def emit(name: str, us_per_call: float, **derived) -> str:
    pairs = ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())
    row = f"{name},{us_per_call:.2f},{pairs}"
    print(row, flush=True)
    return row


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def time_callable(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Mean wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
