"""Shared benchmark helpers. Every bench emits ``name,us_per_call,derived``
CSV rows via ``emit`` (derived = semicolon-separated key=value pairs);
benches with tracked acceptance numbers also write a machine-readable
``results/BENCH_<name>.json`` via ``write_bench_json`` (consumed by CI)."""

from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time


def emit(name: str, us_per_call: float, **derived) -> str:
    pairs = ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())
    row = f"{name},{us_per_call:.2f},{pairs}"
    print(row, flush=True)
    return row


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(["git", *args], capture_output=True,
                             text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
    except (OSError, subprocess.SubprocessError):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


def provenance(config: dict | None = None) -> dict:
    """Provenance stamp for every ``BENCH_*.json``: the git commit the
    numbers came from, whether the tree was dirty, and a short stable
    hash of the run configuration — so two result files are comparable
    only when their config hashes match. Git being absent (tarball
    checkout) degrades to ``None`` fields, never an error."""
    sha = _git("rev-parse", "HEAD")
    status = _git("status", "--porcelain")
    cfg_hash = None
    if config:
        blob = json.dumps(config, sort_keys=True, default=str)
        cfg_hash = hashlib.sha256(blob.encode()).hexdigest()[:12]
    return {
        "git_sha": sha,
        "git_dirty": bool(status) if status is not None else None,
        "config_hash": cfg_hash,
    }


def write_bench_json(name: str, payload: dict, out_dir: str = "results",
                     config: dict | None = None) -> str:
    """Write ``results/BENCH_<name>.json`` and return its path. A
    ``provenance`` block (git SHA, dirty flag, config hash over
    ``config`` — pass the bench's knob dict) is stamped into every
    payload unless the caller already provided one."""
    os.makedirs(out_dir, exist_ok=True)
    payload = dict(payload)
    payload.setdefault("provenance", provenance(config))
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return path


def index_meta(index, n_shards: int = 1) -> dict:
    """Embedding-tier layout of a DeviceResidentIndex, recorded in every
    BENCH_*.json payload so perf trajectories stay comparable across
    resident dtypes AND topologies: the dtype, the per-row embedding
    payload (incl. the int8 scale word), the full synced row size, and
    the shard count (1 = single device-resident index; for a
    ShardedSemanticCache pass its ``n_shards`` alongside one shard's
    index — per-row layout is identical across shards)."""
    return {
        "emb_dtype": index.emb_dtype,
        "emb_row_bytes": index.emb_row_nbytes(),
        "row_nbytes": index.row_nbytes(),
        "n_shards": int(n_shards),
    }


def time_callable(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Mean wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
