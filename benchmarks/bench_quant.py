"""Quantized embedding data plane: fp32 vs int8 at matched capacity.

The paper's economics need the in-memory tier cheap enough that
low-hit-rate categories break even; the int8 resident tier cuts the
embedding component of every byte stream ~4x (d·4 → d + 4 bytes/row:
int8 rows + one fp32 dequant scale). This bench measures the three
streams where those bytes move, fp32 vs int8 with the SAME content at
the SAME capacity, and gates on DETERMINISTIC byte counters — this
container has ~30 % wall-clock noise, the byte counters have none:

    resident  — emb bytes per resident entry (index.emb_row_nbytes)
    sync      — emb bytes moved per steady-state delta flush
                (sync_stats["emb_bytes_synced"]; the dirty-row pattern is
                identical across dtypes because graph wiring runs on the
                fp32 host control plane, so the ratio is exact)
    gather    — bytes gathered per query by the beam search
                (rows_gathered × per-row gather cost; row counts can
                drift a little between dtypes, so this gate is looser)

Decision parity at the τ boundary is the re-rank tier's property test
(tests/test_quantized.py), not a wall-clock concern; this bench reports
hit rates as a sanity row only.

A fourth, fully static gate reads the COMPILED search itself: the
per-dtype byte split of the lowered HLO (``hlo_cost.bytes_by_dtype`` —
the same accounting path the ``contracts.DtypeDiscipline`` rule uses),
asserting the int8 run's executable actually moves its table bytes as
s8 and carries no silent fp32 rematerialization.

Emits CSV rows and ``results/BENCH_quant.json``; ``--check`` is the CI
smoke gate (~4x resident/sync, >3x gather).

    PYTHONPATH=src python -m benchmarks.bench_quant [--quick] [--check]
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, index_meta, write_bench_json
from repro.analysis import hlo_cost
from repro.analysis.contracts import DtypeDiscipline, lower_classified_search
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.embedding import SyntheticCategorySpace
from repro.core.policy import CategoryConfig, PolicyEngine

DTYPES = ("float32", "int8")


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("quant", threshold=0.88, ttl=1e9, quota=1.0),
    ])


def _run_dtype(emb_dtype: str, *, capacity: int, prefill: int, steps: int,
               batch: int, seed: int) -> dict:
    """One steady-state run: prefill, then ``steps`` of (lookup batch +
    insert batch + delta flush). Same seed ⇒ same vectors, same host
    graph wiring, same dirty rows — only the bytes differ by dtype."""
    rng = np.random.default_rng(seed)
    sp = SyntheticCategorySpace(name="quant", n_centers=200_000,
                                sigma=0.015, loose_frac=0.0, seed=seed)
    cache = SemanticCache(_policies(), capacity=capacity, clock=SimClock(),
                          index_kind="hnsw", use_device=True, seed=seed,
                          emb_dtype=emb_dtype)
    ids = np.arange(prefill)
    embs = np.stack([sp.sample(int(i), rng) for i in ids])
    cache.insert_batch(embs, ["quant"] * prefill,
                       [f"q{i}" for i in ids], [f"r{i}" for i in ids])
    cache.lookup_batch(embs[:batch], ["quant"] * batch)   # initial upload

    sync_rows_0 = cache.index.sync_stats["rows_synced"]
    sync_emb_0 = cache.index.sync_stats["emb_bytes_synced"]
    next_intent = prefill
    rows_gathered, gathered_bytes, hits, lookups = 0, 0, 0, 0
    for s in range(steps):
        hot = rng.integers(0, prefill, batch // 2)
        cold = np.arange(next_intent, next_intent + batch - batch // 2)
        next_intent += len(cold)
        q = np.stack([sp.sample(int(i), rng)
                      for i in np.concatenate([hot, cold])])
        results = cache.lookup_batch(q, ["quant"] * batch)
        ls = cache.last_lookup_stats
        rows_gathered += ls["rows_gathered"]
        gathered_bytes += ls["gathered_bytes"]
        hits += sum(r.hit for r in results)
        lookups += batch
        miss = [i for i, r in enumerate(results) if not r.hit]
        if miss:
            cache.insert_batch(q[miss], ["quant"] * len(miss),
                               [f"mq{s}_{i}" for i in miss],
                               [f"mr{s}_{i}" for i in miss])
        cache.index.device_tables()             # attribute sync to the step
    out = {
        "emb_dtype": emb_dtype,
        "capacity": capacity,
        "hit_rate": round(hits / max(1, lookups), 3),
        **index_meta(cache.index),
        "sync_rows": cache.index.sync_stats["rows_synced"] - sync_rows_0,
        "sync_emb_bytes": cache.index.sync_stats["emb_bytes_synced"]
        - sync_emb_0,
        "rows_gathered_per_query": round(rows_gathered / max(1, lookups), 1),
        "gathered_bytes_per_query": round(gathered_bytes / max(1, lookups)),
        "reranks": sum(st.reranks
                       for st in cache.metrics.per_category.values()),
    }
    out["sync_emb_bytes_per_step"] = out["sync_emb_bytes"] // max(1, steps)
    # Static HLO gate: the compiled search's per-dtype byte split, off
    # the SAME accounting path as contracts.DtypeDiscipline.
    trace = lower_classified_search(cache.index,
                                    name=f"bench_quant[{emb_dtype}]")
    split = hlo_cost.analyze(trace.hlo).bytes_by_dtype
    out["hlo_s8_bytes"] = int(split.get("s8", 0))
    out["hlo_f32_bytes"] = int(split.get("f32", 0))
    out["hlo_dtype_violations"] = [str(v)
                                   for v in DtypeDiscipline().check(trace)]
    emit(f"quant.{emb_dtype}.cap{capacity}", 0.0, **{
        k: v for k, v in out.items() if k not in ("emb_dtype", "capacity")})
    return out


def run(capacity: int = 8192, prefill: int = 800, steps: int = 12,
        batch: int = 16, seed: int = 0, out_dir: str = "results") -> dict:
    runs = {dt: _run_dtype(dt, capacity=capacity, prefill=prefill,
                           steps=steps, batch=batch, seed=seed)
            for dt in DTYPES}
    f32, i8 = runs["float32"], runs["int8"]
    ratios = {
        "resident_emb_bytes": round(f32["emb_row_bytes"]
                                    / i8["emb_row_bytes"], 3),
        "sync_emb_bytes": round(f32["sync_emb_bytes"]
                                / max(1, i8["sync_emb_bytes"]), 3),
        "gathered_bytes_per_query": round(
            f32["gathered_bytes_per_query"]
            / max(1, i8["gathered_bytes_per_query"]), 3),
        "sync_rows_equal": f32["sync_rows"] == i8["sync_rows"],
    }
    emit("quant.ratio.fp32_over_int8", 0.0, **ratios)
    payload = {"capacity": capacity, "prefill": prefill, "steps": steps,
               "batch": batch, "runs": list(runs.values()),
               "ratios": ratios}
    write_bench_json("quant", payload, out_dir=out_dir)
    return payload


def check(payload: dict) -> None:
    """The ~4x acceptance gates — deterministic byte counters only."""
    r = payload["ratios"]
    if not r["sync_rows_equal"]:
        raise SystemExit(
            "quant determinism regression: fp32 and int8 runs synced "
            "different row counts — graph wiring must ride the fp32 host "
            "control plane so the dirty pattern is dtype-independent")
    if r["resident_emb_bytes"] < 3.5:
        raise SystemExit(
            f"resident-bytes regression: int8 residency shrinks the "
            f"embedding row only {r['resident_emb_bytes']}x (expected "
            f"~4x: d·4 → d + 4 scale bytes)")
    if r["sync_emb_bytes"] < 3.5:
        raise SystemExit(
            f"sync-bytes regression: emb bytes per delta flush shrink "
            f"only {r['sync_emb_bytes']}x under int8 (expected ~4x — is "
            f"the scale table double-counted or the fp32 table leaking "
            f"into the sync?)")
    if r["gathered_bytes_per_query"] < 3.0:
        raise SystemExit(
            f"gather-bytes regression: bytes gathered per query shrink "
            f"only {r['gathered_bytes_per_query']}x under int8 "
            f"(expected ~4x modulo small beam-path drift)")
    runs = {run["emb_dtype"]: run for run in payload["runs"]}
    f32, i8 = runs["float32"], runs["int8"]
    if i8["hlo_dtype_violations"]:
        raise SystemExit(
            "DtypeDiscipline violation in the int8 search executable:\n"
            + "\n".join(i8["hlo_dtype_violations"]))
    if i8["hlo_s8_bytes"] <= i8["hlo_f32_bytes"]:
        raise SystemExit(
            f"quantized HLO regression: the compiled int8 search moves "
            f"{i8['hlo_s8_bytes']} s8 bytes vs {i8['hlo_f32_bytes']} f32 "
            f"bytes — the int8 table should dominate its own traffic")
    if f32["hlo_s8_bytes"] >= 4096:
        raise SystemExit(
            f"fp32 HLO oddity: the fp32 search moves "
            f"{f32['hlo_s8_bytes']} s8 bytes (expected ~none)")
    print(f"# check ok: fp32/int8 byte ratios — resident "
          f"{r['resident_emb_bytes']}x, sync {r['sync_emb_bytes']}x, "
          f"gather {r['gathered_bytes_per_query']}x (sync rows equal); "
          f"compiled int8 search moves {i8['hlo_s8_bytes']} s8 bytes, "
          f"0 dtype violations")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller capacity/prefill, fewer steps")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the fp32/int8 byte ratios "
                         "hold (~4x resident + sync, >3x gather; all "
                         "deterministic counters)")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    if args.quick:
        payload = run(capacity=2048, prefill=300, steps=6, out_dir=args.out)
    else:
        payload = run(out_dir=args.out)
    if args.check:
        check(payload)


if __name__ == "__main__":
    main()
