"""§7.5 adaptive load-based policies: traffic reduction under a spike.

Paper projection: threshold relaxation of 0.05 cuts model traffic by
9–17 % depending on base hit rate (linear Δh=k·δ assumption). We measure
the actual reduction end-to-end in the simulator, with the §7.5.6
FP-feedback loop active, for the loaded model only (§7.5.5 isolation).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.economics import traffic_reduction
from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.serving.simulator import ServingSimulator, SimConfig


def simulate(adaptive: bool, spikes, n: int, seed: int,
             fp_rate_limit: float = 0.05):
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=seed)
    sim = ServingSimulator(eng, SimConfig(
        architecture="hybrid", cache_capacity=12000, index_kind="flat",
        adaptive=adaptive, fp_rate_limit=fp_rate_limit,
        load_spikes=list(spikes)))
    return sim.run(gen, n)


def run(n: int = 6000, seed: int = 11):
    # §7.5.4 analytic projections
    for h0 in (0.40, 0.45, 0.55):
        dh = 0.05  # k=1.0 per 0.01 → Δh = 0.05 at δ=0.05
        emit(f"adaptive.analytic.h0_{h0:.2f}", 0.0,
             delta_h=dh, reduction=traffic_reduction(h0, dh))

    spikes = [(30.0, 1000.0, "o1", 3.0)]      # 3× spike on the code model
    base = simulate(False, spikes, n, seed)
    adap = simulate(True, spikes, n, seed)
    calls_b = base.model_calls.get("o1", 1)
    calls_a = adap.model_calls.get("o1", 1)
    fp_b = base.per_category["code_generation"]["false_positives"]
    fp_a = adap.per_category["code_generation"]["false_positives"]
    emit("adaptive.spike_o1", 0.0,
         calls_base=calls_b, calls_adaptive=calls_a,
         traffic_reduction=1 - calls_a / calls_b,
         paper_projection="0.09-0.17",
         fp_base=fp_b, fp_adaptive=fp_a,
         hit_base=base.per_category["code_generation"]["hit_rate"],
         hit_adaptive=adap.per_category["code_generation"]["hit_rate"])
    # isolation: unloaded models keep their traffic (±5 %)
    other_b = sum(v for k, v in base.model_calls.items() if k != "o1")
    other_a = sum(v for k, v in adap.model_calls.items() if k != "o1")
    emit("adaptive.isolation_other_models", 0.0,
         calls_base=other_b, calls_adaptive=other_a,
         drift=abs(other_a - other_b) / max(1, other_b))
    # latency win for users during the spike
    emit("adaptive.latency", 0.0,
         mean_base_ms=base.mean_latency_ms,
         mean_adaptive_ms=adap.mean_latency_ms,
         stale_base=base.stale_served, stale_adaptive=adap.stale_served)

    # Paper's-assumptions variant: §7.5.4 projects 9–17 % from Δh = k·δ
    # with NO accuracy constraint. Disabling the FP-feedback loop
    # (fp_rate_limit=1.0) reproduces that regime; the run above shows what
    # survives once §7.5.6 safety is enforced.
    adap_nofb = simulate(True, spikes, n, seed, fp_rate_limit=1.0)
    ca_nofb = adap_nofb.model_calls.get("o1", 1)
    fp_nofb = adap_nofb.per_category["code_generation"]["false_positives"]
    emit("adaptive.spike_o1_no_fp_safety", 0.0,
         calls_base=calls_b, calls_adaptive=ca_nofb,
         traffic_reduction=1 - ca_nofb / calls_b,
         paper_projection="0.09-0.17",
         fp_code=fp_nofb,
         hit_code=adap_nofb.per_category["code_generation"]["hit_rate"],
         note="projection_reproduced_at_accuracy_cost")

    # Second scenario: spike on gpt4o (legal/api/medical). Legal's space is
    # sparse enough that relaxed τ stays FP-free → the full projected
    # reduction is achievable there (vs the FP-bounded dense code case).
    spikes2 = [(30.0, 1000.0, "gpt4o", 3.0)]
    base2 = simulate(False, spikes2, n, seed + 1)
    adap2 = simulate(True, spikes2, n, seed + 1)
    cb = base2.model_calls.get("gpt4o", 1)
    ca = adap2.model_calls.get("gpt4o", 1)
    emit("adaptive.spike_gpt4o", 0.0,
         calls_base=cb, calls_adaptive=ca,
         traffic_reduction=1 - ca / cb,
         hit_legal_base=base2.per_category["legal_queries"]["hit_rate"],
         hit_legal_adaptive=adap2.per_category["legal_queries"]["hit_rate"],
         fp_legal_adaptive=adap2.per_category["legal_queries"]
         ["false_positives"])


if __name__ == "__main__":
    run()
