"""Kernel microbenchmarks (interpret mode on CPU = correctness-scale only;
TPU projections from the roofline model are reported alongside).

Roofline projections (v5e: 197 TFLOP/s bf16, 819 GB/s HBM):
  flat_topk over N×384 fp32  → max(bytes/819e9, flops/197e12)
  decode_attention B,H,S,dh  → KV bytes / 819e9
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_callable
from repro.kernels import ops, ref

HBM = 819e9
PEAK = 197e12


def run(seed: int = 0):
    rng = np.random.default_rng(seed)

    # flat cache scan (the 2 ms local search at 1 M entries)
    for n in (4096, 16384):
        table = rng.standard_normal((n, 384)).astype(np.float32)
        valid = np.ones(n, bool)
        q = rng.standard_normal((16, 384)).astype(np.float32)
        args = (jnp.asarray(table), jnp.asarray(valid), jnp.asarray(q))
        us_ref = time_callable(
            lambda: ref.flat_topk_ref(args[0], args[1], args[2]
                                      )[0].block_until_ready(), iters=5)
        emit(f"kernels.flat_topk_ref.n{n}", us_ref, entries=n, batch=16)
    # TPU roofline projection at 1 M entries (paper's budget: 2 ms)
    n = 1_000_000
    bytes_scanned = n * 384 * 4
    flops = 2 * n * 384 * 16
    emit("kernels.flat_topk.tpu_projection_1M", 0.0,
         mem_ms=bytes_scanned / HBM * 1e3,
         compute_ms=flops / PEAK * 1e3,
         bound="memory", paper_budget_ms=2.0)

    # HNSW hop (gather_scores): bytes = B·K·d·4
    B, K = 16, 1024
    emit("kernels.gather_scores.tpu_projection", 0.0,
         bytes_per_hop=B * K * 384 * 4,
         mem_us=B * K * 384 * 4 / HBM * 1e6,
         hops=8, total_us=8 * B * K * 384 * 4 / HBM * 1e6)

    # decode attention: KV-bandwidth bound
    for (b, hkv, s, dh, name) in ((128, 8, 32768, 128, "decode_32k"),
                                  (1, 8, 524288, 128, "long_500k")):
        kv_bytes = 2 * b * hkv * s * dh * 2      # k+v bf16
        emit(f"kernels.decode_attention.{name}", 0.0,
             kv_bytes=kv_bytes, mem_ms_single_chip=kv_bytes / HBM * 1e3,
             mem_us_256chips=kv_bytes / 256 / HBM * 1e6)

    # cache maintenance hot loops (vectorized sweep/eviction scoring):
    # per-slot Python policy loops → numpy over per-category tables
    from repro.core.cache import SemanticCache
    from repro.core.policy import CategoryConfig, PolicyEngine
    eng = PolicyEngine([
        CategoryConfig(f"cat{i}", threshold=0.85, ttl=3600.0 * (i + 1),
                       quota=1.0 / 8, priority=float(i + 1))
        for i in range(8)])
    cache = SemanticCache(eng, capacity=16384, index_kind="flat")
    vecs = rng.standard_normal((8192, 384)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    for j in range(8192):
        cache.insert(vecs[j], f"cat{j % 8}", f"q{j}", f"r{j}")
    slots = np.where(cache.slot_valid)[0]
    us_score = time_callable(lambda: cache._entry_score(slots), iters=20)
    emit("cache.entry_score.n8192", us_score, entries=len(slots),
         us_per_slot=us_score / max(1, len(slots)))
    us_sweep = time_callable(cache.sweep_expired, iters=20)
    emit("cache.sweep_expired.n8192", us_sweep, entries=len(cache),
         us_per_slot=us_sweep / max(1, len(cache)))

    # interpret-mode correctness-scale timings (not perf numbers)
    q = (rng.standard_normal((1, 4, 64, 64)) * 0.3).astype(np.float32)
    k = (rng.standard_normal((1, 2, 64, 64)) * 0.3).astype(np.float32)
    us = time_callable(
        lambda: np.asarray(ops.flash_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(k),
            block_q=64, block_k=64, interpret=True)), iters=3)
    emit("kernels.flash_attention.interpret_64tok", us,
         note="interpret-mode_correctness_path")


if __name__ == "__main__":
    run()
