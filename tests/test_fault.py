"""Fault tolerance: watchdog, preemption, retry."""

import pytest

from repro.distributed.fault import (PreemptionHandler, StepWatchdog,
                                     retry_step)


def test_watchdog_flags_stragglers():
    events = []
    wd = StepWatchdog(timeout_factor=3.0, min_history=5,
                      on_straggler=lambda dt, med: events.append((dt, med)))
    for _ in range(10):
        wd.observe_for_test(0.1)
    wd.observe_for_test(0.5)      # 5× median → straggler
    assert wd.straggler_events == 1
    assert events and events[0][0] == pytest.approx(0.5)
    wd.observe_for_test(0.12)     # normal again
    assert wd.straggler_events == 1


def test_watchdog_needs_history():
    wd = StepWatchdog(min_history=5)
    wd.observe_for_test(10.0)     # first step slow (compile) — no event
    assert wd.straggler_events == 0


def test_preemption_flag_via_trigger():
    h = PreemptionHandler().install()
    assert not h.preempted
    h.trigger_for_test()
    assert h.preempted
    h.uninstall()


def test_preemption_triggers_emergency_checkpoint(tmp_path):
    """SIGTERM-style preemption mid-run → checkpoint written + clean exit."""
    from repro.configs import get_config
    from repro.launch.train import run_training
    import repro.distributed.fault as fault

    cfg = get_config("llama3_2_3b").reduced(n_layers=2, d_model=64,
                                            vocab_size=256)
    orig_install = fault.PreemptionHandler.install

    def install_and_fire(self):
        orig_install(self)
        self.trigger_for_test()
        return self
    fault.PreemptionHandler.install = install_and_fire
    try:
        res = run_training(cfg, steps=50, batch=2, seq=32,
                           ckpt_dir=str(tmp_path), ckpt_every=1000,
                           log=lambda *_: None)
    finally:
        fault.PreemptionHandler.install = orig_install
    assert res["steps_run"] == 1          # stopped at first boundary
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 1


def test_retry_step_retries_then_raises():
    calls = []

    def flaky():
        calls.append(1)
        raise RuntimeError("transient")

    with pytest.raises(RuntimeError):
        retry_step(flaky, retries=2)
    assert len(calls) == 3

    attempts = []

    def ok_after_one():
        attempts.append(1)
        if len(attempts) < 2:
            raise RuntimeError("once")
        return "fine"

    assert retry_step(ok_after_one, retries=2) == "fine"


def test_retry_step_backoff_charges_injected_clock():
    """Backoff routes through the injectable Clock: on a SimClock the
    2^k ladder is pure simulated time — deterministic, no wall sleep —
    and a success consumes only the backoff of the failed attempts."""
    from repro.core.clock import SimClock

    clk = SimClock()
    attempts = []

    def ok_after_two():
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "fine"

    assert retry_step(ok_after_two, retries=3, backoff_s=0.5,
                      clock=clk) == "fine"
    assert clk.now() == pytest.approx(0.5 + 1.0)    # 0.5·2^0 + 0.5·2^1

    # exhaustion: no backoff after the FINAL attempt (nothing to wait for)
    clk2 = SimClock()
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("x")),
                   retries=2, backoff_s=0.25, clock=clk2)
    assert clk2.now() == pytest.approx(0.25 + 0.5)

    # default backoff_s=0 keeps the historical retry-immediately path
    clk3 = SimClock()
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("x")),
                   retries=1, clock=clk3)
    assert clk3.now() == 0.0
