"""Sharded cache tier (core/shard.py): planner placement, sharded-vs-
single parity, live category migration.

The parity tests are the subsystem's contract: because search is
category-masked and quota ceilings resolve against the GLOBAL capacity
on every shard, a ``ShardedSemanticCache`` over any shard count must
return bit-identical {hit, expired, miss} classes and serve the same
documents as one ``SemanticCache`` on the same workload — across index
kinds, resident dtypes and the host/device search paths. Everything is
seeded and clocked on ``SimClock``, so the runs are exactly
reproducible.
"""

import numpy as np
import pytest

from repro.core import SemanticCache, SimClock
from repro.core.economics import ResidencyModel
from repro.core.hnsw import INVALID, quantize_rows
from repro.core.policy import CategoryConfig, PolicyEngine, paper_policies
from repro.core.shard import (CRC32Planner, CategoryMigration, ShardPlanner,
                              ShardedSemanticCache, crc32_shard)

DIM = 48


def _policies() -> PolicyEngine:
    return PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=25.0, quota=0.30,
                       priority=2.0),
        CategoryConfig("b", threshold=0.78, ttl=1e6, quota=0.30),
        CategoryConfig("c", threshold=0.75, ttl=1e6, quota=0.05,
                       priority=0.5),
        CategoryConfig("d", threshold=0.95, ttl=1.0, quota=0.0,
                       allow_caching=False),
    ])


def _banks(n_intents: int = 64) -> dict[str, np.ndarray]:
    """Deterministic per-category intent vectors (unit rows; at dim 48
    cross-intent cosines sit ~0.14 ± 0.14, far below every τ)."""
    banks = {}
    for k, cat in enumerate(("a", "b", "c", "d")):
        rng = np.random.default_rng(100 + k)
        v = rng.standard_normal((n_intents, DIM)).astype(np.float32)
        banks[cat] = v / np.linalg.norm(v, axis=1, keepdims=True)
    return banks


def _workload(rounds: int = 8) -> list[list[tuple[str, int]]]:
    """Per-round (category, intent) schedule: revisits (hits), fresh
    intents (misses → inserts), category "c" overflowing its 12-entry
    quota, and a compliance-blocked "d" query per round."""
    sched = []
    seen = {"a": 0, "b": 0, "c": 0}
    for r in range(rounds):
        batch: list[tuple[str, int]] = []
        for cat, new in (("a", 2), ("b", 2), ("c", 3)):
            for j in range(3):      # revisit earlier intents (if any)
                if seen[cat]:
                    batch.append((cat, (r + j) % seen[cat]))
            for j in range(new):    # fresh traffic
                batch.append((cat, seen[cat] + j))
            seen[cat] += new
        batch.append(("d", r))
        sched.append(batch)
    return sched


def _run(cache, banks, sched) -> list[tuple]:
    """Drive one cache through the schedule; returns the observable
    trace: (hit, reason-class, response) per query per round."""
    trace = []
    for r, batch in enumerate(sched):
        embs = np.stack([banks[c][i] for c, i in batch])
        cats = [c for c, _ in batch]
        results = cache.lookup_batch(embs, cats)
        for (c, i), res in zip(batch, results):
            trace.append((res.hit, res.reason, res.response))
        miss = [k for k, res in enumerate(results)
                if not res.hit and res.reason != "compliance"]
        if miss:
            cache.insert_batch(
                embs[miss], [cats[k] for k in miss],
                [f"q:{batch[k][0]}:{batch[k][1]}" for k in miss],
                [f"r:{batch[k][0]}:{batch[k][1]}" for k in miss])
        cache.clock.advance(10.0)
        if r % 3 == 2:
            cache.sweep_expired()
    return trace


@pytest.mark.parametrize("index_kind,emb_dtype,use_device", [
    ("flat", "float32", False),
    ("flat", "float32", True),
    ("flat", "int8", True),
    ("hnsw", "float32", False),
    ("hnsw", "float32", True),
    ("hnsw", "int8", True),
])
def test_sharded_matches_single_cache(index_kind, emb_dtype, use_device):
    """Property: over shard counts {1, 2, 4}, both index kinds and both
    resident dtypes, the sharded cache's hit/expired/miss classes and
    served documents are bit-identical to a single cache's on the same
    mixed-category workload (with TTL expiry, quota evictions and
    compliance rejects all exercised)."""
    banks = _banks()
    sched = _workload()
    kw = dict(dim=DIM, capacity=256, index_kind=index_kind,
              use_device=use_device, emb_dtype=emb_dtype, seed=0)
    baseline = _run(SemanticCache(_policies(), clock=SimClock(), **kw),
                    banks, sched)
    assert any(t[1] == "expired" for t in baseline)
    assert any(t[1] == "hit" for t in baseline)
    assert any(t[1] == "compliance" for t in baseline)
    for n in (1, 2, 4):
        sharded = ShardedSemanticCache(_policies(), n_shards=n,
                                       clock=SimClock(), **kw)
        trace = _run(sharded, banks, sched)
        assert trace == baseline, \
            f"n_shards={n} diverged from the single cache"
        if n > 1:   # the planner actually spread the categories
            homes = {sharded.shard_of(c) for c in ("a", "b", "c")}
            assert len(homes) > 1


def test_sharded_quota_ceiling_matches_global_capacity():
    """Quota math resolves against the GLOBAL capacity on every shard:
    category "c" (quota 0.05 → 12 of 256) caps at the same entry count
    under 1 and 4 shards."""
    banks = _banks()
    sched = _workload()
    counts = []
    for n in (1, 4):
        cache = ShardedSemanticCache(_policies(), dim=DIM, capacity=256,
                                     n_shards=n, clock=SimClock(),
                                     index_kind="flat")
        _run(cache, banks, sched)
        counts.append(cache.category_count("c"))
    assert counts[0] == counts[1] == 12


def test_global_slot_encoding_and_doc_ids():
    """Returned slots are globally encoded (shard · shard_capacity +
    local), doc ids are globally unique across shards, and doc_id_of
    decodes both."""
    cache = ShardedSemanticCache(_policies(), dim=DIM, capacity=64,
                                 n_shards=2, clock=SimClock(),
                                 index_kind="flat")
    banks = _banks()
    slots = cache.insert_batch(
        np.stack([banks["a"][0], banks["b"][0]]), ["a", "b"],
        ["qa", "qb"], ["ra", "rb"])
    shards = {cache.shard_of_slot(s)[0] for s in slots}
    assert shards == {0, 1}
    doc_ids = [cache.doc_id_of(s) for s in slots]
    assert len(set(doc_ids)) == 2
    assert {d % 2 for d in doc_ids} == {0, 1}   # strided id sequences
    res = cache.lookup_batch(np.stack([banks["a"][0], banks["b"][0]]),
                             ["a", "b"])
    assert [r.slot for r in res] == slots
    assert [r.doc_id for r in res] == doc_ids


def test_aggregated_stats_views():
    """sync_stats / last_lookup_stats / metrics merge across shards."""
    cache = ShardedSemanticCache(_policies(), dim=DIM, capacity=128,
                                 n_shards=2, clock=SimClock(),
                                 index_kind="flat", use_device=True)
    banks = _banks()
    embs = np.stack([banks["a"][0], banks["b"][0], banks["a"][1]])
    cats = ["a", "b", "a"]
    cache.insert_batch(embs, cats, ["q0", "q1", "q2"], ["r0", "r1", "r2"])
    res = cache.lookup_batch(embs, cats)
    assert all(r.hit for r in res)
    sync = cache.sync_stats
    assert len(sync["per_shard"]) == 2
    assert sync["bytes_synced"] == sum(s["bytes_synced"]
                                       for s in sync["per_shard"])
    assert sync["full_uploads"] >= 2            # one initial upload per shard
    ls = cache.last_lookup_stats
    assert ls["batch"] == 3
    assert set(ls["per_shard"]) == {0, 1}
    snap = cache.metrics.snapshot()
    assert snap["a"]["lookups"] == 2 and snap["b"]["lookups"] == 1
    assert cache.metrics.overall_hit_rate() == 1.0
    rep = cache.shard_report()
    assert sum(r["entries"] for r in rep) == len(cache) == 3
    assert all(r["resident_bytes"] > 0 for r in rep)


def _admission_policies() -> PolicyEngine:
    """_policies() with admission gating on "a"/"c" and distinct miss
    costs, so admit-on-2nd-touch skips and cost_aware scoring both fire."""
    return PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=25.0, quota=0.30,
                       priority=2.0, admit_after=2, expected_tllm_ms=800.0),
        CategoryConfig("b", threshold=0.78, ttl=1e6, quota=0.30,
                       expected_tllm_ms=200.0),
        CategoryConfig("c", threshold=0.75, ttl=1e6, quota=0.05,
                       priority=0.5, admit_after=2, expected_tllm_ms=500.0),
        CategoryConfig("d", threshold=0.95, ttl=1.0, quota=0.0,
                       allow_caching=False),
    ])


@pytest.mark.parametrize("index_kind,use_device", [
    ("flat", False),
    ("flat", True),
    ("hnsw", True),
])
def test_sharded_parity_with_admission_and_cost_aware_eviction(
        index_kind, use_device):
    """The parity contract survives the new control plane: with
    admit_after=2 on two categories AND cost_aware eviction scoring, the
    sharded cache still reproduces the single cache bit-for-bit over
    shard counts {1, 2, 4} — admission state is seeded from the category
    NAME (not the shard's seed+i), and both quota eviction and admission
    skips are shard-local decisions over identical per-category streams.
    """
    banks = _banks()
    sched = _workload(rounds=10)
    kw = dict(dim=DIM, capacity=256, index_kind=index_kind,
              use_device=use_device, eviction="cost_aware", seed=0)
    single = SemanticCache(_admission_policies(), clock=SimClock(), **kw)
    baseline = _run(single, banks, sched)
    snap = single.metrics.snapshot()
    base_skips = {c: s["admission_skips"] for c, s in snap.items()}
    assert base_skips["a"] > 0 and base_skips["c"] > 0   # the gate fired
    assert base_skips["b"] == 0                          # ungated category
    # gated intents that DO repeat still get admitted and then hit
    assert any(t[1] == "hit" for t in baseline)
    assert single.eviction == "cost_aware"
    for n in (1, 2, 4):
        sharded = ShardedSemanticCache(_admission_policies(), n_shards=n,
                                       clock=SimClock(), **kw)
        trace = _run(sharded, banks, sched)
        assert trace == baseline, \
            f"n_shards={n} diverged with admission + cost_aware enabled"
        ssnap = sharded.metrics.snapshot()
        assert {c: s["admission_skips"] for c, s in ssnap.items()} \
            == base_skips
        agg = sharded.last_insert_stats
        assert agg["admission_skips"] == sum(
            s.get("admission_skips", 0) for s in agg["per_shard"].values())


def test_migration_hands_admission_state_to_target():
    """After a live migration, the target shard continues the source's
    repetition counts: an intent one touch short of admission on the
    source is admitted by its FIRST post-cutover touch on the target."""
    pol = PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=1e6, quota=0.45,
                       admit_after=3),
        CategoryConfig("b", threshold=0.80, ttl=1e6, quota=0.45),
    ])
    planner = ShardPlanner(2, 256, policies=pol)
    planner.plan({"a": 0.45, "b": 0.45})
    cache = ShardedSemanticCache(pol, dim=DIM, capacity=256, n_shards=2,
                                 clock=SimClock(), index_kind="flat",
                                 planner=planner)
    banks = _banks()
    emb = banks["a"][:1]
    for _ in range(2):                      # two touches: still below k=3
        cache.insert_batch(emb, ["a"], ["q"], ["r"])
    assert cache.category_count("a") == 0
    src, dst = cache.shard_of("a"), 1 - cache.shard_of("a")
    assert cache.shards[src].admission.stats()["a"]["observations"] == 2
    cache.migrate_category("a", dst)
    assert cache.shard_of("a") == dst
    assert "a" not in cache.shards[src].admission.stats()   # detached
    assert cache.shards[dst].admission.stats()["a"]["observations"] == 2
    cache.insert_batch(emb, ["a"], ["q"], ["r"])   # 3rd touch, on target
    assert cache.category_count("a") == 1
    res = cache.lookup_batch(emb, ["a"])
    assert res[0].hit and res[0].response == "r"


# ---------------------------------------------------------------------------
# Planner placement.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
def test_planner_beats_crc32_on_paper_quotas(n_shards):
    """Quota-byte bin-packing spreads the Table-1 quota mass strictly
    better (max/mean shard bytes) than crc32-mod, which piles the head
    categories onto one shard."""
    pol = PolicyEngine(paper_policies())
    planner = ShardPlanner.from_policies(pol, n_shards, 100_000)
    crc_bytes = [0] * n_shards
    for name in pol.categories():
        crc_bytes[crc32_shard(name, n_shards)] += \
            planner.quota_bytes(pol.get(name).quota)
    crc_imbalance = max(crc_bytes) / (sum(crc_bytes) / n_shards)
    assert planner.imbalance() < crc_imbalance
    # LPT is bound below by the single heaviest category (code_generation
    # holds 0.40 of the quota mass — replication, not placement, would be
    # needed to split it; see ROADMAP open items), so the achievable
    # spread depends on the shard count.
    assert planner.imbalance() <= {2: 1.1, 4: 1.65}[n_shards]
    # deterministic: replanning produces the identical assignment
    again = ShardPlanner.from_policies(pol, n_shards, 100_000)
    assert again.assignments == planner.assignments


def test_planner_weights_follow_residency_dtype():
    """int8 residency shrinks every quota-byte weight (the embedding
    component ~4x; graph + metadata ride along unshrunk, so the whole
    entry lands ~2.8x at d=384) and preserves the relative packing."""
    fp32 = ResidencyModel(dim=384, emb_dtype="float32")
    int8 = ResidencyModel(dim=384, emb_dtype="int8")
    assert fp32.quota_bytes(0.4, 10_000) > 2.5 * int8.quota_bytes(0.4, 10_000)
    pol = PolicyEngine(paper_policies())
    a = ShardPlanner.from_policies(pol, 4, 50_000, emb_dtype="float32")
    b = ShardPlanner.from_policies(pol, 4, 50_000, emb_dtype="int8")
    assert a.assignments == b.assignments


def test_planner_unknown_category_and_assign():
    pol = _policies()
    planner = ShardPlanner.from_policies(pol, 2, 1000)
    s = planner.shard_of("never_seen")          # registers on first sight
    assert planner.shard_of("never_seen") == s
    planner.assign("a", 1 - planner.shard_of("a"))
    assert sum(planner.shard_bytes) == sum(planner._bytes.values())


def test_router_shard_for_uses_planner_with_hash_fallback():
    from repro.serving.router import ModelBackend, ModelRouter
    pol = PolicyEngine(paper_policies())
    backends = [ModelBackend("m", 100.0, 0.01)]
    routed = ModelRouter(pol, backends, n_cache_shards=2)
    assert routed.planner is not None
    heads = ("code_generation", "api_documentation")
    assert routed.shard_for(heads[0]) != routed.shard_for(heads[1])
    fallback = ModelRouter(PolicyEngine(paper_policies()), backends,
                           n_cache_shards=2, planner=False)
    assert fallback.planner is None
    for name in pol.categories():
        assert fallback.shard_for(name) == crc32_shard(name, 2)
    # crc32 collides the heads — the failure mode the planner removes
    assert fallback.shard_for(heads[0]) == fallback.shard_for(heads[1])


# ---------------------------------------------------------------------------
# Live category migration.
# ---------------------------------------------------------------------------

def _migration_cache(emb_dtype="float32", index_kind="flat",
                     use_device=False):
    pol = PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=500.0, quota=0.45,
                       priority=2.0),
        CategoryConfig("b", threshold=0.80, ttl=1e6, quota=0.45),
    ])
    planner = ShardPlanner(2, 256, residency=ResidencyModel(
        dim=DIM, emb_dtype=emb_dtype), policies=pol)
    planner.plan({"a": 0.45, "b": 0.45})        # a → shard 0, b → shard 1
    return ShardedSemanticCache(pol, dim=DIM, capacity=256, n_shards=2,
                                clock=SimClock(), index_kind=index_kind,
                                use_device=use_device, emb_dtype=emb_dtype,
                                planner=planner, seed=3)


@pytest.mark.parametrize("emb_dtype,index_kind,use_device", [
    ("float32", "flat", False),
    ("int8", "flat", True),
    ("float32", "hnsw", True),
    ("int8", "hnsw", True),
])
def test_live_migration_coherence(emb_dtype, index_kind, use_device):
    """Mid-migration reads stay correct (source serves until cutover),
    writes during the drain are caught up, and after cutover the target
    holds every entry exactly once — timestamps, hit counts and (under
    int8) the quantized rows preserved bit-identically."""
    cache = _migration_cache(emb_dtype, index_kind, use_device)
    banks = _banks()
    n0 = 30
    embs = banks["a"][:n0]
    cache.insert_batch(embs, ["a"] * n0,
                       [f"q{i}" for i in range(n0)],
                       [f"r{i}" for i in range(n0)])
    cache.insert_batch(banks["b"][:10], ["b"] * 10,
                       [f"bq{i}" for i in range(10)],
                       [f"br{i}" for i in range(10)])
    t_inserted = cache.shards[0].slot_inserted[
        cache.shards[0].category_slots("a")].copy()
    cache.clock.advance(5.0)

    src, dst = cache.shard_of("a"), cache.shard_of("b")
    assert src != dst
    mig = cache.migrate_category("a", dst, batch_size=7, stepwise=True)
    total_new = 0
    while mig.remaining() > 0:
        mig.step()
        # reads mid-drain: every entry (old and mid-drain-written) hits
        # with its own document, and "a" still routes to the source
        assert cache.shard_of("a") == src
        res = cache.lookup_batch(embs[:n0], ["a"] * n0)
        for i, r in enumerate(res):
            assert r.hit and r.response == f"r{i}"
        # writes DURING the drain — they land on the source (each one
        # re-fills the pending set) and must survive the cutover catch-up
        if total_new < 4:
            i = n0 + total_new
            cache.insert_batch(banks["a"][i][None, :], ["a"],
                               [f"q{i}"], [f"r{i}"])
            total_new += 1
    assert total_new == 4
    mig.cutover()

    n = n0 + total_new
    assert cache.shard_of("a") == dst
    assert cache.shards[src].category_count("a") == 0
    assert cache.shards[dst].category_count("a") == n   # no loss, no dupes
    res = cache.lookup_batch(banks["a"][:n], ["a"] * n)
    for i, r in enumerate(res):
        assert r.hit and r.response == f"r{i}"
    # b never moved and never flinched
    res_b = cache.lookup_batch(banks["b"][:10], ["b"] * 10)
    assert all(r.hit for r in res_b)

    # preserved state on the target: timestamps (ages), quantized rows
    dslots = cache.shards[dst].category_slots("a")
    migrated_ts = np.sort(cache.shards[dst].slot_inserted[dslots])[:n0]
    assert np.array_equal(migrated_ts, np.sort(t_inserted))
    if emb_dtype == "int8":
        idx = cache.shards[dst].index
        q, s = quantize_rows(idx.emb[dslots])
        assert np.array_equal(idx.emb_q[dslots], q)
        assert np.array_equal(idx.emb_scale[dslots], s)
    # preserved timestamps keep TTL semantics: the originals expire on
    # the TARGET exactly when they would have on the source
    cache.clock.advance(500.0)
    res = cache.lookup_batch(embs[:5], ["a"] * 5)
    assert all(r.reason == "expired" for r in res)


def test_migration_reconciles_source_evictions_and_hits():
    """Entries evicted from the source AFTER being copied do not
    resurrect at cutover, and hits served during the drain transfer."""
    cache = _migration_cache()
    banks = _banks()
    cache.insert_batch(banks["a"][:12], ["a"] * 12,
                       [f"q{i}" for i in range(12)],
                       [f"r{i}" for i in range(12)])
    src, dst = cache.shard_of("a"), 1 - cache.shard_of("a")
    mig = cache.migrate_category("a", dst, batch_size=12, stepwise=True)
    assert mig.step() == 12                     # everything copied
    # source-side eviction after the copy (TTL) + hits during the drain
    s0 = cache.shards[src]
    victims = s0.category_slots("a")[:3]
    victim_docs = {f"r{int(np.argmax(banks['a'][:12] @ s0.index.emb[v]))}"
                   for v in victims}
    for v in victims:
        s0._evict_slot(int(v), reason="ttl")
    cache.lookup_batch(banks["a"][3:8], ["a"] * 5)   # hits accrue on src
    mig.cutover()
    assert cache.shards[dst].category_count("a") == 9
    res = cache.lookup_batch(banks["a"][:12], ["a"] * 12)
    served = {r.response for r in res if r.hit}
    assert len(served) == 9 and served.isdisjoint(victim_docs)
    # drain-time hits carried over to the target's eviction scoring
    hit_slots = cache.shards[dst].category_slots("a")
    assert cache.shards[dst].slot_hits[hit_slots].sum() >= 5


def test_rebalance_follows_quota_reassignment():
    """Quota changes re-plan placement and live-migrate the movers —
    the AdaptiveController-shaped trigger."""
    pol = PolicyEngine([
        CategoryConfig("big", threshold=0.80, ttl=1e6, quota=0.40),
        CategoryConfig("mid", threshold=0.80, ttl=1e6, quota=0.30),
        CategoryConfig("small", threshold=0.80, ttl=1e6, quota=0.10),
    ])
    cache = ShardedSemanticCache(pol, dim=DIM, capacity=256, n_shards=2,
                                 clock=SimClock(), index_kind="flat")
    banks = _banks()
    # seed entries for every category (reuse bank "a" vectors, distinct
    # intents per category so embeddings never collide across them)
    names = ["big", "mid", "small"]
    for k, name in enumerate(names):
        vecs = banks["a"][10 * k:10 * k + 8]
        cache.insert_batch(vecs, [name] * 8,
                           [f"{name}q{i}" for i in range(8)],
                           [f"{name}r{i}" for i in range(8)])
    before = {n: cache.shard_of(n) for n in names}
    # invert the economics: "small" becomes the heavy category
    pol.update("big", quota=0.05)
    pol.update("small", quota=0.45)
    moves = cache.rebalance()
    assert moves, "rebalance made no moves despite inverted quotas"
    for name, (s, d) in moves.items():
        assert before[name] == s and cache.shard_of(name) == d
    for k, name in enumerate(names):
        vecs = banks["a"][10 * k:10 * k + 8]
        res = cache.lookup_batch(vecs, [name] * 8)
        assert all(r.hit for r in res), f"{name} lost entries in rebalance"


def test_migration_guards():
    cache = _migration_cache()
    assert cache.migrate_category("a", cache.shard_of("a")) is None
    assert cache.migrate_category("a", 99) is None
    mig = cache.migrate_category("a", 1, stepwise=True)
    with pytest.raises(RuntimeError):
        cache.migrate_category("a", 1)
    mig.cutover()
    assert "a" not in cache._migrations
    assert isinstance(mig, CategoryMigration)


def test_doc_id_of_invalid_slot():
    """INVALID slots decode to INVALID on both cache types — never to a
    real shard/slot via numpy negative indexing."""
    single = SemanticCache(_policies(), dim=DIM, capacity=8,
                           clock=SimClock(), index_kind="flat")
    banks = _banks()
    single.insert_batch(banks["a"][:8], ["a"] * 8,
                        [f"q{i}" for i in range(8)],
                        [f"r{i}" for i in range(8)])     # fill every slot
    assert single.doc_id_of(INVALID) == INVALID
    sharded = ShardedSemanticCache(_policies(), dim=DIM, capacity=8,
                                   n_shards=2, clock=SimClock(),
                                   index_kind="flat")
    sharded.insert_batch(banks["a"][:4], ["a"] * 4,
                         [f"q{i}" for i in range(4)],
                         [f"r{i}" for i in range(4)])
    assert sharded.shard_of_slot(INVALID) == (INVALID, INVALID)
    assert sharded.doc_id_of(INVALID) == INVALID


def test_migration_into_full_target_aborts_cleanly():
    """A drain step that finds the target physically full aborts the
    migration atomically: no target copies survive, the source keeps
    serving, and the move is retryable (not stuck in _migrations)."""
    pol = PolicyEngine([
        CategoryConfig("a", threshold=0.80, ttl=1e6, quota=0.45),
        CategoryConfig("b", threshold=0.80, ttl=1e6, quota=0.45),
    ])
    planner = ShardPlanner(2, 40, policies=pol)
    planner.plan({"a": 0.45, "b": 0.45})
    cache = ShardedSemanticCache(pol, dim=DIM, capacity=40, n_shards=2,
                                 clock=SimClock(), index_kind="flat",
                                 planner=planner, shard_capacity=12)
    banks = _banks()
    cache.insert_batch(banks["a"][:10], ["a"] * 10,
                       [f"q{i}" for i in range(10)],
                       [f"r{i}" for i in range(10)])
    cache.insert_batch(banks["b"][:10], ["b"] * 10,
                       [f"bq{i}" for i in range(10)],
                       [f"br{i}" for i in range(10)])    # target nearly full
    with pytest.raises(RuntimeError, match="free"):
        cache.migrate_category("a", cache.shard_of("b"), batch_size=5)
    assert "a" not in cache._migrations                  # retryable
    assert cache.shards[cache.shard_of("b")].category_count("a") == 0
    res = cache.lookup_batch(banks["a"][:10], ["a"] * 10)
    assert all(r.hit for r in res)                       # source untouched
    with pytest.raises(RuntimeError, match="free"):      # retry, same error
        cache.migrate_category("a", cache.shard_of("b"))


def test_rebalance_requires_shard_planner():
    cache = ShardedSemanticCache(_policies(), dim=DIM, capacity=64,
                                 n_shards=2, clock=SimClock(),
                                 index_kind="flat", planner=CRC32Planner(2))
    with pytest.raises(TypeError, match="ShardPlanner"):
        cache.rebalance()


def test_crc32_planner_is_the_hash():
    p = CRC32Planner(4)
    assert p.shard_of("code_generation") == crc32_shard("code_generation", 4)
    p.assign("code_generation", 2)
    assert p.shard_of("code_generation") == 2
