"""Per-kernel interpret-mode validation against the pure-jnp oracles.

Every kernel sweeps shapes + dtypes and must allclose its ref.py oracle.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flat_topk import flat_topk
from repro.kernels.frontier_hop import TOMBSTONE, frontier_hop
from repro.kernels.gather_scores import gather_scores, gather_scores_masked
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.scatter_update import scatter_rows


def _unit_rows(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _quantize(rows):
    """Per-row symmetric int8 — the PRODUCTION quantizer, so kernel
    parity always tests the actual resident-tier layout."""
    from repro.core.hnsw import quantize_rows
    return quantize_rows(rows)


# ---------------------------------------------------------------- flat_topk
@pytest.mark.parametrize("N,d,B,block", [
    (1024, 384, 8, 256), (2048, 128, 16, 512), (512, 256, 8, 512),
])
def test_flat_topk_matches_ref(rng, N, d, B, block):
    table = _unit_rows(rng, N, d)
    valid = rng.random(N) > 0.2
    q = _unit_rows(rng, B, d)
    s, i = flat_topk(jnp.asarray(table), jnp.asarray(valid), jnp.asarray(q),
                     block_n=block, interpret=True)
    rs, ri = ref.flat_topk_ref(jnp.asarray(table), jnp.asarray(valid),
                               jnp.asarray(q))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ri))


def test_cache_topk_wrapper_pads_arbitrary_shapes(rng):
    # N=1000 (not a tile multiple), B=5, d=384
    table = _unit_rows(rng, 1000, 384)
    valid = np.ones(1000, bool)
    q = _unit_rows(rng, 5, 384)
    s, i = ops.cache_topk(jnp.asarray(table), jnp.asarray(valid),
                          jnp.asarray(q), block_n=256, interpret=True)
    rs, ri = ref.flat_topk_ref(jnp.asarray(table), jnp.asarray(valid),
                               jnp.asarray(q))
    assert s.shape == (5,)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ri))


@pytest.mark.parametrize("N,d,B,block", [(1024, 384, 8, 256), (512, 128, 8, 128)])
def test_flat_topk_category_mask_matches_ref(rng, N, d, B, block):
    """§5.3: rows from another category are masked exactly like invalid
    rows; query category −1 is a wildcard (category-blind scan)."""
    table = _unit_rows(rng, N, d)
    valid = rng.random(N) > 0.2
    cats = rng.integers(0, 4, N).astype(np.int32)
    q = _unit_rows(rng, B, d)
    qc = rng.integers(-1, 4, B).astype(np.int32)
    s, i = flat_topk(jnp.asarray(table), jnp.asarray(valid), jnp.asarray(q),
                     jnp.asarray(cats), jnp.asarray(qc),
                     block_n=block, interpret=True)
    rs, ri = ref.flat_topk_masked_ref(jnp.asarray(table), jnp.asarray(valid),
                                      jnp.asarray(q), jnp.asarray(cats),
                                      jnp.asarray(qc))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ri))
    # results honor the mask
    for b in range(B):
        if qc[b] >= 0 and i[b] >= 0:
            assert cats[int(i[b])] == qc[b]


def test_category_args_must_travel_together(rng):
    """Exactly one of (categories, query_categories) is a ValueError —
    silently dropping the mask would bypass category isolation."""
    table = jnp.asarray(_unit_rows(rng, 256, 128))
    valid = jnp.ones(256, bool)
    q = jnp.asarray(_unit_rows(rng, 8, 128))
    qc = jnp.zeros(8, jnp.int32)
    cats = jnp.zeros(256, jnp.int32)
    idx = jnp.zeros((8, 4), jnp.int32)
    with pytest.raises(ValueError):
        flat_topk(table, valid, q, None, qc, block_n=64, interpret=True)
    with pytest.raises(ValueError):
        ops.cache_topk(table, valid, q, cats, None, interpret=True)
    with pytest.raises(ValueError):
        ops.hop_scores(table, idx, q, None, qc, interpret=True)


def test_cache_topk_masked_wrapper_pads_arbitrary_shapes(rng):
    table = _unit_rows(rng, 1000, 384)
    valid = np.ones(1000, bool)
    cats = (np.arange(1000) % 3).astype(np.int32)
    q = _unit_rows(rng, 5, 384)
    qc = np.array([0, 1, 2, -1, 0], np.int32)
    s, i = ops.cache_topk(jnp.asarray(table), jnp.asarray(valid),
                          jnp.asarray(q), jnp.asarray(cats), jnp.asarray(qc),
                          block_n=256, interpret=True)
    rs, ri = ref.flat_topk_masked_ref(jnp.asarray(table), jnp.asarray(valid),
                                      jnp.asarray(q), jnp.asarray(cats),
                                      jnp.asarray(qc))
    assert s.shape == (5,)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ri))


# ----------------------------------------------------- quantized flat_topk
@pytest.mark.parametrize("N,d,B,block", [(1024, 384, 8, 256),
                                         (512, 128, 8, 128)])
def test_flat_topk_quantized_matches_ref(rng, N, d, B, block):
    """int8 residency: the kernel's fused dequant (int8 tile × fp32 query,
    score × per-row scale AFTER the dot) must equal the oracle scoring
    the dequantized fp32 table — including the category mask and
    tombstoned rows."""
    table = _unit_rows(rng, N, d)
    tq, ts = _quantize(table)
    valid = rng.random(N) > 0.2
    cats = rng.integers(0, 4, N).astype(np.int32)
    q = _unit_rows(rng, B, d)
    qc = rng.integers(-1, 4, B).astype(np.int32)
    s, i = flat_topk(jnp.asarray(tq), jnp.asarray(valid), jnp.asarray(q),
                     jnp.asarray(cats), jnp.asarray(qc), jnp.asarray(ts),
                     block_n=block, interpret=True)
    rs, ri = ref.flat_topk_masked_ref(jnp.asarray(tq), jnp.asarray(valid),
                                      jnp.asarray(q), jnp.asarray(cats),
                                      jnp.asarray(qc), jnp.asarray(ts))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ri))
    # ...and the dequantized scores sit within int8 error of exact fp32
    es, _ = ref.flat_topk_masked_ref(jnp.asarray(table), jnp.asarray(valid),
                                     jnp.asarray(q), jnp.asarray(cats),
                                     jnp.asarray(qc))
    finite = np.isfinite(np.asarray(es))
    np.testing.assert_allclose(np.asarray(s)[finite], np.asarray(es)[finite],
                               atol=5e-3)


def test_cache_topk_quantized_wrapper_pads_arbitrary_shapes(rng):
    """ops.cache_topk with scales: padding rows get scale 0 and must never
    win (N=1000 not a tile multiple, B=5)."""
    table = _unit_rows(rng, 1000, 384)
    tq, ts = _quantize(table)
    valid = np.ones(1000, bool)
    cats = (np.arange(1000) % 3).astype(np.int32)
    q = _unit_rows(rng, 5, 384)
    qc = np.array([0, 1, 2, -1, 0], np.int32)
    s, i = ops.cache_topk(jnp.asarray(tq), jnp.asarray(valid),
                          jnp.asarray(q), jnp.asarray(cats),
                          jnp.asarray(qc), jnp.asarray(ts),
                          block_n=256, interpret=True)
    rs, ri = ref.flat_topk_masked_ref(jnp.asarray(tq), jnp.asarray(valid),
                                      jnp.asarray(q), jnp.asarray(cats),
                                      jnp.asarray(qc), jnp.asarray(ts))
    assert s.shape == (5,)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ri))


# ------------------------------------------------------------ gather_scores
@pytest.mark.parametrize("N,d,B,K", [(256, 128, 4, 8), (512, 384, 2, 16)])
def test_gather_scores_matches_ref(rng, N, d, B, K):
    table = rng.standard_normal((N, d)).astype(np.float32)
    idx = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    q = rng.standard_normal((B, d)).astype(np.float32)
    out = gather_scores(jnp.asarray(table), jnp.asarray(idx), jnp.asarray(q),
                        interpret=True)
    want = ref.gather_scores_ref(jnp.asarray(table), jnp.asarray(idx),
                                 jnp.asarray(q))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,d,B,K", [(256, 128, 4, 8), (512, 384, 2, 16)])
def test_gather_scores_masked_matches_ref(rng, N, d, B, K):
    """§5.3 fused hop mask: cross-category candidates and padding both
    score -inf; query category −1 is a wildcard."""
    table = rng.standard_normal((N, d)).astype(np.float32)
    idx = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    q = rng.standard_normal((B, d)).astype(np.float32)
    cats = rng.integers(0, 3, N).astype(np.int32)
    qc = rng.integers(-1, 3, B).astype(np.int32)
    out = gather_scores_masked(jnp.asarray(table), jnp.asarray(idx),
                               jnp.asarray(q), jnp.asarray(cats),
                               jnp.asarray(qc), interpret=True)
    want = ref.gather_scores_masked_ref(jnp.asarray(table), jnp.asarray(idx),
                                        jnp.asarray(q), jnp.asarray(cats),
                                        jnp.asarray(qc))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # cross-category positions really are -inf
    out = np.asarray(out)
    for b in range(B):
        if qc[b] < 0:
            continue
        wrong = (idx[b] >= 0) & (cats[np.maximum(idx[b], 0)] != qc[b])
        assert np.all(np.isneginf(out[b][wrong]))


def test_hop_scores_dispatches_masked(rng):
    """ops.hop_scores with categories must equal the masked oracle (and
    the unmasked call must stay unchanged)."""
    N, d, B, K = 256, 384, 4, 16
    table = rng.standard_normal((N, d)).astype(np.float32)
    idx = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    q = rng.standard_normal((B, d)).astype(np.float32)
    cats = rng.integers(0, 3, N).astype(np.int32)
    qc = np.array([0, 1, 2, -1], np.int32)
    out = ops.hop_scores(jnp.asarray(table), jnp.asarray(idx), jnp.asarray(q),
                         jnp.asarray(cats), jnp.asarray(qc), interpret=True)
    want = ref.gather_scores_masked_ref(jnp.asarray(table), jnp.asarray(idx),
                                        jnp.asarray(q), jnp.asarray(cats),
                                        jnp.asarray(qc))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------- quantized gather_scores
@pytest.mark.parametrize("N,d,B,K", [(256, 128, 4, 8), (512, 384, 2, 16)])
def test_gather_scores_quantized_matches_ref(rng, N, d, B, K):
    """int8 residency: the per-candidate scale DMA + in-kernel dequant
    must equal the oracle, masked and unmasked, with -1 padding."""
    table = _unit_rows(rng, N, d)
    tq, ts = _quantize(table)
    idx = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    q = rng.standard_normal((B, d)).astype(np.float32)
    cats = rng.integers(0, 3, N).astype(np.int32)
    qc = rng.integers(-1, 3, B).astype(np.int32)
    out = gather_scores(jnp.asarray(tq), jnp.asarray(idx), jnp.asarray(q),
                        jnp.asarray(ts), interpret=True)
    want = ref.gather_scores_ref(jnp.asarray(tq), jnp.asarray(idx),
                                 jnp.asarray(q), jnp.asarray(ts))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    outm = gather_scores_masked(jnp.asarray(tq), jnp.asarray(idx),
                                jnp.asarray(q), jnp.asarray(cats),
                                jnp.asarray(qc), jnp.asarray(ts),
                                interpret=True)
    wantm = ref.gather_scores_masked_ref(jnp.asarray(tq), jnp.asarray(idx),
                                         jnp.asarray(q), jnp.asarray(cats),
                                         jnp.asarray(qc), jnp.asarray(ts))
    np.testing.assert_allclose(np.asarray(outm), np.asarray(wantm),
                               rtol=1e-4, atol=1e-4)


def test_hop_scores_quantized_dispatch(rng):
    """ops.hop_scores with scales equals the quantized oracle and sits
    within int8 error of the exact fp32 scores."""
    N, d, B, K = 256, 384, 4, 16
    table = _unit_rows(rng, N, d)
    tq, ts = _quantize(table)
    idx = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    q = _unit_rows(rng, B, d)
    out = ops.hop_scores(jnp.asarray(tq), jnp.asarray(idx), jnp.asarray(q),
                         scales=jnp.asarray(ts), interpret=True)
    want = ref.gather_scores_ref(jnp.asarray(tq), jnp.asarray(idx),
                                 jnp.asarray(q), jnp.asarray(ts))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    exact = ref.gather_scores_ref(jnp.asarray(table), jnp.asarray(idx),
                                  jnp.asarray(q))
    finite = idx >= 0
    np.testing.assert_allclose(np.asarray(out)[finite],
                               np.asarray(exact)[finite], atol=5e-3)


# ------------------------------------------------------------ frontier_hop
def _hop_inputs(rng, N, d, B, F, M):
    emb = rng.standard_normal((N, d)).astype(np.float32)
    nbrs = rng.integers(-1, N, size=(N, M)).astype(np.int32)
    valid = rng.random(N) > 0.3
    cats = rng.integers(0, 3, N).astype(np.int32)
    meta = np.where(valid, cats, TOMBSTONE).astype(np.int32)
    frontier = rng.integers(-1, N, size=(B, F)).astype(np.int32)
    q = rng.standard_normal((B, d)).astype(np.float32)
    qc = rng.integers(-1, 3, B).astype(np.int32)       # includes wildcards
    done = (rng.random(B) > 0.6).astype(np.int32)
    return emb, nbrs, meta, frontier, q, qc, done


@pytest.mark.parametrize("N,d,B,F,M", [(64, 128, 3, 4, 8),
                                       (128, 256, 2, 3, 16)])
def test_frontier_hop_matches_ref(rng, N, d, B, F, M):
    """The fused hop (in-kernel neighbor fetch + embedding DMA + masked
    dot) must agree with the jnp oracle on ids, routing scores and
    result-masked scores, across tombstones, wildcard queries and done
    (frozen) queries."""
    args = tuple(map(jnp.asarray, _hop_inputs(rng, N, d, B, F, M)))
    ids, route, res = frontier_hop(*args, interpret=True)
    ri, rr, rs = ref.frontier_hop_ref(*args)
    assert np.array_equal(np.asarray(ids), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(route), np.asarray(rr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res), np.asarray(rs),
                               rtol=1e-4, atol=1e-4)


def test_frontier_hop_done_query_is_fully_dead(rng):
    """The freeze contract: a done query's lanes emit INVALID ids and -inf
    scores for EVERY candidate — those lanes issue no gather DMAs, so the
    rows-gathered counter (which counts id != INVALID) sees zero."""
    emb, nbrs, meta, frontier, q, qc, _ = _hop_inputs(rng, 64, 128, 4, 4, 8)
    frontier = np.abs(frontier)                       # all lanes routable
    done = np.array([1, 0, 1, 0], np.int32)
    ids, route, res = frontier_hop(*map(jnp.asarray, (
        emb, nbrs, meta, frontier, q, qc, done)), interpret=True)
    ids, route, res = map(np.asarray, (ids, route, res))
    for b in range(4):
        if done[b]:
            assert (ids[b] == -1).all()
            assert np.isneginf(route[b]).all() and np.isneginf(res[b]).all()
        else:
            assert (ids[b] >= 0).any()


def test_ops_frontier_hop_dispatch_agrees(rng):
    """ops.frontier_hop: the kernel path and the jnp reference path must
    be interchangeable (same dispatch contract as scatter_rows)."""
    args = tuple(map(jnp.asarray, _hop_inputs(rng, 64, 128, 3, 4, 8)))
    out_k = ops.frontier_hop(*args, impl="pallas", interpret=True)
    out_r = ops.frontier_hop(*args, impl="ref")
    assert np.array_equal(np.asarray(out_k[0]), np.asarray(out_r[0]))
    for a, b in zip(out_k[1:], out_r[1:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,d,B,F,M", [(64, 128, 3, 4, 8),
                                       (128, 256, 2, 3, 16)])
def test_frontier_hop_quantized_matches_ref(rng, N, d, B, F, M):
    """int8 residency: the fused hop's per-candidate int8-row + scale-word
    DMAs and in-kernel dequant must agree with the jnp oracle across
    tombstones, wildcards and done queries, and sit within int8 error of
    the fp32 scores on live lanes."""
    emb, nbrs, meta, frontier, q, qc, done = _hop_inputs(rng, N, d, B, F, M)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    eq, es = _quantize(emb)
    argsq = tuple(map(jnp.asarray, (eq, nbrs, meta, frontier, q, qc, done,
                                    es)))
    ids, route, res = frontier_hop(*argsq, interpret=True)
    ri, rr, rs = ref.frontier_hop_ref(*argsq)
    assert np.array_equal(np.asarray(ids), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(route), np.asarray(rr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res), np.asarray(rs),
                               rtol=1e-4, atol=1e-4)
    # dispatch parity (kernel vs ref), quantized
    out_k = ops.frontier_hop(*argsq, impl="pallas", interpret=True)
    out_r = ops.frontier_hop(*argsq, impl="ref")
    assert np.array_equal(np.asarray(out_k[0]), np.asarray(out_r[0]))
    for a, b in zip(out_k[1:], out_r[1:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    # quantization error bound vs the exact fp32 hop
    _, er, _ = ref.frontier_hop_ref(*map(jnp.asarray, (
        emb, nbrs, meta, frontier, q, qc, done)))
    live = np.asarray(ids) >= 0
    np.testing.assert_allclose(np.asarray(route)[live],
                               np.asarray(er)[live], atol=2e-2)


# ---------------------------------------------------------- scatter_update
@pytest.mark.parametrize("N,d,R", [(64, 128, 8), (256, 384, 32),
                                   (128, 32, 5)])
def test_scatter_rows_matches_ref(rng, N, d, R):
    """Delta flush: scattered rows take the staged values, every untouched
    row stays bit-identical (the aliased table is never re-materialized)."""
    table = rng.standard_normal((N, d)).astype(np.float32)
    rows = rng.choice(N, R, replace=False).astype(np.int32)
    vals = rng.standard_normal((R, d)).astype(np.float32)
    out = scatter_rows(jnp.asarray(table), jnp.asarray(rows),
                       jnp.asarray(vals), interpret=True)
    want = ref.scatter_rows_ref(jnp.asarray(table), jnp.asarray(rows),
                                jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    untouched = np.setdiff1d(np.arange(N), rows)
    np.testing.assert_array_equal(np.asarray(out)[untouched],
                                  table[untouched])


def test_scatter_rows_duplicate_ids_identical_payload(rng):
    """The bucketing contract: padded delta rows repeat a (row, val) pair,
    which must be a deterministic no-op."""
    table = rng.standard_normal((32, 128)).astype(np.float32)
    vals = rng.standard_normal((2, 128)).astype(np.float32)
    rows = np.array([7, 7, 7, 3], np.int32)
    vals4 = np.stack([vals[0], vals[0], vals[0], vals[1]])
    out = scatter_rows(jnp.asarray(table), jnp.asarray(rows),
                       jnp.asarray(vals4), interpret=True)
    np.testing.assert_array_equal(np.asarray(out)[7], vals[0])
    np.testing.assert_array_equal(np.asarray(out)[3], vals[1])


def test_ops_scatter_rows_1d_and_int_tables(rng):
    """The ops wrapper routes 1-D flag tables (valid/category) through a
    column view and preserves dtype — both backends give the ref result."""
    for dtype in (np.int32, np.bool_):
        table = (rng.random(64) > 0.5).astype(dtype)
        rows = np.array([3, 9, 40], np.int32)
        vals = (rng.random(3) > 0.5).astype(dtype)
        out = ops.scatter_rows(jnp.asarray(table), jnp.asarray(rows),
                               jnp.asarray(vals))
        want = np.asarray(table).copy()
        want[rows] = vals
        assert out.dtype == table.dtype
        np.testing.assert_array_equal(np.asarray(out), want)


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=False),
    dict(causal=True, window=96), dict(causal=True, softcap=30.0),
    dict(causal=True, kv_offset=64),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(rng, kwargs, dtype):
    B, Hq, Hkv, Sq, Skv, dh = 2, 4, 2, 128, 192, 64
    if kwargs.get("kv_offset"):
        Skv = Sq + kwargs["kv_offset"]
    q = (rng.standard_normal((B, Hq, Sq, dh)) * 0.3).astype(dtype)
    k = (rng.standard_normal((B, Hkv, Skv, dh)) * 0.3).astype(dtype)
    v = (rng.standard_normal((B, Hkv, Skv, dh)) * 0.3).astype(dtype)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          block_q=64, block_k=64, interpret=True, **kwargs)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             **kwargs)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


# -------------------------------------------------------- decode_attention
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_decode_attention_matches_ref(rng, softcap):
    B, Hq, Hkv, S, dh = 3, 4, 2, 256, 64
    q = (rng.standard_normal((B, Hq, dh)) * 0.3).astype(np.float32)
    k = (rng.standard_normal((B, Hkv, S, dh)) * 0.3).astype(np.float32)
    v = (rng.standard_normal((B, Hkv, S, dh)) * 0.3).astype(np.float32)
    lens = np.array([256, 100, 7], np.int32)
    out = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           jnp.asarray(lens), softcap=softcap, block_k=64,
                           interpret=True)
    want = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), kv_len=jnp.asarray(lens),
                                    softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_decode_attention_ragged_skips_are_exact(rng):
    """Tiles past kv_len are skipped — result must STILL be exact."""
    B, Hq, Hkv, S, dh = 2, 2, 2, 512, 32
    q = rng.standard_normal((B, Hq, dh)).astype(np.float32)
    k = rng.standard_normal((B, Hkv, S, dh)).astype(np.float32)
    v = rng.standard_normal((B, Hkv, S, dh)).astype(np.float32)
    lens = np.array([3, 65], np.int32)
    out = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           jnp.asarray(lens), block_k=64, interpret=True)
    want = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), kv_len=jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


# -------------------------------------------------------------- mamba_scan
@pytest.mark.parametrize("Bt,L,Dm,N,bd,bl", [
    (2, 128, 64, 16, 32, 32), (1, 64, 128, 8, 64, 64), (2, 96, 32, 16, 32, 32),
])
def test_mamba_scan_matches_ref(rng, Bt, L, Dm, N, bd, bl):
    x = (rng.standard_normal((Bt, L, Dm)) * 0.5).astype(np.float32)
    dt = np.abs(rng.standard_normal((Bt, L, Dm))).astype(np.float32) * 0.1
    A = -np.abs(rng.standard_normal((Dm, N))).astype(np.float32)
    B = (rng.standard_normal((Bt, L, N)) * 0.5).astype(np.float32)
    C = (rng.standard_normal((Bt, L, N)) * 0.5).astype(np.float32)
    D = rng.standard_normal((Dm,)).astype(np.float32)
    args = tuple(map(jnp.asarray, (x, dt, A, B, C, D)))
    y, h = mamba_scan(*args, block_d=bd, block_l=bl, interpret=True)
    yr, hr = ref.mamba_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_mamba_scan_state_carries_across_chunks(rng):
    """Same input, different chunking → identical output (state carry)."""
    Bt, L, Dm, N = 1, 128, 32, 8
    x = (rng.standard_normal((Bt, L, Dm)) * 0.5).astype(np.float32)
    dt = np.abs(rng.standard_normal((Bt, L, Dm))).astype(np.float32) * 0.1
    A = -np.abs(rng.standard_normal((Dm, N))).astype(np.float32)
    B = (rng.standard_normal((Bt, L, N)) * 0.5).astype(np.float32)
    C = (rng.standard_normal((Bt, L, N)) * 0.5).astype(np.float32)
    D = rng.standard_normal((Dm,)).astype(np.float32)
    args = tuple(map(jnp.asarray, (x, dt, A, B, C, D)))
    y1, h1 = mamba_scan(*args, block_d=32, block_l=16, interpret=True)
    y2, h2 = mamba_scan(*args, block_d=32, block_l=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
