"""Per-architecture smoke tests (deliverable f): every assigned arch at
reduced scale — one forward/train step on CPU, shape + finiteness checks,
and decode-vs-prefill consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, runnable_cells, \
    skipped_cells
from repro.models import Model
from repro.models.model import padded_vocab
from repro.models.transformer import layer_groups


def _batch_for(cfg, rng, B=2, S=32):
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["audio"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_ctx, cfg.enc_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch_for(cfg, rng)
    loss, met = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert float(met["tokens"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_serve_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 32
    batch = _batch_for(cfg, rng, B, S)
    del batch["labels"]
    logits, cache, kv_len = model.prefill(params, batch, S + 4)
    vp = padded_vocab(cfg.vocab_size)
    assert logits.shape == (B, vp)
    assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size])))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    logits2, cache2, kv2 = model.decode_step(params, cache, tok, kv_len)
    assert logits2.shape == (B, vp)
    assert bool(jnp.all(jnp.isfinite(logits2[:, :cfg.vocab_size])))
    assert int(kv2[0]) == int(kv_len[0]) + 1


@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma2_2b",
                                  "jamba_v0_1_52b", "falcon_mamba_7b",
                                  "whisper_large_v3"])
def test_decode_matches_prefill(arch, rng):
    """Incremental decode of token S−1 == full prefill of S tokens."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.key(1))
    B, S = 2, 24
    toks = rng.integers(1, cfg.vocab_size, (B, S))
    full = _batch_for(cfg, rng, B, S)
    full["tokens"] = jnp.asarray(toks)
    pre = dict(full)
    pre["tokens"] = jnp.asarray(toks[:, :S - 1])
    for b in (full, pre):
        b.pop("labels", None)
    lf, _, _ = model.prefill(params, full, S + 4)
    lp, cache, kvl = model.prefill(params, pre, S + 4)
    ld, _, _ = model.decode_step(params, cache,
                                 jnp.asarray(toks[:, S - 1]), kvl)
    V = cfg.vocab_size
    np.testing.assert_allclose(np.asarray(lf[:, :V]), np.asarray(ld[:, :V]),
                               atol=5e-2)  # bf16 path


def test_layer_groups_patterns():
    """Scan-group factorization matches each family's structure."""
    g, n = layer_groups(get_config("gemma2_2b"))
    assert len(g) == 2 and n == 13
    assert g[0].window == 4096 and g[1].window is None
    g, n = layer_groups(get_config("jamba_v0_1_52b"))
    assert len(g) == 8 and n == 4
    assert [s.kind for s in g].count("attn") == 1
    assert g[4].kind == "attn"
    assert [s.mlp for s in g] == ["dense", "moe"] * 4
    g, n = layer_groups(get_config("falcon_mamba_7b"))
    assert len(g) == 1 and n == 64 and g[0].kind == "mamba"
    g, n = layer_groups(get_config("deepseek_67b"))
    assert len(g) == 1 and n == 95


def test_param_counts_plausible():
    """Analytic N close to the marketed sizes (drives MODEL_FLOPS)."""
    expect = {
        "gemma2_2b": (2.0e9, 3.5e9),       # incl. 256k vocab embeddings
        "deepseek_67b": (60e9, 72e9),
        "llama3_2_3b": (2.8e9, 4.0e9),
        "granite_8b": (7.5e9, 9.0e9),
        "kimi_k2_1t_a32b": (0.9e12, 1.15e12),
        "jamba_v0_1_52b": (45e9, 58e9),
        "llava_next_mistral_7b": (6.5e9, 8.0e9),
        "falcon_mamba_7b": (6.5e9, 8.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.2e},{hi:.2e}]"
    # kimi active ≈ 32 B
    a = get_config("kimi_k2_1t_a32b").active_param_count()
    assert 25e9 <= a <= 45e9


def test_cell_accounting():
    """40 nominal cells = 32 runnable + 8 documented skips."""
    run = runnable_cells()
    skip = skipped_cells()
    assert len(run) == 32
    assert len(skip) == 8
    assert all(s[1] == "long_500k" for s in skip)
    assert {a for a, s in run if s == "long_500k"} == \
        {"jamba_v0_1_52b", "falcon_mamba_7b"}
    assert len(run) + len(skip) == len(ARCH_IDS) * len(SHAPES)
