"""End-to-end simulator behavior: Table 1 bands, baseline comparisons,
adaptive load reduction, staleness/TTL trade-offs, the deterministic
scenario matrix, and hit/miss accounting under admission control."""

import numpy as np
import pytest

from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import (SCENARIO_NAMES, TABLE1_WORKLOAD,
                                 WorkloadGenerator, scenario_generator,
                                 scenario_matrix)
from repro.serving.simulator import ServingSimulator, SimConfig

N = 5000


def run(arch="hybrid", n=N, adaptive=False, spikes=(), seed=42, **kw):
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=seed)
    sim = ServingSimulator(eng, SimConfig(
        architecture=arch, cache_capacity=12000, index_kind="flat",
        adaptive=adaptive, load_spikes=list(spikes), **kw))
    return sim.run(gen, n)


@pytest.fixture(scope="module")
def hybrid_result():
    return run("hybrid")


@pytest.fixture(scope="module")
def none_result():
    return run("none")


def test_long_tail_hit_rate_bands(hybrid_result):
    """Table 1 qualitative claim: head 40–60 %+, tail 2–20 %."""
    pc = hybrid_result.per_category
    assert pc["code_generation"]["hit_rate"] > 0.40
    assert pc["api_documentation"]["hit_rate"] > 0.35
    for tail in ("conversational_chat", "financial_data", "legal_queries",
                 "medical_queries", "specialized_domains"):
        assert 0.005 <= pc[tail]["hit_rate"] <= 0.25, (tail, pc[tail])
    head = pc["code_generation"]["hit_rate"]
    tail = pc["conversational_chat"]["hit_rate"]
    assert head > 2.5 * tail                     # long tail shape


def test_hybrid_beats_none_latency(hybrid_result, none_result):
    assert hybrid_result.mean_latency_ms < none_result.mean_latency_ms
    assert hybrid_result.model_cost < none_result.model_cost


def test_hybrid_beats_vdb_on_heterogeneous_workload(hybrid_result):
    vdb = run("vdb")
    # Uniform collection threshold (0.85) mismatches the dense code space
    # (cross-intent sims ≈ 0.85): the vdb "hits" are contaminated with
    # false positives — wrong answers served fast (§3.1/§4.2).
    assert vdb.false_positives > 5 * max(1, hybrid_result.false_positives)
    # Quality-adjusted latency (every FP hit must be re-asked → + T_llm):
    t_fp = 500.0
    hy = hybrid_result.mean_latency_ms + \
        hybrid_result.false_positives / hybrid_result.n_queries * t_fp
    vd = vdb.mean_latency_ms + vdb.false_positives / vdb.n_queries * t_fp
    assert hy < vd
    # structural overhead claim: vdb pays 30 ms search on EVERY query
    assert vdb.mean_latency_ms > 30.0


def test_financial_ttl_limits_staleness(hybrid_result):
    """5-minute TTL on 80 %/h content keeps stale serves low."""
    fin = hybrid_result.per_category["financial_data"]
    if fin["hits"]:
        assert fin["stale_served"] / max(1, fin["hits"]) < 0.35


def test_compliance_category_never_cached():
    from dataclasses import replace
    from repro.core.workload import CategorySpec
    specs = TABLE1_WORKLOAD + [CategorySpec(
        "phi_medical_records", traffic_share=0.05, pool_size=100,
        zipf_alpha=1.5, staleness_per_s=0.0, t_llm_ms=300.0,
        model_name="gpt4o", sigma=0.01, center_spread=0.3, seed=99)]
    total = sum(s.traffic_share for s in specs)
    specs = [replace(s, traffic_share=s.traffic_share / total) for s in specs]
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(specs, rate_per_s=30.0, seed=7)
    sim = ServingSimulator(eng, SimConfig(architecture="hybrid",
                                          index_kind="flat"))
    res = sim.run(gen, 2000)
    phi = res.per_category.get("phi_medical_records")
    assert phi is not None
    assert phi["hits"] == 0
    assert phi["compliance_rejects"] == phi["lookups"]


def test_adaptive_reduces_model_traffic_under_load():
    """§7.5: threshold relaxation under a spike cuts model calls for the
    loaded model vs the non-adaptive run (projection band: >0 %, sane)."""
    spikes = [(30.0, 900.0, "o1", 3.0)]
    base = run("hybrid", adaptive=False, spikes=spikes, seed=11)
    adap = run("hybrid", adaptive=True, spikes=spikes, seed=11)
    calls_base = base.model_calls.get("o1", 0)
    calls_adap = adap.model_calls.get("o1", 0)
    assert calls_adap < calls_base
    reduction = 1 - calls_adap / calls_base
    assert 0.005 <= reduction <= 0.5, reduction


def test_false_positive_rates_with_wrong_threshold():
    """§3.1: τ=0.80 on dense code space → cross-intent false positives;
    the category-aware τ=0.90 suppresses them."""
    eng_bad = PolicyEngine(paper_policies())
    eng_bad.update("code_generation", threshold=0.80)
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=5)
    sim = ServingSimulator(eng_bad, SimConfig(architecture="hybrid",
                                              index_kind="flat"))
    res_bad = sim.run(gen, 3000)
    fp_bad = res_bad.per_category["code_generation"]["fp_rate"]

    res_good = run("hybrid", n=3000, seed=5)
    fp_good = res_good.per_category["code_generation"]["fp_rate"]
    assert fp_bad > fp_good
    assert fp_bad > 0.02
    assert fp_good < 0.02


# ---------------------------------------------------------------------------
# Scenario matrix (core/workload.py): deterministic generation, shape
# sanity, and simulator smoke per scenario.
# ---------------------------------------------------------------------------

def test_scenario_matrix_registry():
    mat = scenario_matrix()
    assert tuple(mat) == SCENARIO_NAMES
    assert {"power_law", "uniform_tail", "bursty", "drifting",
            "session_drift", "flash_crowd", "stale_burst"} == set(mat)
    for name, scen in mat.items():
        assert scen.name == name and scen.description
        assert sum(s.traffic_share for s in scen.specs) == \
            pytest.approx(1.0)
    with pytest.raises(KeyError):
        scenario_generator("no_such_scenario")
    # rate override reaches the generator
    gen = scenario_generator("power_law", seed=1, rate_per_s=100.0)
    assert gen.rate_per_s == 100.0


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_scenario_fixed_seed_identical_trace(name):
    """Same seed → byte-identical query trace (category, intent,
    timestamp, version AND embedding); a different seed diverges."""
    a = scenario_generator(name, seed=3).generate(200)
    b = scenario_generator(name, seed=3).generate(200)
    for qa, qb in zip(a, b):
        assert (qa.category, qa.intent_id, qa.content_version,
                qa.timestamp) == \
            (qb.category, qb.intent_id, qb.content_version, qb.timestamp)
        assert np.array_equal(qa.embedding, qb.embedding)
    c = scenario_generator(name, seed=4).generate(200)
    assert any(qa.intent_id != qc.intent_id or qa.category != qc.category
               for qa, qc in zip(a, c))


def test_power_law_vs_uniform_tail_shape():
    """The two gate scenarios sit at opposite ends of the repetition
    spectrum: Zipf code traffic concentrates (top-10 intents ≫ uniform's)
    while the 50 k-intent chat tail almost never repeats."""
    from collections import Counter
    pl = Counter(q.intent_id
                 for q in scenario_generator("power_law", seed=3)
                 .generate(2000))
    ut = Counter(q.intent_id
                 for q in scenario_generator("uniform_tail", seed=3)
                 .generate(2000))
    top10 = lambda c: sum(n for _, n in c.most_common(10)) / 2000  # noqa: E731
    assert top10(pl) > 0.30 and len(pl) / 2000 < 0.45
    assert top10(ut) < 0.08 and len(ut) / 2000 > 0.75


def test_bursty_rotating_working_set():
    """Within the first burst window, ≥70 % of draws land in the 32-
    intent working set starting at intent 0 (burst_frac = 0.85 minus the
    uniform escape traffic)."""
    qs = scenario_generator("bursty", seed=3).generate(1000)
    w0 = [q for q in qs if q.timestamp < 60.0]
    assert len(w0) > 500
    share = sum(1 for q in w0 if 0 <= q.intent_id < 32) / len(w0)
    assert share > 0.70


def test_drifting_head_slides_with_time():
    """The Zipf head tracks a center moving at drift_per_s: the median
    intent of the last 500 queries sits far above the first 500's."""
    import statistics
    qs = scenario_generator("drifting", seed=3).generate(4000)
    first = statistics.median(q.intent_id for q in qs[:500])
    last = statistics.median(q.intent_id for q in qs[-500:])
    assert last > first + 100


def test_flash_crowd_is_windowed():
    """Chat traffic concentrates on the 16 flash intents ONLY inside
    the [20 s, 80 s) flash span."""
    qs = scenario_generator("flash_crowd", seed=3).generate(3000)
    chat = [q for q in qs if q.category == "conversational_chat"]
    inw = [q for q in chat if 20.0 <= q.timestamp < 80.0]
    outw = [q for q in chat if not (20.0 <= q.timestamp < 80.0)]
    assert len(inw) > 200 and len(outw) > 200
    assert sum(q.intent_id < 16 for q in inw) / len(inw) > 0.30
    assert sum(q.intent_id < 16 for q in outw) / len(outw) < 0.05


def _scenario_run(name, n=400, gated=None, eviction="static", seed=0):
    pol = PolicyEngine(paper_policies())
    if gated:
        pol.update(gated, admit_after=2)
    sim = ServingSimulator(pol, SimConfig(
        architecture="hybrid", cache_capacity=3000, index_kind="flat",
        eviction=eviction, seed=seed))
    return sim.run(scenario_generator(name, seed=seed), n), sim


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_simulator_smoke_every_scenario(name):
    """Every scenario drives the hybrid simulator end to end, and the
    counters balance: category lookups sum to queries issued and
    hits + misses == lookups in every category."""
    res, _ = _scenario_run(name)
    assert res.n_queries == 400
    assert sum(s["lookups"] for s in res.per_category.values()) == 400
    for cat, s in res.per_category.items():
        assert s["hits"] + s["misses"] == s["lookups"], (name, cat, s)
    assert res.mean_resident_entries > 0
    assert res.hits_per_resident_mb >= 0.0


def test_admission_skips_are_not_a_hit_rate_leak():
    """Accounting regression (the admission gate must not perturb the
    lookup ledger): with admit-on-2nd-touch active on chat, lookups
    still sum to queries issued, hits + misses == lookups, the skips
    surface in cache metrics, and the insert-side stats balance."""
    res, sim = _scenario_run("uniform_tail", n=1500,
                             gated="conversational_chat")
    per = res.metrics.per_category
    assert sum(s.lookups for s in per.values()) == 1500
    for s in per.values():
        assert s.hits + s.misses == s.lookups
    chat = per["conversational_chat"]
    assert chat.admission_skips > 0
    # skips are misses that were simply not admitted — never hits, and
    # never more numerous than the misses that produced them
    assert chat.admission_skips <= chat.misses
    # the serialized view and the insert-side ledger agree
    assert res.per_category["conversational_chat"]["admission_skips"] \
        == chat.admission_skips
    ins = sim.cache.last_insert_stats
    assert ins["batch"] == ins["admitted"] + ins["admission_skips"] \
        + ins["insert_rejects"]
    # an ungated run of the same scenario records zero skips
    res2, _ = _scenario_run("uniform_tail", n=1500)
    assert all(s["admission_skips"] == 0
               for s in res2.per_category.values())
    # and gating strictly shrinks the resident footprint
    assert res.mean_resident_entries < res2.mean_resident_entries
