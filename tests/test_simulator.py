"""End-to-end simulator behavior: Table 1 bands, baseline comparisons,
adaptive load reduction, staleness/TTL trade-offs."""

import pytest

from repro.core.policy import PolicyEngine, paper_policies
from repro.core.workload import TABLE1_WORKLOAD, WorkloadGenerator
from repro.serving.simulator import ServingSimulator, SimConfig

N = 5000


def run(arch="hybrid", n=N, adaptive=False, spikes=(), seed=42, **kw):
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=seed)
    sim = ServingSimulator(eng, SimConfig(
        architecture=arch, cache_capacity=12000, index_kind="flat",
        adaptive=adaptive, load_spikes=list(spikes), **kw))
    return sim.run(gen, n)


@pytest.fixture(scope="module")
def hybrid_result():
    return run("hybrid")


@pytest.fixture(scope="module")
def none_result():
    return run("none")


def test_long_tail_hit_rate_bands(hybrid_result):
    """Table 1 qualitative claim: head 40–60 %+, tail 2–20 %."""
    pc = hybrid_result.per_category
    assert pc["code_generation"]["hit_rate"] > 0.40
    assert pc["api_documentation"]["hit_rate"] > 0.35
    for tail in ("conversational_chat", "financial_data", "legal_queries",
                 "medical_queries", "specialized_domains"):
        assert 0.005 <= pc[tail]["hit_rate"] <= 0.25, (tail, pc[tail])
    head = pc["code_generation"]["hit_rate"]
    tail = pc["conversational_chat"]["hit_rate"]
    assert head > 2.5 * tail                     # long tail shape


def test_hybrid_beats_none_latency(hybrid_result, none_result):
    assert hybrid_result.mean_latency_ms < none_result.mean_latency_ms
    assert hybrid_result.model_cost < none_result.model_cost


def test_hybrid_beats_vdb_on_heterogeneous_workload(hybrid_result):
    vdb = run("vdb")
    # Uniform collection threshold (0.85) mismatches the dense code space
    # (cross-intent sims ≈ 0.85): the vdb "hits" are contaminated with
    # false positives — wrong answers served fast (§3.1/§4.2).
    assert vdb.false_positives > 5 * max(1, hybrid_result.false_positives)
    # Quality-adjusted latency (every FP hit must be re-asked → + T_llm):
    t_fp = 500.0
    hy = hybrid_result.mean_latency_ms + \
        hybrid_result.false_positives / hybrid_result.n_queries * t_fp
    vd = vdb.mean_latency_ms + vdb.false_positives / vdb.n_queries * t_fp
    assert hy < vd
    # structural overhead claim: vdb pays 30 ms search on EVERY query
    assert vdb.mean_latency_ms > 30.0


def test_financial_ttl_limits_staleness(hybrid_result):
    """5-minute TTL on 80 %/h content keeps stale serves low."""
    fin = hybrid_result.per_category["financial_data"]
    if fin["hits"]:
        assert fin["stale_served"] / max(1, fin["hits"]) < 0.35


def test_compliance_category_never_cached():
    from dataclasses import replace
    from repro.core.workload import CategorySpec
    specs = TABLE1_WORKLOAD + [CategorySpec(
        "phi_medical_records", traffic_share=0.05, pool_size=100,
        zipf_alpha=1.5, staleness_per_s=0.0, t_llm_ms=300.0,
        model_name="gpt4o", sigma=0.01, center_spread=0.3, seed=99)]
    total = sum(s.traffic_share for s in specs)
    specs = [replace(s, traffic_share=s.traffic_share / total) for s in specs]
    eng = PolicyEngine(paper_policies())
    gen = WorkloadGenerator(specs, rate_per_s=30.0, seed=7)
    sim = ServingSimulator(eng, SimConfig(architecture="hybrid",
                                          index_kind="flat"))
    res = sim.run(gen, 2000)
    phi = res.per_category.get("phi_medical_records")
    assert phi is not None
    assert phi["hits"] == 0
    assert phi["compliance_rejects"] == phi["lookups"]


def test_adaptive_reduces_model_traffic_under_load():
    """§7.5: threshold relaxation under a spike cuts model calls for the
    loaded model vs the non-adaptive run (projection band: >0 %, sane)."""
    spikes = [(30.0, 900.0, "o1", 3.0)]
    base = run("hybrid", adaptive=False, spikes=spikes, seed=11)
    adap = run("hybrid", adaptive=True, spikes=spikes, seed=11)
    calls_base = base.model_calls.get("o1", 0)
    calls_adap = adap.model_calls.get("o1", 0)
    assert calls_adap < calls_base
    reduction = 1 - calls_adap / calls_base
    assert 0.005 <= reduction <= 0.5, reduction


def test_false_positive_rates_with_wrong_threshold():
    """§3.1: τ=0.80 on dense code space → cross-intent false positives;
    the category-aware τ=0.90 suppresses them."""
    eng_bad = PolicyEngine(paper_policies())
    eng_bad.update("code_generation", threshold=0.80)
    gen = WorkloadGenerator(TABLE1_WORKLOAD, rate_per_s=30.0, seed=5)
    sim = ServingSimulator(eng_bad, SimConfig(architecture="hybrid",
                                              index_kind="flat"))
    res_bad = sim.run(gen, 3000)
    fp_bad = res_bad.per_category["code_generation"]["fp_rate"]

    res_good = run("hybrid", n=3000, seed=5)
    fp_good = res_good.per_category["code_generation"]["fp_rate"]
    assert fp_bad > fp_good
    assert fp_bad > 0.02
    assert fp_good < 0.02
