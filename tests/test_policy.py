"""Policy engine + adaptive controller (§7.5), with hypothesis properties.

Property tests need ``hypothesis`` (declared in requirements-dev.txt);
without it they are skipped and the example-based tests still run.
"""

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.economics import traffic_reduction
from repro.core.policy import (AdaptiveController, CategoryConfig,
                               LoadSignal, ModelLoadTracker, PolicyEngine,
                               paper_policies)


def test_effective_policy_paper_example():
    """§7.5.4: τ0=0.90 δmax=0.05 t0=7d βmax=2 → λ=1 gives 0.85 / 14d."""
    cfg = CategoryConfig("code", threshold=0.90, ttl=7 * 86400, quota=0.4,
                         delta_max=0.05, beta_max=2.0, tau_min=0.80)
    e0 = cfg.effective(0.0)
    e1 = cfg.effective(1.0)
    assert e0.threshold == pytest.approx(0.90)
    assert e0.ttl == pytest.approx(7 * 86400)
    assert e1.threshold == pytest.approx(0.85)
    assert e1.ttl == pytest.approx(14 * 86400)


@given(st.floats(0, 1), st.floats(0.71, 0.99), st.floats(0, 0.2),
       st.floats(1.0, 5.0))
@settings(max_examples=200, deadline=None)
def test_effective_policy_bounds_hold(lam, tau0, dmax, bmax):
    cfg = CategoryConfig("c", threshold=tau0, ttl=100.0, quota=0.5,
                         delta_max=dmax, beta_max=bmax, tau_min=0.70,
                         ttl_max=150.0)
    e = cfg.effective(lam)
    assert 0.70 <= e.threshold <= tau0 + 1e-9         # safety bound
    assert 100.0 - 1e-9 <= e.ttl <= 150.0 + 1e-9      # ttl cap
    # monotone: more load never tightens the policy
    e2 = cfg.effective(min(1.0, lam + 0.1))
    assert e2.threshold <= e.threshold + 1e-12
    assert e2.ttl >= e.ttl - 1e-9


def test_load_factor_eq7():
    tr = ModelLoadTracker(latency_target_ms=500, queue_target=32,
                          w_latency=0.6, w_queue=0.4, hysteresis=0.0)
    for _ in range(20):
        tr.observe(LoadSignal(latency_ms=250, queue_depth=16))
    # λ = 0.6·(250/500) + 0.4·(16/32) = 0.5
    assert tr.raw_load_factor() == pytest.approx(0.5, abs=0.02)
    for _ in range(50):
        tr.observe(LoadSignal(latency_ms=5000, queue_depth=500))
    assert tr.raw_load_factor() == 1.0                # clamped


def test_hysteresis_damps_small_changes():
    tr = ModelLoadTracker(latency_target_ms=500, queue_target=32,
                          hysteresis=0.1)
    for _ in range(10):
        tr.observe(LoadSignal(latency_ms=100, queue_depth=2))
    base = tr.load_factor()
    # small drift: published value must NOT move
    for _ in range(10):
        tr.observe(LoadSignal(latency_ms=120, queue_depth=3))
    assert tr.load_factor() == base
    # big spike: it must move
    for _ in range(64):
        tr.observe(LoadSignal(latency_ms=2000, queue_depth=100))
    assert tr.load_factor() > base + 0.1


def test_controller_per_model_isolation():
    """§7.5.5: load on model A relaxes only A's categories."""
    ctl = AdaptiveController()
    eng = PolicyEngine([
        CategoryConfig("a_cat", threshold=0.9, ttl=100, quota=0.5,
                       delta_max=0.05, tau_min=0.8, model_name="A"),
        CategoryConfig("b_cat", threshold=0.9, ttl=100, quota=0.5,
                       delta_max=0.05, tau_min=0.8, model_name="B"),
    ], controller=ctl)
    ctl.register_model("A", latency_target_ms=500, queue_target=32)
    ctl.register_model("B", latency_target_ms=500, queue_target=32)
    for _ in range(64):
        ctl.observe("A", LoadSignal(latency_ms=3000, queue_depth=200))
        ctl.observe("B", LoadSignal(latency_ms=50, queue_depth=0))
    assert eng.effective("a_cat").threshold < 0.9
    assert eng.effective("b_cat").threshold == pytest.approx(0.9)


def test_fp_feedback_shrinks_delta():
    """§7.5.6: FP rate above limit halves δ_max."""
    ctl = AdaptiveController(fp_rate_limit=0.05)
    eng = PolicyEngine([
        CategoryConfig("c", threshold=0.9, ttl=100, quota=0.5,
                       delta_max=0.08, tau_min=0.7, model_name="M")],
        controller=ctl)
    for _ in range(64):
        ctl.observe("M", LoadSignal(latency_ms=5000, queue_depth=300))
    relaxed = eng.effective("c").threshold
    ctl.report_false_positive_rate("c", 0.10)
    after = eng.effective("c").threshold
    assert after > relaxed                      # relaxation halved


def test_paper_policies_cover_table1():
    eng = PolicyEngine(paper_policies())
    assert not eng.get("phi_medical_records").allow_caching
    assert eng.get("code_generation").threshold == 0.90
    assert eng.get("conversational_chat").threshold == 0.75
    assert eng.get("financial_data").ttl == 300.0


@given(st.floats(0.0, 0.95), st.floats(0.0, 0.3))
@settings(max_examples=200, deadline=None)
def test_traffic_reduction_formula(h0, dh):
    """§7.5.2 example: h0=0.40, Δh=0.10 → 16.7% reduction; general props."""
    r = traffic_reduction(h0, dh)
    assert r >= 0
    if dh <= (1 - h0):
        assert r <= 1.0 + 1e-9


def test_traffic_reduction_paper_example():
    assert traffic_reduction(0.40, 0.10) == pytest.approx(0.1667, abs=1e-3)
