"""Host/device coherence of the delta-synced index (seeded random
interleaves — property-style but hypothesis-free so they always run).

The device tables are persistent and mutated in place by scatter flushes;
these tests drive long random interleaves of the write path
(``insert_batch`` / ``remove`` / ``sweep_expired``) with syncs injected at
random points — crossing the delta/rebuild boundary repeatedly — and
assert the device mirror stays EXACTLY equal to the host tables, and that
host and device searches agree.
"""

import numpy as np
import pytest

from repro.core import SemanticCache, SimClock
from repro.core.hnsw import HNSWIndex, INVALID
from repro.core.policy import CategoryConfig, PolicyEngine

DIM = 64


def _unit(rng, n):
    x = rng.standard_normal((n, DIM)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _assert_mirror_exact(idx: HNSWIndex) -> None:
    t = idx.device_tables()
    pairs = [("neighbors", idx.neighbors[0]), ("valid", idx.valid),
             ("category", idx.category)]
    # Quantized residency: the device holds the int8 rows + the per-slot
    # scale table (riding the same delta sync), never the fp32 rows.
    pairs += ([("emb", idx.emb_q), ("scale", idx.emb_scale)]
              if idx.quantized else [("emb", idx.emb)])
    for key, host in pairs:
        assert np.array_equal(np.asarray(t[key]), host), \
            f"device {key} diverged from host"
    assert np.array_equal(np.asarray(t["entries"]), idx.entry_set())


@pytest.mark.parametrize("seed,emb_dtype", [(0, "float32"), (1, "float32"),
                                            (2, "float32"), (0, "int8"),
                                            (2, "int8")])
def test_index_mirror_exact_under_random_interleave(seed, emb_dtype):
    """Random add_batch/remove interleave with syncs at random points:
    after every flush the device tables equal the host tables exactly —
    under int8 residency that includes the scale table riding the delta
    sync."""
    rng = np.random.default_rng(seed)
    from repro.core.hnsw import HNSWParams
    idx = HNSWIndex(DIM, 512, params=HNSWParams(emb_dtype=emb_dtype),
                    seed=seed)
    live: list[int] = []
    for _ in range(60):
        op = rng.random()
        if op < 0.55 or not live:
            b = int(rng.integers(1, 9))
            cats = rng.integers(0, 3, b).astype(np.int32)
            live.extend(int(s) for s in idx.add_batch(_unit(rng, b), cats))
        elif op < 0.85:
            k = min(len(live), int(rng.integers(1, 5)))
            for _ in range(k):
                live.remove(victim := live[int(rng.integers(len(live)))])
                idx.remove(victim)
        else:
            _assert_mirror_exact(idx)       # sync mid-interleave
    _assert_mirror_exact(idx)
    assert idx.sync_stats["delta_updates"] > 0, \
        "interleave never exercised the delta path"


@pytest.mark.parametrize("seed", [3, 4])
def test_search_host_device_agree_after_interleave(seed):
    """After a mutation storm, exact-vector searches agree between the
    host hierarchical search and the device beam search over the synced
    tables (every device result same-category and above threshold)."""
    rng = np.random.default_rng(seed)
    idx = HNSWIndex(DIM, 512, seed=seed)
    vecs = _unit(rng, 200)
    cats = (np.arange(200) % 2).astype(np.int32)
    idx.add_batch(vecs[:150], cats[:150])
    idx.search_batch(vecs[:8], np.full(8, 0.99, np.float32))  # first upload
    removed = rng.choice(150, 30, replace=False)
    for s in removed:
        idx.remove(int(s))
    reused = idx.add_batch(vecs[150:], cats[150:])
    stale = np.setdiff1d(removed, reused)    # tombstones never recycled

    alive = np.setdiff1d(np.arange(200), removed)
    picks = rng.choice(alive, 32, replace=False)
    q = vecs[picks]
    qc = cats[picks]
    taus = np.full(32, 0.99, np.float32)
    hi, _ = idx.search_host(q, taus, categories=qc)
    di, _ = idx.search_batch(q, taus, categories=qc)
    assert float(np.mean(hi != INVALID)) >= 0.9
    assert float(np.mean(di != INVALID)) >= 0.85
    both = (hi != INVALID) & (di != INVALID)
    assert float(np.mean(hi[both] == di[both])) >= 0.9
    for arr in (hi, di):
        found = arr != INVALID
        assert (idx.category[arr[found]] == qc[found]).all()
        assert not np.isin(arr[found], stale).any()


@pytest.mark.parametrize("emb_dtype", ["float32", "int8"])
def test_cache_mirror_exact_under_insert_remove_sweep(rng, emb_dtype):
    """Cache-level interleave: insert_batch / TTL sweep_expired / lookups
    (which evict expired matches) keep the device mirror exact — for both
    resident dtypes."""
    eng = PolicyEngine([
        CategoryConfig("a", threshold=0.90, ttl=50.0, quota=0.6),
        CategoryConfig("b", threshold=0.90, ttl=1e6, quota=0.6),
    ])
    clock = SimClock()
    cache = SemanticCache(eng, dim=DIM, capacity=512, clock=clock,
                          index_kind="hnsw", use_device=True, seed=9,
                          emb_dtype=emb_dtype)
    rng2 = np.random.default_rng(9)
    vecs = _unit(rng2, 120)
    for step in range(6):
        lo, hi = step * 20, (step + 1) * 20
        cats = ["a" if i % 2 else "b" for i in range(lo, hi)]
        cache.insert_batch(vecs[lo:hi], cats,
                           [f"q{i}" for i in range(lo, hi)],
                           [f"r{i}" for i in range(lo, hi)])
        clock.advance(20.0)
        if step % 2:
            cache.sweep_expired()           # expires "a" entries (ttl 50)
        res = cache.lookup_batch(vecs[lo:hi], cats)
        _assert_mirror_exact(cache.index)
        # device search never serves an expired/evicted slot
        for r in res:
            if r.hit:
                assert cache.slot_valid[r.slot]
    assert cache.metrics.cat("a").ttl_evictions > 0
    assert cache.index.sync_stats["delta_updates"] > 0


@pytest.mark.parametrize("fail_after,emb_dtype", [(0, "float32"),
                                                  (1, "float32"),
                                                  (2, "int8")])
def test_failed_partial_delta_flush_recovers_exact(monkeypatch, fail_after,
                                                   emb_dtype):
    """Injected failed/PARTIAL delta flush: the scatter comprehension
    dies after ``fail_after`` of the per-table scatters — the old mirror
    may hold donated (invalid) buffers — and a retried flush must
    restore exact host/device table equality. device_tables() drops the
    poisoned mirror on the way out, so the retry is a clean full
    rebuild; the dirty log survives unconsumed."""
    from repro.core.hnsw import HNSWIndex, HNSWParams
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    idx = HNSWIndex(DIM, 256, params=HNSWParams(emb_dtype=emb_dtype),
                    seed=7)
    idx.add_batch(_unit(rng, 32), np.zeros(32, np.int32))
    _assert_mirror_exact(idx)               # establish a mirror (full up)
    idx.add_batch(_unit(rng, 4), np.ones(4, np.int32))  # dirty delta

    real = ops.scatter_rows
    calls = {"n": 0}

    def dying_scatter(dst, rows, payload):
        if calls["n"] >= fail_after:
            raise RuntimeError("injected flush fault (device OOM)")
        calls["n"] += 1
        return real(dst, rows, payload)

    monkeypatch.setattr(ops, "scatter_rows", dying_scatter)
    with pytest.raises(RuntimeError, match="injected flush fault"):
        idx.device_tables()
    assert idx._device is None              # poisoned mirror dropped
    assert idx._dirty                       # delta not marked consumed
    monkeypatch.setattr(ops, "scatter_rows", real)
    _assert_mirror_exact(idx)               # retried flush: exact again
    # and the index keeps delta-syncing normally afterwards
    idx.add_batch(_unit(rng, 2), np.zeros(2, np.int32))
    before = idx.sync_stats["delta_updates"]
    _assert_mirror_exact(idx)
    assert idx.sync_stats["delta_updates"] == before + 1
