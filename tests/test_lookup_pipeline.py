"""Lookup data plane: fused-hop/reference parity, bucketed batch shapes,
done-query freeze, and on-device TTL classification (ISSUE 3).

Property-style parity: the jnp ``beam_search`` reference and the fused
frontier-hop path (jnp fallback AND the actual Pallas kernel in interpret
mode) must agree on idx, score, hit class and the deterministic counters
over random graphs with tombstones, wildcard queries and mixed categories.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SemanticCache, SimClock
from repro.core.hnsw import (CLS_EXPIRED, CLS_HIT, CLS_MISS, HNSWIndex,
                             HNSWParams, INVALID, _bucket_batch, beam_search,
                             beam_search_classified)
from repro.core.policy import CategoryConfig, PolicyEngine

IMPLS = ("reference", "fused", "fused_pallas")


def _unit(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _small_params():
    # tiny beam/M0 keep the interpret-mode kernel cheap on CPU
    return HNSWParams(M=4, M0=8, ef_construction=16, ef_search=16,
                      beam=8, max_hops=5, n_entries=4)


def _random_graph(seed, n=70, d=128, removed=12):
    rng = np.random.default_rng(seed)
    idx = HNSWIndex(d, 96, params=_small_params(), seed=seed)
    vecs = _unit(rng, n, d)
    cats = (np.arange(n) % 2).astype(np.int32)
    idx.add_batch(vecs, cats)
    for s in rng.choice(n, removed, replace=False):
        idx.remove(int(s))                         # tombstones still route
    return idx, vecs, cats, rng


def _mixed_queries(rng, vecs, d, B=8):
    """Exact revisits, paraphrases and cold randoms; wildcard + both
    categories; thresholds from trivially-met to unreachable (so some
    queries freeze at hop 0 while others run to convergence)."""
    picks = rng.integers(0, len(vecs), B)
    q = vecs[picks].copy()
    q[B // 2:] = _unit(rng, B - B // 2, d)         # cold random tail
    qc = rng.integers(-1, 2, B).astype(np.int32)
    taus = np.where(np.arange(B) % 3 == 0, 0.2, 0.92).astype(np.float32)
    taus[-1] = 2.0                                 # unreachable: never done
    return q, taus, qc


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_beam_search_impl_parity(seed):
    """idx, score AND the deterministic counters (hops, rows gathered)
    agree across all three hop implementations."""
    idx, vecs, cats, rng = _random_graph(seed)
    t = idx.device_tables()
    q, taus, qc = _mixed_queries(rng, vecs, 128)
    outs = {}
    for impl in IMPLS:
        i, s, st = beam_search(t["emb"], t["neighbors"], t["valid"],
                               t["entries"], jnp.asarray(q),
                               jnp.asarray(taus), t["category"],
                               jnp.asarray(qc), beam=idx.p.beam,
                               max_hops=idx.p.max_hops, hop_impl=impl)
        outs[impl] = (np.asarray(i), np.asarray(s), int(st["hops"]),
                      np.asarray(st["rows_gathered"]))
    ref = outs["reference"]
    for impl in IMPLS[1:]:
        got = outs[impl]
        assert np.array_equal(got[0], ref[0]), impl
        np.testing.assert_allclose(got[1], ref[1], rtol=1e-4, atol=1e-4,
                                   err_msg=impl)
        assert got[2] == ref[2], f"{impl}: hop count diverged"
        assert np.array_equal(got[3], ref[3]), \
            f"{impl}: rows-gathered counter diverged"
    # masked-search invariants hold on every path
    i0 = ref[0]
    found = i0 != INVALID
    assert found.any()
    own = qc >= 0
    assert (idx.category[i0[found & own]] == qc[found & own]).all()
    assert idx.valid[i0[found]].all()


@pytest.mark.parametrize("seed", [3, 4])
def test_classified_search_impl_parity(seed):
    """{hit, expired, miss} classes agree across implementations and match
    the host oracle computed from (idx, score)."""
    idx, vecs, cats, rng = _random_graph(seed)
    # give slots spread-out insertion times so some matches are expired
    idx.inserted[:] = rng.uniform(0.0, 100.0, idx.capacity).astype(np.float32)
    idx._dirty.update(range(idx.capacity))
    idx._version += 1
    t = idx.device_tables()
    q, taus, qc = _mixed_queries(rng, vecs, 128)
    ttls = np.full(8, 60.0, np.float32)
    now = np.float32(130.0)
    outs = {}
    for impl in IMPLS:
        i, s, c, _st = beam_search_classified(
            t["emb"], t["neighbors"], t["valid"], t["entries"],
            t["inserted"], jnp.asarray(q), jnp.asarray(taus),
            jnp.asarray(ttls), now, t["category"], jnp.asarray(qc),
            beam=idx.p.beam, max_hops=idx.p.max_hops, hop_impl=impl)
        outs[impl] = (np.asarray(i), np.asarray(s), np.asarray(c))
    ref = outs["reference"]
    for impl in IMPLS[1:]:
        assert np.array_equal(outs[impl][0], ref[0]), impl
        assert np.array_equal(outs[impl][2], ref[2]), \
            f"{impl}: hit class diverged"
    i0, _s0, c0 = ref
    found = i0 != INVALID
    want = np.where(found & (now - idx.inserted[np.maximum(i0, 0)] > ttls),
                    CLS_EXPIRED, np.where(found, CLS_HIT, CLS_MISS))
    assert np.array_equal(c0, want)
    assert set(np.unique(c0)) <= {CLS_MISS, CLS_EXPIRED, CLS_HIT}


def test_bucket_batch_shapes():
    assert _bucket_batch(1) == _bucket_batch(8) == 8
    assert _bucket_batch(9) == _bucket_batch(16) == 16
    assert _bucket_batch(17) == 32


def test_one_compilation_serves_all_serve_batch_sizes():
    """Acceptance: engine queue drains produce B = 1..max_batch; bucketing
    must make them all hit ONE compiled program."""
    rng = np.random.default_rng(7)
    idx, vecs, _cats, _ = _random_graph(7)
    cache_size = getattr(beam_search_classified, "_cache_size", None)
    before = cache_size() if cache_size else None
    for B in range(1, 9):
        q = vecs[rng.integers(0, len(vecs), B)]
        i, s, c, cand = idx.search_classified(q, np.full(B, 0.9, np.float32),
                                              categories=np.zeros(B, np.int32))
        assert i.shape == (B,) and s.shape == (B,) and c.shape == (B,)
        assert cand.shape == (B,)
    assert idx.search_stats["searches"] == 8
    assert idx.search_stats["compilations"] == 1, \
        "batch bucketing regressed: distinct padded shapes per serve size"
    if before is not None:
        assert cache_size() - before <= 1, \
            "jit cache grew more than one entry across B = 1..max_batch"


def test_flat_index_device_path_matches_host():
    """use_device on a flat index routes through ops.cache_topk and must
    agree with the host scan, including bucketed odd batch sizes."""
    rng = np.random.default_rng(11)
    eng = PolicyEngine([
        CategoryConfig("a", threshold=0.90, ttl=3600.0, quota=0.6),
        CategoryConfig("b", threshold=0.90, ttl=3600.0, quota=0.6),
    ])
    host = SemanticCache(eng, dim=128, capacity=256, clock=SimClock(),
                         index_kind="flat", use_device=False)
    dev = SemanticCache(eng, dim=128, capacity=256, clock=SimClock(),
                        index_kind="flat", use_device=True)
    vecs = _unit(rng, 40, 128)
    cats = ["a" if i % 2 else "b" for i in range(40)]
    for c in (host, dev):
        c.insert_batch(vecs, cats, [f"q{i}" for i in range(40)],
                       [f"r{i}" for i in range(40)])
    for B in (1, 3, 8):
        picks = rng.integers(0, 40, B)
        rh = host.lookup_batch(vecs[picks], [cats[i] for i in picks])
        rd = dev.lookup_batch(vecs[picks], [cats[i] for i in picks])
        for a, b in zip(rh, rd):
            assert a.hit == b.hit and a.response == b.response
            assert a.reason == b.reason
    assert dev.index.search_stats["compilations"] == 1
    assert dev.index.sync_stats["full_uploads"] >= 1


@pytest.mark.parametrize("index_kind", ["hnsw", "flat"])
def test_device_ttl_classification_evicts_expired(index_kind):
    """Algorithm 1 lines 18-21 on device: an expired match classifies as
    CLS_EXPIRED inside the jitted search, and the cache evicts it without
    touching the store."""
    rng = np.random.default_rng(13)
    eng = PolicyEngine([
        CategoryConfig("short", threshold=0.90, ttl=600.0, quota=1.0),
    ])
    clock = SimClock()
    cache = SemanticCache(eng, dim=128, capacity=256, clock=clock,
                          index_kind=index_kind, use_device=True)
    vecs = _unit(rng, 20, 128)
    cache.insert_batch(vecs, ["short"] * 20,
                       [f"q{i}" for i in range(20)],
                       [f"r{i}" for i in range(20)])
    res = cache.lookup_batch(vecs[:4], ["short"] * 4)
    assert all(r.hit and r.reason == "hit" for r in res)
    clock.advance(601.0)
    res = cache.lookup_batch(vecs[:4], ["short"] * 4)
    assert all((not r.hit) and r.reason == "expired" for r in res)
    assert cache.metrics.cat("short").ttl_evictions == 4
    assert len(cache) == 16                       # expired entries evicted
    miss = cache.lookup_batch(_unit(rng, 1, 128), ["short"])
    assert not miss[0].hit and miss[0].reason == "no_match"


@pytest.mark.parametrize("use_device", [True, False])
def test_ttl_survives_epoch_scale_clock(use_device):
    """The inserted table is float32 (the device dtype), whose spacing at
    absolute epoch times (~1.7e9 s) is minutes — the cache must rebase
    timestamps to its construction instant so short TTLs classify
    correctly under a wall-clock-like SimClock, on both paths."""
    rng = np.random.default_rng(17)
    eng = PolicyEngine([
        CategoryConfig("short", threshold=0.90, ttl=60.0, quota=1.0),
    ])
    clock = SimClock(start=1.7e9)               # epoch-scale absolute time
    cache = SemanticCache(eng, dim=128, capacity=128, clock=clock,
                          index_kind="hnsw", use_device=use_device)
    vecs = _unit(rng, 8, 128)
    cache.insert_batch(vecs, ["short"] * 8,
                       [f"q{i}" for i in range(8)],
                       [f"r{i}" for i in range(8)])
    res = cache.lookup_batch(vecs[:4], ["short"] * 4)
    assert all(r.hit for r in res), "fresh entries misclassified as expired"
    clock.advance(61.0)
    res = cache.lookup_batch(vecs[:4], ["short"] * 4)
    assert all(r.reason == "expired" for r in res), \
        "float32 timestamp rounding swallowed a 61 s advance"


def test_done_query_freeze_reduces_rows_gathered():
    """A query that reaches τ immediately must stop issuing gathers: its
    rows-gathered counter sits strictly below a never-satisfied query's."""
    idx, vecs, _cats, rng = _random_graph(21, removed=0)
    q = vecs[:8]
    idx.search_batch(q, np.full(8, 0.5, np.float32))       # instant hits
    rows_easy = int(np.sum(np.asarray(idx.last_search["rows_gathered"])))
    hops_easy = int(idx.last_search["hops"])
    idx.search_batch(_unit(rng, 8, 128), np.full(8, 2.0, np.float32))
    rows_hard = int(np.sum(np.asarray(idx.last_search["rows_gathered"])))
    hops_hard = int(idx.last_search["hops"])
    assert rows_easy < rows_hard
    assert hops_easy <= hops_hard
    # τ satisfied by ANY entry point → done at init: zero hops, and the
    # only rows fetched are the entry set's
    idx.search_batch(q, np.full(8, -1.0, np.float32))
    assert int(idx.last_search["hops"]) == 0
    rows_init = int(np.sum(np.asarray(idx.last_search["rows_gathered"])))
    assert rows_init == 8 * min(idx.p.n_entries, idx.p.beam)


def test_search_batch_returns_device_arrays():
    """Satellite: search_batch must not force a blocking host sync — both
    outputs stay jax arrays; the cache layer converts once."""
    idx, vecs, _cats, _rng = _random_graph(31)
    i, s = idx.search_batch(vecs[:4], np.full(4, 0.9, np.float32))
    assert isinstance(i, jax.Array) and isinstance(s, jax.Array)
    assert i.shape == (4,) and s.shape == (4,)
    assert isinstance(idx.last_search["rows_gathered"], jax.Array)


def test_fused_path_has_no_materialized_embedding_gather():
    """Acceptance: on the fused path the compiled HLO contains NO f32
    gather shaped (B, K, d) — hop scoring goes through ops.hop_scores /
    the frontier-hop kernel, so candidate embeddings never materialize as
    an XLA gather. The reference path (the CPU oracle) does contain one,
    which also proves the rule works. The check itself is the
    ``contracts.NoMaterializedGather`` rule (the shared static-analysis
    gate), not a local regex."""
    from repro.analysis.contracts import HloTrace, NoMaterializedGather
    d, B = 256, 8
    idx, vecs, _cats, rng = _random_graph(41, n=40, d=d)
    t = idx.device_tables()
    args = (t["emb"], t["neighbors"], t["valid"], t["entries"],
            jnp.asarray(_unit(rng, B, d)),
            jnp.asarray(np.full(B, 0.9, np.float32)), t["category"],
            jnp.asarray(np.zeros(B, np.int32)))

    def trace(impl):
        hlo = beam_search.lower(*args, beam=idx.p.beam, max_hops=3,
                                hop_impl=impl).compile().as_text()
        return HloTrace(name=impl, hlo=hlo, meta={"d": d})

    rule = NoMaterializedGather()
    assert rule.check(trace("reference")), \
        "rule broken: reference path should materialize the gather"
    assert rule.check(trace("fused_pallas")) == [], \
        "fused path still materializes a (B, K, d) embedding gather"
