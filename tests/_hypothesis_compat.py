"""Shared hypothesis import guard (declared in requirements-dev.txt).

Without hypothesis installed, ``@given``-decorated property tests turn
into skips and the example-based tests in the same module still run.
Import in test modules as::

    from _hypothesis_compat import given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no hypothesis: skip property tests
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _MissingStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _MissingStrategies()
