"""HNSW index: recall vs brute force, tombstones, device/host agreement."""

import numpy as np
import pytest

from repro.core.embedding import make_dense_space, make_sparse_space
from repro.core.hnsw import FlatIndex, HNSWIndex, INVALID


def _unit(rng, n, d=384):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_host_search_recall_vs_flat(rng):
    n = 600
    vecs = _unit(rng, n)
    hnsw = HNSWIndex(384, 1024, seed=1)
    flat = FlatIndex(384, 1024)
    for v in vecs:
        hnsw.add(v)
        flat.add(v)
    queries = vecs[rng.integers(0, n, 64)] + \
        0.02 * rng.standard_normal((64, 384)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    taus = np.full(64, -np.inf, np.float32)
    hi, hs = hnsw.search_host(queries, taus)
    fi, fs = flat.search_host(queries, taus)
    recall = float(np.mean(hi == fi))
    assert recall >= 0.9


def test_device_beam_search_agrees_with_host(rng):
    n = 400
    vecs = _unit(rng, n)
    hnsw = HNSWIndex(384, 512, seed=2)
    for v in vecs:
        hnsw.add(v)
    queries = vecs[rng.integers(0, n, 32)]
    taus = np.full(32, 0.99, np.float32)     # exact-vector lookups
    di, ds = hnsw.search_batch(queries, taus)
    hits = float(np.mean(di != INVALID))
    assert hits >= 0.85                      # ANN beam recall
    ok = ds[di != INVALID] >= 0.99 - 1e-5
    assert ok.all()


def test_threshold_early_exit_semantics(rng):
    """Results below per-query τ must come back INVALID."""
    vecs = _unit(rng, 100)
    hnsw = HNSWIndex(384, 256, seed=3)
    for v in vecs:
        hnsw.add(v)
    q = _unit(rng, 8)                         # random queries: low sims
    idx, score = hnsw.search_batch(q, np.full(8, 0.95, np.float32))
    assert (idx == INVALID).all()


def test_tombstone_remove_excludes_from_results(rng):
    vecs = _unit(rng, 200)
    hnsw = HNSWIndex(384, 256, seed=4)
    slots = [hnsw.add(v) for v in vecs]
    target = 17
    i0, _ = hnsw.search_host(vecs[target][None], np.array([0.99]))
    assert i0[0] == slots[target]
    hnsw.remove(slots[target])
    i1, s1 = hnsw.search_host(vecs[target][None], np.array([0.99]))
    assert i1[0] != slots[target]
    # device path too
    i2, _ = hnsw.search_batch(vecs[target][None], np.array([0.99]))
    assert i2[0] != slots[target]


def test_slot_reuse_after_eviction(rng):
    idx = HNSWIndex(16, 4, seed=5)
    a = idx.add(_unit(rng, 1, 16)[0])
    b = idx.add(_unit(rng, 1, 16)[0])
    idx.remove(a)
    c = idx.add(_unit(rng, 1, 16)[0])
    assert c == a                             # freelist reuse
    d = idx.add(_unit(rng, 1, 16)[0])
    idx.add(_unit(rng, 1, 16)[0])
    with pytest.raises(RuntimeError):
        idx.add(_unit(rng, 1, 16)[0])         # capacity enforced


def test_bulk_build_recall(rng):
    """Bulk build on clustered data (the realistic cache distribution:
    semantic intents form clusters). Pure-uniform high-d data is the known
    pathological case for graph ANN and is served by the flat path."""
    n, n_clusters, d = 3000, 60, 384
    centers = _unit(rng, n_clusters, d)
    assign = rng.integers(0, n_clusters, n)
    vecs = centers[assign] + 0.05 * rng.standard_normal((n, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    idx = HNSWIndex.bulk_build(vecs, seed=7)
    flat = FlatIndex(d, n + 8)
    for v in vecs:
        flat.add(v)
    q = vecs[rng.integers(0, n, 64)] + \
        0.02 * rng.standard_normal((64, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    fi, fs = flat.search_host(q, np.full(64, -np.inf, np.float32))
    hi, hs = idx.search_host(q, np.full(64, -np.inf, np.float32))
    # score-recall: bulk graph may return a different but near-equal neighbor
    close = np.mean(hs >= fs - 0.02)
    assert close >= 0.85


def test_density_profiles_match_paper(rng):
    """§3.1: dense 10NN dist ≈ 0.12, sparse ≈ 0.38."""
    d = make_dense_space(seed=0).nn_distance_profile()
    s = make_sparse_space(seed=0).nn_distance_profile()
    assert 0.08 <= d <= 0.20
    assert 0.30 <= s <= 0.48
