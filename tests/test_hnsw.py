"""HNSW index: recall vs brute force, tombstones, device/host agreement."""

import numpy as np
import pytest

from repro.core.embedding import make_dense_space, make_sparse_space
from repro.core.hnsw import FlatIndex, HNSWIndex, INVALID


def _unit(rng, n, d=384):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_host_search_recall_vs_flat(rng):
    n = 600
    vecs = _unit(rng, n)
    hnsw = HNSWIndex(384, 1024, seed=1)
    flat = FlatIndex(384, 1024)
    for v in vecs:
        hnsw.add(v)
        flat.add(v)
    queries = vecs[rng.integers(0, n, 64)] + \
        0.02 * rng.standard_normal((64, 384)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    taus = np.full(64, -np.inf, np.float32)
    hi, hs = hnsw.search_host(queries, taus)
    fi, fs = flat.search_host(queries, taus)
    recall = float(np.mean(hi == fi))
    assert recall >= 0.9


def test_device_beam_search_agrees_with_host(rng):
    n = 400
    vecs = _unit(rng, n)
    hnsw = HNSWIndex(384, 512, seed=2)
    for v in vecs:
        hnsw.add(v)
    queries = vecs[rng.integers(0, n, 32)]
    taus = np.full(32, 0.99, np.float32)     # exact-vector lookups
    di, ds = hnsw.search_batch(queries, taus)
    hits = float(np.mean(di != INVALID))
    assert hits >= 0.85                      # ANN beam recall
    ok = ds[di != INVALID] >= 0.99 - 1e-5
    assert ok.all()


def test_threshold_early_exit_semantics(rng):
    """Results below per-query τ must come back INVALID."""
    vecs = _unit(rng, 100)
    hnsw = HNSWIndex(384, 256, seed=3)
    for v in vecs:
        hnsw.add(v)
    q = _unit(rng, 8)                         # random queries: low sims
    idx, score = hnsw.search_batch(q, np.full(8, 0.95, np.float32))
    assert (idx == INVALID).all()


def test_tombstone_remove_excludes_from_results(rng):
    vecs = _unit(rng, 200)
    hnsw = HNSWIndex(384, 256, seed=4)
    slots = [hnsw.add(v) for v in vecs]
    target = 17
    i0, _ = hnsw.search_host(vecs[target][None], np.array([0.99]))
    assert i0[0] == slots[target]
    hnsw.remove(slots[target])
    i1, s1 = hnsw.search_host(vecs[target][None], np.array([0.99]))
    assert i1[0] != slots[target]
    # device path too
    i2, _ = hnsw.search_batch(vecs[target][None], np.array([0.99]))
    assert i2[0] != slots[target]


def test_slot_reuse_after_eviction(rng):
    idx = HNSWIndex(16, 4, seed=5)
    a = idx.add(_unit(rng, 1, 16)[0])
    b = idx.add(_unit(rng, 1, 16)[0])
    idx.remove(a)
    c = idx.add(_unit(rng, 1, 16)[0])
    assert c == a                             # freelist reuse
    d = idx.add(_unit(rng, 1, 16)[0])
    idx.add(_unit(rng, 1, 16)[0])
    with pytest.raises(RuntimeError):
        idx.add(_unit(rng, 1, 16)[0])         # capacity enforced


def test_bulk_build_recall(rng):
    """Bulk build on clustered data (the realistic cache distribution:
    semantic intents form clusters). Pure-uniform high-d data is the known
    pathological case for graph ANN and is served by the flat path."""
    n, n_clusters, d = 3000, 60, 384
    centers = _unit(rng, n_clusters, d)
    assign = rng.integers(0, n_clusters, n)
    vecs = centers[assign] + 0.05 * rng.standard_normal((n, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    idx = HNSWIndex.bulk_build(vecs, seed=7)
    flat = FlatIndex(d, n + 8)
    for v in vecs:
        flat.add(v)
    q = vecs[rng.integers(0, n, 64)] + \
        0.02 * rng.standard_normal((64, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    fi, fs = flat.search_host(q, np.full(64, -np.inf, np.float32))
    hi, hs = idx.search_host(q, np.full(64, -np.inf, np.float32))
    # score-recall: bulk graph may return a different but near-equal neighbor
    close = np.mean(hs >= fs - 0.02)
    assert close >= 0.85


def test_flat_masked_search_matches_bruteforce_oracle(rng):
    """FlatIndex with categories == numpy argmax over same-category rows."""
    n, d = 400, 384
    vecs = _unit(rng, n, d)
    cats = (rng.integers(0, 3, n)).astype(np.int32)
    flat = FlatIndex(d, 512)
    for v, c in zip(vecs, cats):
        flat.add(v, category=int(c))
    q = _unit(rng, 32, d)
    qc = rng.integers(-1, 3, 32).astype(np.int32)   # includes wildcards
    taus = np.full(32, -np.inf, np.float32)
    fi, fs = flat.search_host(q, taus, categories=qc)
    sims = q @ vecs.T
    for b in range(32):
        allowed = np.ones(n, bool) if qc[b] < 0 else (cats == qc[b])
        want = int(np.argmax(np.where(allowed, sims[b], -np.inf)))
        assert fi[b] == want
        assert fs[b] == pytest.approx(sims[b, want], abs=1e-5)


def test_host_device_parity_mixed_category_batch(rng):
    """Acceptance: over a mixed-category batch, host search and the jitted
    device beam search must agree — every returned slot is same-category,
    and exact-vector queries resolve to their own slot on both paths."""
    n = 400
    vecs = _unit(rng, n)
    hnsw = HNSWIndex(384, 512, seed=6)
    for j, v in enumerate(vecs):
        hnsw.add(v, category=j % 2)
    picks = rng.integers(0, n, 32)
    queries = vecs[picks]
    qc = (picks % 2).astype(np.int32)
    taus = np.full(32, 0.99, np.float32)     # exact-vector lookups
    hi, hs = hnsw.search_host(queries, taus, categories=qc)
    di, ds = hnsw.search_batch(queries, taus, categories=qc)
    for idx_arr in (hi, di):
        found = idx_arr != INVALID
        # every result is the query's own category
        assert (hnsw.category[idx_arr[found]] == qc[found]).all()
    assert float(np.mean(hi != INVALID)) >= 0.9
    assert float(np.mean(di != INVALID)) >= 0.85      # ANN beam recall
    both = (hi != INVALID) & (di != INVALID)
    assert float(np.mean(hi[both] == di[both])) >= 0.9


def test_cross_category_nodes_route_but_never_win(rng):
    """DiskANN-style: the opposite category still routes the beam, but the
    returned best is always same-category — even when a cross-category node
    is strictly nearer to the query."""
    n = 300
    vecs = _unit(rng, n)
    hnsw = HNSWIndex(384, 512, seed=7)
    for j, v in enumerate(vecs):
        hnsw.add(v, category=j % 2)
    # query ON category-0 vectors, but ask for category 1
    own = np.arange(0, 32, 2)                 # slots with category 0
    q = vecs[own]
    qc = np.ones(16, np.int32)
    taus = np.full(16, -np.inf, np.float32)
    hi, hs = hnsw.search_host(q, taus, categories=qc)
    di, ds = hnsw.search_batch(q, taus, categories=qc)
    assert (hnsw.category[hi[hi != INVALID]] == 1).all()
    assert (hnsw.category[di[di != INVALID]] == 1).all()
    # never the (category-0) exact match the query sits on
    assert not np.any(hi == own)
    assert not np.any(di == own)


def test_flat_masked_empty_category_is_a_miss(rng):
    """All slots masked out + τ = -inf must return INVALID, not an
    arbitrary -inf-scored cross-category slot (-inf >= -inf)."""
    flat = FlatIndex(384, 16)
    for v in _unit(rng, 4):
        flat.add(v, category=0)
    i, s = flat.search_host(_unit(rng, 1), np.array([-np.inf], np.float32),
                            categories=np.array([5], np.int32))
    assert i[0] == INVALID
    # same guard for the pre-existing all-tombstones variant
    flat2 = FlatIndex(384, 16)
    flat2.remove(flat2.add(_unit(rng, 1)[0]))
    i, s = flat2.search_host(_unit(rng, 1), np.array([-np.inf], np.float32))
    assert i[0] == INVALID


def test_bulk_build_carries_categories(rng):
    """bulk_build must accept per-slot categories so masked search works
    on bulk-built indexes (host and device)."""
    n, n_clusters, d = 1200, 40, 384
    centers = _unit(rng, n_clusters, d)
    assign = rng.integers(0, n_clusters, n)
    vecs = centers[assign] + 0.015 * rng.standard_normal((n, d)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    cats = (np.arange(n) % 2).astype(np.int32)
    idx = HNSWIndex.bulk_build(vecs, seed=7, categories=cats)
    picks = rng.choice(np.arange(0, n, 2), 32, replace=False)  # cat-0 slots
    qc = np.ones(32, np.int32)                                 # want cat 1
    taus = np.full(32, 0.85, np.float32)
    hi, _ = idx.search_host(vecs[picks], taus, categories=qc)
    di, _ = idx.search_batch(vecs[picks], taus, categories=qc)
    assert float(np.mean(hi != INVALID)) >= 0.9
    assert float(np.mean(di != INVALID)) >= 0.85
    assert (idx.category[hi[hi != INVALID]] == 1).all()
    assert (idx.category[di[di != INVALID]] == 1).all()


def _device_matches_host(idx: HNSWIndex) -> None:
    t = idx.device_tables()
    for key, host in (("emb", idx.emb), ("neighbors", idx.neighbors[0]),
                      ("valid", idx.valid), ("category", idx.category)):
        assert np.array_equal(np.asarray(t[key]), host), key
    assert np.array_equal(np.asarray(t["entries"]), idx.entry_set())


def test_delta_sync_small_mutation_is_not_a_full_upload(rng):
    """Steady-state contract: after the initial upload, a small mutation
    batch flushes as ONE in-place delta (rows ≪ capacity) and the device
    tables match the host tables exactly."""
    idx = HNSWIndex(64, 2048, seed=1)
    idx.add_batch(_unit(rng, 100, 64), np.arange(100) % 3)
    _device_matches_host(idx)
    assert idx.sync_stats["full_uploads"] == 1
    before = dict(idx.sync_stats)
    idx.add_batch(_unit(rng, 4, 64), np.full(4, 1))
    idx.remove(5)
    _device_matches_host(idx)
    after = idx.sync_stats
    assert after["full_uploads"] == before["full_uploads"]
    assert after["delta_updates"] == before["delta_updates"] + 1
    rows_moved = after["rows_synced"] - before["rows_synced"]
    assert 0 < rows_moved < idx.capacity // 4
    # sync cost is O(delta): far below a full-table upload
    assert (after["bytes_synced"] - before["bytes_synced"]) < \
        0.25 * idx.capacity * idx._row_nbytes()


def test_delta_sync_rebuild_threshold_falls_back_to_full(rng):
    """A churn burst past rebuild_threshold re-uploads the full tables
    instead of scattering thousands of rows."""
    idx = HNSWIndex(64, 128, seed=2)
    idx.add_batch(_unit(rng, 20, 64))
    idx.device_tables()
    assert idx.sync_stats["full_uploads"] == 1
    idx.add_batch(_unit(rng, 60, 64))      # dirties > 25% of capacity
    _device_matches_host(idx)
    assert idx.sync_stats["full_uploads"] == 2
    assert idx.sync_stats["delta_updates"] == 0


def test_add_batch_coalesces_to_one_flush(rng):
    """B inserts between searches must cost one sync, not B."""
    idx = HNSWIndex(64, 4096, seed=3)
    idx.add_batch(_unit(rng, 64, 64))
    idx.device_tables()
    n0 = idx.sync_stats["full_uploads"] + idx.sync_stats["delta_updates"]
    vecs = _unit(rng, 16, 64)
    slots = idx.add_batch(vecs, np.zeros(16, np.int32))
    assert len(set(slots.tolist())) == 16
    di, _ = idx.search_batch(vecs, np.full(16, 0.99, np.float32),
                             categories=np.zeros(16, np.int32))
    n1 = idx.sync_stats["full_uploads"] + idx.sync_stats["delta_updates"]
    assert n1 == n0 + 1
    assert float(np.mean(di != INVALID)) >= 0.85


def test_forced_full_resync_mode(rng):
    """rebuild_threshold < 0 restores the pre-delta behavior (the
    benchmark's O(capacity) contrast): every sync is a full upload."""
    idx = HNSWIndex(32, 256, seed=4)
    idx.p.rebuild_threshold = -1.0
    idx.add_batch(_unit(rng, 10, 32))
    idx.device_tables()
    idx.add(_unit(rng, 1, 32)[0])
    idx.device_tables()
    assert idx.sync_stats["full_uploads"] == 2
    assert idx.sync_stats["delta_updates"] == 0


def test_entry_set_cached_on_version(rng):
    idx = HNSWIndex(32, 256, seed=5)
    idx.add_batch(_unit(rng, 40, 32))
    e0 = idx.entry_set()
    assert idx.entry_set() is e0               # no recompute, same version
    assert idx.entry_point in e0
    assert (idx.level[e0[e0 != INVALID]] >= 0).all()
    # top-E selection: no live node outranks the chosen set's minimum level
    chosen = e0[e0 != INVALID]
    alive = np.where(idx.valid)[0]
    others = np.setdiff1d(alive, chosen)
    if others.size and chosen.size == idx.p.n_entries:
        assert idx.level[others].max() <= idx.level[chosen].max()
    idx.add(_unit(rng, 1, 32)[0])
    assert idx.entry_set() is not e0           # version bump invalidates


def test_density_profiles_match_paper(rng):
    """§3.1: dense 10NN dist ≈ 0.12, sparse ≈ 0.38."""
    d = make_dense_space(seed=0).nn_distance_profile()
    s = make_sparse_space(seed=0).nn_distance_profile()
    assert 0.08 <= d <= 0.20
    assert 0.30 <= s <= 0.48
