"""Admission control plane (core/admission.py): frequency-sketch
properties, paraphrase canonicalization, controller determinism and
migration handoff, and the pluggable eviction scorers.

The sketch properties are the contract the admission gate leans on —
a conservative-update count-min sketch can OVER-count (collisions) but
must never under-count, so ``admit_after`` can only admit EARLY, never
starve a genuinely repeating intent. Property-tested with hypothesis
when available (skipped cleanly otherwise, per _hypothesis_compat).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.admission import (AdmissionController, CategoryTracker,
                                  CostAwareEvictionScorer, FrequencySketch,
                                  QueryFingerprinter, StaticEvictionScorer,
                                  make_eviction_scorer)
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.policy import CategoryConfig, PolicyEngine

DIM = 48

keys = st.integers(min_value=0, max_value=2**64 - 1)


def _unit(rng, n=1, dim=DIM):
    v = rng.standard_normal((n, dim)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# FrequencySketch properties.
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(stream=st.lists(keys, max_size=300), seed=st.integers(0, 2**31))
def test_sketch_never_undercounts_and_bounded_by_traffic(stream, seed):
    """Without decay: true_count(k) ≤ estimate(k) ≤ total observations,
    for every key in the stream."""
    sk = FrequencySketch(width=64, depth=2, seed=seed, decay_every=0)
    true = {}
    for k in stream:
        sk.observe(k)
        true[k] = true.get(k, 0) + 1
    assert sk.observations == len(stream)
    for k, n in true.items():
        assert n <= sk.estimate(k) <= len(stream)


@settings(max_examples=40, deadline=None)
@given(stream=st.lists(keys, max_size=200), seed=st.integers(0, 2**31))
def test_sketch_deterministic_at_fixed_seed(stream, seed):
    a = FrequencySketch(width=128, depth=3, seed=seed)
    b = FrequencySketch(width=128, depth=3, seed=seed)
    ra = [a.observe(k) for k in stream]
    rb = [b.observe(k) for k in stream]
    assert ra == rb
    assert np.array_equal(a.counts, b.counts)
    # a different seed re-hashes: state need not match, API still works
    c = FrequencySketch(width=128, depth=3, seed=seed + 1)
    for k in stream:
        c.observe(k)
    assert c.observations == len(stream)


@settings(max_examples=40, deadline=None)
@given(stream=st.lists(keys, min_size=1, max_size=120))
def test_sketch_decay_halves_every_estimate(stream):
    sk = FrequencySketch(width=64, depth=2, seed=7, decay_every=0)
    for k in stream:
        sk.observe(k)
    before = {k: sk.estimate(k) for k in stream}
    sk.decay()
    for k, est in before.items():
        assert sk.estimate(k) == est // 2   # >>1 is monotone, min commutes


@settings(max_examples=30, deadline=None)
@given(s1=st.lists(keys, max_size=100), s2=st.lists(keys, max_size=100))
def test_sketch_merge_never_undercounts_combined_stream(s1, s2):
    """Merging two shards' sketches keeps the no-undercount guarantee
    over the union stream (cell-wise add can only raise estimates)."""
    a = FrequencySketch(width=64, depth=2, seed=3, decay_every=0)
    b = FrequencySketch(width=64, depth=2, seed=3, decay_every=0)
    for k in s1:
        a.observe(k)
    for k in s2:
        b.observe(k)
    ea = {k: a.estimate(k) for k in s1 + s2}
    a.merge(b)
    assert a.observations == len(s1) + len(s2)
    true = {}
    for k in s1 + s2:
        true[k] = true.get(k, 0) + 1
    for k, n in true.items():
        assert a.estimate(k) >= n
        assert a.estimate(k) >= ea[k]       # merge never lowers


def test_sketch_auto_decay_and_validation():
    sk = FrequencySketch(width=32, depth=2, seed=0, decay_every=4)
    for _ in range(3):
        sk.observe(42)
    assert sk.estimate(42) == 3
    sk.observe(42)                           # 4th observation → decay fires
    assert sk.estimate(42) == 2              # 4 >> 1
    with pytest.raises(ValueError):
        FrequencySketch(width=0)
    with pytest.raises(ValueError):
        sk.merge(FrequencySketch(width=32, depth=2, seed=99))
    with pytest.raises(ValueError):
        sk.merge(FrequencySketch(width=16, depth=2, seed=0))


# ---------------------------------------------------------------------------
# Fingerprinter + tracker canonicalization.
# ---------------------------------------------------------------------------

def test_fingerprinter_deterministic_and_bounded():
    fp1 = QueryFingerprinter(DIM, n_bits=16, seed=5)
    fp2 = QueryFingerprinter(DIM, n_bits=16, seed=5)
    embs = _unit(np.random.default_rng(0), 32)
    for e in embs:
        k = fp1.key(e)
        assert k == fp2.key(e)
        assert 0 <= k < 2**16
    with pytest.raises(ValueError):
        QueryFingerprinter(DIM, n_bits=0)
    with pytest.raises(ValueError):
        QueryFingerprinter(DIM, n_bits=65)


def test_tracker_counts_repeats_and_canonicalizes_paraphrases():
    """An exact repeat counts up 1, 2, 3…; a paraphrase within τ of a
    representative inherits its key and counts as the same intent."""
    tr = CategoryTracker(DIM, tau=0.8, seed=1)
    rng = np.random.default_rng(2)
    intent = _unit(rng)[0]
    assert [tr.observe(intent) for _ in range(3)] == [1, 2, 3]
    para = intent + 0.1 * _unit(rng)[0]      # cos ≈ 0.995 ≥ τ
    para /= np.linalg.norm(para)
    assert tr.observe(para) == 4
    other = _unit(rng)[0]                    # cos ≈ 0.14 at dim 48: new
    assert tr.observe(other) == 1
    assert tr.representatives == 2


def test_tracker_exact_repeat_survives_ring_eviction():
    """The SimHash mint is a deterministic function of the embedding, so
    an EXACT repeat re-mints the identical key even after its
    representative aged out of the ring buffer — only paraphrase linkage
    is bounded by the window."""
    tr = CategoryTracker(DIM, tau=0.8, buffer_size=2, seed=1)
    rng = np.random.default_rng(3)
    first, a, b = _unit(rng, 3)
    assert tr.observe(first) == 1
    tr.observe(a)
    tr.observe(b)                            # ring size 2: first evicted
    assert tr.representatives == 2
    assert tr.observe(first) == 2            # same mint → count continues


def test_tracker_key_of_enrolls_without_counting():
    tr = CategoryTracker(DIM, tau=0.8, seed=1)
    e = _unit(np.random.default_rng(4))[0]
    k = tr.key_of(e)
    assert tr.estimate(e) == 0
    assert tr.sketch.observations == 0
    assert tr.observe(e) == 1
    assert tr.key_of(e) == k                 # representative key is stable


# ---------------------------------------------------------------------------
# AdmissionController: name seeding, determinism, migration handoff.
# ---------------------------------------------------------------------------

def test_controller_decisions_independent_of_owner():
    """Two controllers (e.g. two shards) fed the same per-category
    stream make identical decisions — state is seeded from the category
    NAME, never from the owning cache."""
    embs = _unit(np.random.default_rng(5), 40)
    stream = list(embs) + list(embs[:10])    # some repeats
    a, b = AdmissionController(DIM), AdmissionController(DIM)
    ca = [a.observe("chat", e, tau=0.8) for e in stream]
    cb = [b.observe("chat", e, tau=0.8) for e in stream]
    assert ca == cb
    assert ca[-10:] == [2] * 10              # the repeats were recognized
    # distinct categories track independently
    assert a.observe("code", embs[0]) == 1
    assert a.estimate("never_seen", embs[0]) == 0


def test_controller_export_adopt_preserves_history():
    """Migration handoff: the destination continues the count where the
    source left off; adopting into an existing tracker merges counts."""
    e = _unit(np.random.default_rng(6))[0]
    src, dst = AdmissionController(DIM), AdmissionController(DIM)
    for _ in range(3):
        src.observe("chat", e)
    assert src.export_state("missing") is None
    dst.adopt_state("chat", None)            # no-op
    dst.adopt_state("chat", src.export_state("chat"))
    assert src.stats() == {}                 # detached from the source
    assert dst.observe("chat", e) == 4       # history survived the move
    # merge path: both sides tracked the category before the handoff
    other = AdmissionController(DIM)
    other.observe("chat", e)
    other.adopt_state("chat", dst.export_state("chat"))
    assert other.estimate("chat", e) >= 5
    assert other.stats()["chat"]["observations"] == 5


# ---------------------------------------------------------------------------
# Batched ring-buffer similarity (PR-7 follow-on): one matmul over the
# batch's gated candidates must reproduce the sequential path's counts.
# ---------------------------------------------------------------------------

def test_observe_batch_matches_sequential_counts():
    """Unchanged-counters regression: ``observe_batch`` (one gemm over
    the ring snapshot + fresh dots for slots the batch itself wrote)
    returns exactly the counts the item-at-a-time ``observe`` loop
    produced — including intra-batch repeats, paraphrases of entries
    enrolled EARLIER IN THE SAME BATCH, and ring-slot overwrites."""
    rng = np.random.default_rng(11)
    base = _unit(rng, 24)
    stream = []
    for i in range(24):
        stream.append(base[i])
        if i % 3 == 0:                      # paraphrase of a recent item
            p = base[i] + 0.05 * _unit(rng)[0]
            stream.append(p / np.linalg.norm(p))
        if i % 5 == 0:
            stream.append(base[i])          # exact intra-batch repeat
    stream = np.stack(stream)
    for batch_size in (1, 4, len(stream)):
        seq = CategoryTracker(DIM, tau=0.8, buffer_size=8, seed=1)
        bat = CategoryTracker(DIM, tau=0.8, buffer_size=8, seed=1)
        got, want = [], []
        for lo in range(0, len(stream), batch_size):
            chunk = stream[lo:lo + batch_size]
            want.extend(seq.observe(e) for e in chunk)
            got.extend(bat.observe_batch(chunk))
        assert got == want, f"batch_size={batch_size}"
        assert bat.representatives == seq.representatives


def test_observe_batch_end_to_end_cache_counters_unchanged():
    """The cache's grouped observe_batch admission gate reproduces the
    per-item path's counters: batched inserts vs B=1 inserts of the
    same stream admit/skip identically under admit_after=2."""
    def policies():
        return PolicyEngine([CategoryConfig("a", threshold=0.80, ttl=1e6,
                                            quota=0.5, admit_after=2),
                             CategoryConfig("b", threshold=0.78, ttl=1e6,
                                            quota=0.4, admit_after=3)])
    rng = np.random.default_rng(12)
    embs = np.concatenate([_unit(rng, 10)] * 3)     # 3 passes over 10
    cats = (["a", "b"] * 5) * 3
    reqs = [f"q{i}" for i in range(len(embs))]
    resps = [f"r{i}" for i in range(len(embs))]
    batched = SemanticCache(policies(), dim=DIM, capacity=64,
                            clock=SimClock(), index_kind="flat", seed=0)
    batched.insert_batch(embs, cats, reqs, resps)
    single = SemanticCache(policies(), dim=DIM, capacity=64,
                           clock=SimClock(), index_kind="flat", seed=0)
    for i in range(len(embs)):
        single.insert(embs[i], cats[i], reqs[i], resps[i])
    for c in ("a", "b"):
        sb, ss = batched.metrics.cat(c), single.metrics.cat(c)
        assert (sb.inserts, sb.admission_skips) == (ss.inserts,
                                                    ss.admission_skips), c
    assert len(batched) == len(single) > 0
    assert batched.metrics.cat("a").admission_skips > 0


# ---------------------------------------------------------------------------
# Eviction scorers.
# ---------------------------------------------------------------------------

def test_make_eviction_scorer():
    assert isinstance(make_eviction_scorer("static"), StaticEvictionScorer)
    assert isinstance(make_eviction_scorer("cost_aware"),
                      CostAwareEvictionScorer)
    with pytest.raises(ValueError, match="unknown eviction"):
        make_eviction_scorer("lru")


def _two_cat_cache(eviction):
    pol = PolicyEngine([
        CategoryConfig("cheap", threshold=0.80, ttl=1e6, quota=1.0,
                       priority=1.0, expected_tllm_ms=100.0),
        CategoryConfig("dear", threshold=0.80, ttl=1e6, quota=1.0,
                       priority=1.0, expected_tllm_ms=1000.0),
    ])
    return SemanticCache(pol, dim=DIM, capacity=8, clock=SimClock(),
                         index_kind="flat", eviction=eviction)


def test_cost_aware_eviction_prefers_expensive_misses():
    """At equal priority and hit history, capacity pressure evicts the
    entry whose miss is CHEAP to recompute (expected_tllm_ms 100 vs
    1000) under cost_aware — while static scoring (equal priority) has
    no basis to distinguish the categories."""
    cache = _two_cat_cache("cost_aware")
    rng = np.random.default_rng(7)
    vecs = _unit(rng, 9)
    cats = ["cheap", "dear"] * 4
    cache.insert_batch(vecs[:8], cats, [f"q{i}" for i in range(8)],
                       [f"r{i}" for i in range(8)])
    cache.clock.advance(5.0)
    cache.insert_batch(vecs[8:], ["dear"], ["q8"], ["r8"])  # forces 1 evict
    assert cache.category_count("cheap") == 3               # victim: cheap
    assert cache.category_count("dear") == 5
    # and the new entry is resident
    res = cache.lookup_batch(vecs[8:], ["dear"])
    assert res[0].hit and res[0].response == "r8"


def test_cost_aware_scores_scale_with_bytes_and_cost():
    """score = rate × cost / bytes: the dear category outranks the cheap
    one 10× at equal hit history, on both resident and fresh entries."""
    cache = _two_cat_cache("cost_aware")
    rng = np.random.default_rng(8)
    vecs = _unit(rng, 2)
    slots = cache.insert_batch(vecs, ["cheap", "dear"], ["q0", "q1"],
                               ["r0", "r1"])
    cache.clock.advance(1.0)
    scorer = cache._evictor
    s = scorer.score(cache, np.asarray(slots))
    assert s[1] == pytest.approx(10.0 * s[0])
    cheap_id = cache._cat_id("cheap")
    dear_id = cache._cat_id("dear")
    assert scorer.fresh_score(cache, dear_id) == \
        pytest.approx(10.0 * scorer.fresh_score(cache, cheap_id))
    # the admission-frequency prior raises the fresh score linearly
    assert scorer.fresh_score(cache, cheap_id, freq=5) == \
        pytest.approx(5.0 * scorer.fresh_score(cache, cheap_id, freq=1))


def test_static_scorer_matches_seed_formula():
    cache = _two_cat_cache("static")
    rng = np.random.default_rng(9)
    vecs = _unit(rng, 2)
    slots = cache.insert_batch(vecs, ["cheap", "dear"], ["q0", "q1"],
                               ["r0", "r1"])
    cache.lookup_batch(vecs[1:], ["dear"])   # one hit on the dear entry
    cache.clock.advance(2.0)
    s = cache._entry_score(np.asarray(slots))
    now = cache._now()
    age = now - cache.slot_inserted[np.asarray(slots)]
    assert s[0] == pytest.approx(1.0 / age[0] * 1.0)
    assert s[1] == pytest.approx(1.0 / age[1] * 2.0)   # (hits+1) = 2
