"""End-to-end behaviour tests for the paper's system: real model behind
the category-aware cache, training loop, optimizer sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.cache import SemanticCache
from repro.core.clock import SimClock
from repro.core.policy import AdaptiveController, PolicyEngine, \
    paper_policies
from repro.models import Model
from repro.optim.adamw import AdamWConfig, apply_adamw, init_opt_state
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3_2_3b").reduced(n_layers=2, d_model=64,
                                            vocab_size=256)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def test_engine_serves_hits_without_model(small_model, rng):
    cfg, model, params = small_model
    policies = PolicyEngine(paper_policies())
    cache = SemanticCache(policies, capacity=1024, clock=SimClock(),
                          index_kind="flat")
    eng = ServingEngine(model, params, cache, max_batch=4, prompt_len=16,
                        max_new_tokens=4)
    toks = rng.integers(2, cfg.vocab_size, 16)
    eng.submit("how do I sort a list in python", "code_generation", toks)
    r1 = eng.drain()
    assert len(r1) == 1 and not r1[0].cached
    tokens_after_first = eng.stats.model_tokens
    # paraphrase-identical resubmission → cache hit, no new model tokens
    eng.submit("how do I sort a list in python", "code_generation", toks)
    r2 = eng.drain()
    assert r2[0].cached
    assert eng.stats.model_tokens == tokens_after_first
    assert r2[0].text == r1[0].text


def test_engine_compliance_always_model(small_model, rng):
    cfg, model, params = small_model
    policies = PolicyEngine(paper_policies())
    cache = SemanticCache(policies, capacity=128, clock=SimClock(),
                          index_kind="flat")
    eng = ServingEngine(model, params, cache, max_batch=2, prompt_len=16,
                        max_new_tokens=4)
    toks = rng.integers(2, cfg.vocab_size, 16)
    for _ in range(2):
        eng.submit("patient record 1234", "phi_medical_records", toks)
    res = eng.drain()
    assert all(not r.cached for r in res)
    assert len(cache) == 0


def test_engine_watchdog_counts_straggler_steps(small_model, rng):
    """The StepWatchdog rides every non-empty step(): fast steps build
    the median history, an artificially slowed step surfaces as
    ``stats.straggler_steps``."""
    import time as _time
    from repro.distributed.fault import StepWatchdog

    cfg, model, params = small_model
    policies = PolicyEngine(paper_policies())
    cache = SemanticCache(policies, capacity=128, clock=SimClock(),
                          index_kind="flat")
    wd = StepWatchdog(timeout_factor=20.0, min_history=5)
    eng = ServingEngine(model, params, cache, max_batch=1, prompt_len=16,
                        max_new_tokens=4, watchdog=wd)
    assert eng.step() == []                 # empty queue: never timed
    toks = rng.integers(2, cfg.vocab_size, 16)
    # one miss compiles + serves, then hits build a stable fast history
    for i in range(8):
        eng.submit("what is a closure", "code_generation", toks)
        eng.step()
    assert eng.stats.straggler_steps == 0
    # slow one step far past 20× the (hit-dominated, ~ms) median
    orig = eng._generate

    def slow_generate(p, t):
        _time.sleep(0.5)
        return orig(p, t)
    eng._generate = slow_generate
    eng.submit("a brand new uncached question", "code_generation", toks)
    eng.step()
    eng._generate = orig
    assert eng.stats.straggler_steps == 1
    assert wd.straggler_events == 1


def test_training_loss_decreases():
    from repro.launch.train import run_training
    cfg = get_config("llama3_2_3b").reduced(n_layers=2, d_model=128,
                                            vocab_size=512)
    res = run_training(cfg, steps=40, batch=8, seq=64, lr=3e-3,
                       log=lambda *_: None)
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first - 0.2, (first, last)


def test_adamw_moves_params_and_clips(rng):
    params = {"w": jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)}
    grads = {"w": jnp.full((8, 128), 100.0)}          # huge → clipped
    cfg = AdamWConfig(lr=1e-2, clip_norm=1.0)
    st = init_opt_state(params, cfg)
    p2, st2, met = apply_adamw(params, grads, st, cfg)
    assert float(met["grad_norm"]) > 1.0
    assert not np.allclose(np.asarray(p2["w"]), np.asarray(params["w"]))
    assert int(st2["step"]) == 1


@pytest.mark.parametrize("state_dtype", ["fp32", "bf16", "int8"])
def test_adamw_state_dtypes_converge(rng, state_dtype):
    """Quantized moments still optimize a quadratic."""
    target = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    params = {"w": jnp.zeros((4, 128))}
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, state_dtype=state_dtype,
                      schedule="constant", warmup_steps=1)
    st = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": params["w"] - target}
        params, st, _ = apply_adamw(params, grads, st, cfg)
    err = float(jnp.mean(jnp.abs(params["w"] - target)))
    assert err < 0.15, err


def test_adaptive_integration_relaxes_threshold(small_model, rng):
    cfg, model, params = small_model
    ctl = AdaptiveController()
    ctl.register_model("default", latency_target_ms=1.0, queue_target=1)
    policies = PolicyEngine(paper_policies(), controller=ctl)
    policies.update("code_generation", model_name="default")
    base_tau = policies.effective("code_generation").threshold
    cache = SemanticCache(policies, capacity=512, clock=SimClock(),
                          index_kind="flat")
    eng = ServingEngine(model, params, cache, max_batch=4, prompt_len=16,
                        max_new_tokens=4, controller=ctl)
    for i in range(12):                    # misses → model calls → load obs
        toks = rng.integers(2, cfg.vocab_size, 16)
        eng.submit(f"query number {i} entirely unique", "code_generation",
                   toks)
    eng.drain()
    assert policies.effective("code_generation").threshold < base_tau
