"""Checkpointing: atomic save/restore, async, GC, resume-exactness,
elastic reshard, data-pipeline state."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import PackedBatcher, SyntheticCorpus


def tree_eq(a, b):
    ja = jax.tree.leaves(a)
    jb = jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(ja, jb))


def make_tree(rng):
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                        jnp.bfloat16),
                       "b": jnp.asarray(rng.standard_normal(8))},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, rng):
    tree = make_tree(rng)
    save_checkpoint(str(tmp_path), 5, tree, extras={"foo": 1})
    got, extras, step = restore_checkpoint(str(tmp_path))
    assert step == 5 and extras == {"foo": 1}
    assert tree_eq(tree, got)
    # bf16 dtype survives
    assert got["params"]["w"].dtype == jnp.bfloat16


def test_latest_and_gc(tmp_path, rng):
    tree = make_tree(rng)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=3)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3


def test_async_checkpointer(tmp_path, rng):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = make_tree(rng)
    ck.save(1, tree)
    ck.save(2, tree)      # waits for 1 internally
    ck.wait()
    assert latest_step(str(tmp_path)) == 2


def test_batcher_state_resumes_exactly():
    corpus = SyntheticCorpus(vocab_size=128, seed=3)
    b1 = PackedBatcher(corpus, batch=2, seq=32)
    _ = [b1.next_batch() for _ in range(3)]
    state = b1.state_dict()
    want = b1.next_batch()
    b2 = PackedBatcher(SyntheticCorpus(vocab_size=128, seed=3), 2, 32)
    b2.load_state_dict(state)
    got = b2.next_batch()
    assert np.array_equal(want["tokens"], got["tokens"])
    assert np.array_equal(want["labels"], got["labels"])


def test_train_resume_matches_uninterrupted(tmp_path):
    """checkpoint/restart: 6 straight steps == 3 steps + restart + 3."""
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("llama3_2_3b").reduced(n_layers=2, d_model=64,
                                            vocab_size=256)
    r_full = run_training(cfg, steps=6, batch=2, seq=32, log=lambda *_: None)
    d = str(tmp_path / "ck")
    run_training(cfg, steps=3, batch=2, seq=32, ckpt_dir=d, ckpt_every=100,
                 log=lambda *_: None)
    r_resumed = run_training(cfg, steps=6, batch=2, seq=32, ckpt_dir=d,
                             ckpt_every=100, log=lambda *_: None)
    assert r_resumed["steps_run"] == 3       # resumed from step 3
    np.testing.assert_allclose(r_full["losses"][3:], r_resumed["losses"],
                               rtol=2e-2, atol=2e-2)


def test_elastic_restore_into_mesh(tmp_path, rng):
    """A single-device checkpoint restores under new shardings (reshape of
    the device mapping — the elasticity primitive)."""
    import subprocess
    import sys
    import textwrap
    tree = make_tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    code = textwrap.dedent(f"""
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import restore_checkpoint
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        sh = {{"params": {{"w": NamedSharding(mesh, P("data", "model")),
                           "b": NamedSharding(mesh, P(None))}},
              "opt": {{"step": NamedSharding(mesh, P())}}}}
        tree, extras, step = restore_checkpoint({str(tmp_path)!r},
                                                shardings=sh)
        w = tree["params"]["w"]
        assert w.sharding.spec == P("data", "model"), w.sharding
        assert step == 1
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=240, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
