"""Break-even economics: the paper's eqs (1)–(6) + properties.

Property tests need ``hypothesis`` (declared in requirements-dev.txt);
without it they are skipped and the example-based tests still run.
"""

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.economics import (CostModel, HYBRID_COSTS, ResidencyModel,
                                  VDB_COSTS, break_even_under_load,
                                  category_economics, expected_latency,
                                  residency_capacity_table, workload_report)


def test_paper_break_even_numbers():
    # §4.4: vdb needs h > 30/195 ≈ 15.4 % (fast), 30/495 ≈ 6.1 % (slow)
    assert VDB_COSTS.break_even_hit_rate(200.0) == pytest.approx(0.154, abs=2e-3)
    assert VDB_COSTS.break_even_hit_rate(500.0) == pytest.approx(0.061, abs=2e-3)
    # §5.5: hybrid needs h > 2/195 ≈ 1.0 %, 2/495 ≈ 0.4 %
    assert HYBRID_COSTS.break_even_hit_rate(200.0) == pytest.approx(0.010, abs=1e-3)
    assert HYBRID_COSTS.break_even_hit_rate(500.0) == pytest.approx(0.004, abs=1e-3)


def test_paper_52_latency_example():
    """§5.2: 20 % hit rate → hybrid 3.0 ms vs vdb 31 ms (search+fetch only)."""
    h = 0.2
    hybrid = HYBRID_COSTS.search_ms + h * HYBRID_COSTS.hit_fetch_ms
    vdb = VDB_COSTS.search_ms + h * VDB_COSTS.hit_fetch_ms
    assert hybrid == pytest.approx(3.0)
    assert vdb == pytest.approx(31.0)


def test_break_even_under_load_eq6():
    # §7.5.1: T_load = 1000 ms → h > 2/995 ≈ 0.2 %
    assert break_even_under_load(500.0, 2.0) == pytest.approx(0.002, abs=5e-4)


@given(st.floats(0.0, 1.0), st.floats(50.0, 2000.0))
@settings(max_examples=300, deadline=None)
def test_expected_latency_monotone_in_hit_rate(h, t_llm):
    """More hits never hurt (as long as fetch < T_llm)."""
    l1 = expected_latency(h, t_llm)
    l2 = expected_latency(min(1.0, h + 0.05), t_llm)
    assert l2 <= l1 + 1e-9


@given(st.floats(0.0, 1.0), st.floats(50.0, 2000.0))
@settings(max_examples=300, deadline=None)
def test_hybrid_dominates_vdb(h, t_llm):
    """Same hit rate → hybrid is always at least as fast as the vector DB."""
    assert (HYBRID_COSTS.expected_latency_ms(h, t_llm)
            <= VDB_COSTS.expected_latency_ms(h, t_llm))


@given(st.floats(10.0, 2000.0))
@settings(max_examples=200, deadline=None)
def test_viability_threshold_consistency(t_llm):
    m = HYBRID_COSTS
    be = m.break_even_hit_rate(t_llm)
    if be < 1.0:
        assert m.viable(min(1.0, be + 0.01), t_llm)
        assert not m.viable(max(0.0, be - 0.01), t_llm)


def test_table1_viability_classification():
    """Table 1: head viable on both; tail viable only on hybrid."""
    rows = [
        category_economics("code_generation", 0.35, 0.55, 500.0),
        category_economics("api_documentation", 0.25, 0.45, 500.0),
        category_economics("conversational_chat", 0.15, 0.12, 200.0),
        category_economics("financial_data", 0.10, 0.08, 200.0),
        category_economics("legal_queries", 0.08, 0.10, 500.0),
        category_economics("medical_queries", 0.04, 0.06, 500.0),
        category_economics("specialized_domains", 0.03, 0.07, 200.0),
    ]
    head = rows[:2]
    tail = rows[2:]
    assert all(r.vdb_viable and r.hybrid_viable for r in head)
    assert all(r.hybrid_viable for r in tail)
    # the fast-model tail categories are NOT viable on the vector DB
    assert not rows[2].vdb_viable          # chat: 12 % < 15.4 %
    assert not rows[3].vdb_viable          # financial: 8 % < 15.4 %
    rep = workload_report(rows)
    assert rep["coverage_hybrid"] == pytest.approx(1.0)
    assert rep["coverage_vdb"] < 0.75
    assert rep["mean_latency_hybrid_ms"] < rep["mean_latency_vdb_ms"]
    assert rep["mean_latency_hybrid_ms"] < rep["mean_latency_none_ms"]


def test_never_viable_when_model_faster_than_fetch():
    m = CostModel("x", search_ms=2.0, hit_fetch_ms=5.0)
    assert m.break_even_hit_rate(4.0) == float("inf")


def test_residency_model_quota_capacity():
    """Quantized-tier quota math: int8 shrinks the embedding component
    exactly 4x-ish (d·4 → d + 4), which multiplies the entries every
    category quota holds out of the same byte budget."""
    f32 = ResidencyModel(dim=384, emb_dtype="float32")
    i8 = ResidencyModel(dim=384, emb_dtype="int8")
    assert f32.emb_bytes() == 1536 and i8.emb_bytes() == 388
    assert f32.emb_bytes() / i8.emb_bytes() == pytest.approx(3.96, abs=0.01)
    # whole-entry ratio is diluted by graph + metadata, but stays > 2x
    assert f32.bytes_per_entry() / i8.bytes_per_entry() > 2.0
    # paper §5.1: fp32 at 384 dims ≈ 1.8 KB/entry in-memory
    assert 1500 < f32.bytes_per_entry() < 2200
    # quota entries scale linearly in budget and quota fraction
    q40 = i8.quota_entries(0.40, budget_mb=1024.0)
    assert q40 == pytest.approx(0.40 * 1024e6 / i8.bytes_per_entry(), abs=1)
    assert i8.quota_entries(0.20, 1024.0) == pytest.approx(q40 / 2, abs=1)
    assert i8.quota_entries(0.40, 1024.0) \
        > 2 * f32.quota_entries(0.40, 1024.0)
    with pytest.raises(ValueError):
        i8.quota_entries(1.5, 1024.0)
    with pytest.raises(ValueError):
        ResidencyModel(emb_dtype="fp16").emb_bytes()


def test_residency_capacity_table_shape():
    tab = residency_capacity_table(512.0, {"code": 0.4, "chat": 0.15})
    assert set(tab["dtypes"]) == {"float32", "int8"}
    for dt, row in tab["dtypes"].items():
        assert set(row["quota_entries"]) == {"code", "chat"}
        assert row["entries_per_mb"] * row["bytes_per_entry"] <= 1e6
    assert (tab["dtypes"]["int8"]["quota_entries"]["code"]
            > 2 * tab["dtypes"]["float32"]["quota_entries"]["code"])
