"""Quantized embedding data plane (int8 residency + fp32 re-rank tier).

Covers the whole-stack contract of ISSUE 4:

* the per-slot scale table rides the dirty-row delta sync (host/device
  coherence of the QUANTIZED mirror, delta path included);
* deterministic byte counters: int8 residency shrinks the embedding
  component of sync and gather traffic ~4x at identical row counts;
* the τ-boundary property: with the fp32 re-rank tier, hit/miss
  decisions on the int8 device path are IDENTICAL to the fp32 oracle
  for queries engineered to land inside the margin band on either side
  of τ — quantization may change latency, never decisions;
* the fp32 embedding stored next to the document (storage round trip,
  re-rank fallback).
"""

import numpy as np
import pytest

from repro.core import SemanticCache, SimClock
from repro.core.hnsw import (FlatIndex, HNSWIndex, HNSWParams,
                             quantize_rows)
from repro.core.policy import CategoryConfig, PolicyEngine
from repro.core.storage import Document, InMemoryStore

DIM = 128


def _unit(rng, n, d=DIM):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _small_params(**kw):
    return HNSWParams(M=4, M0=8, ef_construction=16, ef_search=16,
                      beam=8, max_hops=5, n_entries=4, **kw)


def _boundary_query(rng, v, target):
    """A unit query whose cosine against unit ``v`` is exactly ``target``:
    q = t·v + √(1−t²)·r with r ⊥ v."""
    r = rng.standard_normal(v.shape).astype(np.float32)
    r -= (r @ v) * v
    r /= np.linalg.norm(r)
    q = target * v + np.sqrt(max(0.0, 1.0 - target * target)) * r
    return (q / np.linalg.norm(q)).astype(np.float32)


# ------------------------------------------------------------ quantize_rows
def test_quantize_rows_roundtrip_error_bound(rng):
    """Symmetric per-row int8: dequant error per component ≤ scale/2, and
    a zero row dequantizes to exactly zero (no NaN from the eps scale)."""
    v = np.vstack([_unit(rng, 16), np.zeros((1, DIM), np.float32)])
    q, s = quantize_rows(v)
    assert q.dtype == np.int8 and s.dtype == np.float32
    deq = q.astype(np.float32) * s[:, None]
    assert np.abs(deq - v).max() <= (s[:, None] / 2 + 1e-7).max()
    assert np.all(deq[-1] == 0.0)


# ----------------------------------------------- scale table rides the sync
@pytest.mark.parametrize("index_cls", ["hnsw", "flat"])
def test_quantized_mirror_coherent_under_interleave(index_cls, rng):
    """Random add/remove interleave on an int8 index: after every flush
    the device int8 emb AND the per-slot scale table equal the host
    quantized tables exactly — including across the delta path."""
    if index_cls == "hnsw":
        idx = HNSWIndex(DIM, 256, params=_small_params(emb_dtype="int8"),
                        seed=3)
    else:
        idx = FlatIndex(DIM, 256, emb_dtype="int8")
    live = []
    for step in range(30):
        if rng.random() < 0.6 or not live:
            b = int(rng.integers(1, 6))
            live.extend(int(s) for s in idx.add_batch(_unit(rng, b)))
        else:
            victim = live.pop(int(rng.integers(len(live))))
            idx.remove(victim)
        if step % 4 == 3:
            t = idx.device_tables()
            assert np.asarray(t["emb"]).dtype == np.int8
            assert np.array_equal(np.asarray(t["emb"]), idx.emb_q)
            assert np.array_equal(np.asarray(t["scale"]), idx.emb_scale)
            assert np.array_equal(np.asarray(t["valid"]), idx.valid)
    t = idx.device_tables()
    assert np.array_equal(np.asarray(t["emb"]), idx.emb_q)
    assert np.array_equal(np.asarray(t["scale"]), idx.emb_scale)
    assert idx.sync_stats["delta_updates"] > 0, \
        "interleave never exercised the delta path"
    # host fp32 control plane and quantized tier stay in lockstep
    q, s = quantize_rows(idx.emb[idx.valid])
    assert np.array_equal(idx.emb_q[idx.valid], q)
    np.testing.assert_array_equal(idx.emb_scale[idx.valid], s)


# ----------------------------------------------------- byte-count contracts
def test_sync_and_gather_bytes_shrink_4x(rng):
    """Deterministic counters: identical inserts on fp32 and int8 indexes
    sync the same ROWS but the int8 emb component is exactly
    (d·4)/(d+4) ≈ 4x smaller; gather bytes per row shrink the same way."""
    d = 384
    vecs = _unit(rng, 40, d)
    idxs = {}
    for dt in ("float32", "int8"):
        idx = HNSWIndex(d, 512, params=_small_params(emb_dtype=dt), seed=7)
        idx.add_batch(vecs[:30])
        idx.device_tables()                      # full upload
        idx.add_batch(vecs[30:])
        idx.device_tables()                      # delta flush
        idxs[dt] = idx
    f32, i8 = idxs["float32"], idxs["int8"]
    assert f32.sync_stats["rows_synced"] == i8.sync_stats["rows_synced"]
    assert f32.sync_stats["delta_updates"] >= 1
    ratio = d * 4 / (d + 4)
    assert f32.emb_row_nbytes() / i8.emb_row_nbytes() == pytest.approx(ratio)
    assert (f32.sync_stats["emb_bytes_synced"]
            / i8.sync_stats["emb_bytes_synced"]) == pytest.approx(ratio)
    assert f32.sync_stats["bytes_synced"] > i8.sync_stats["bytes_synced"]
    # the gather cost per row feeds last_search the same way
    q = vecs[:8]
    taus = np.full(8, 2.0, np.float32)           # never done: max gathers
    for idx in (f32, i8):
        idx.search_batch(q, taus)
    rows_f32 = int(np.sum(np.asarray(f32.last_search["rows_gathered"])))
    rows_i8 = int(np.sum(np.asarray(i8.last_search["rows_gathered"])))
    assert f32.last_search["gather_row_nbytes"] == d * 4
    assert i8.last_search["gather_row_nbytes"] == d + 4
    gb_f32 = rows_f32 * f32.last_search["gather_row_nbytes"]
    gb_i8 = rows_i8 * i8.last_search["gather_row_nbytes"]
    assert gb_f32 / gb_i8 > 3.0                  # ~4x modulo beam drift


# ----------------------------------------------------- τ-boundary property
TAU = 0.90


def _build_pair(rng, n=24, index_kind="hnsw", margin=0.02):
    eng = lambda: PolicyEngine([
        CategoryConfig("a", threshold=TAU, ttl=1e9, quota=0.6,
                       rerank_margin=margin),
        CategoryConfig("b", threshold=TAU, ttl=1e9, quota=0.6,
                       rerank_margin=margin),
    ])
    vecs = _unit(rng, n)
    cats = ["a" if i % 2 else "b" for i in range(n)]
    caches = {}
    for dt in ("float32", "int8"):
        c = SemanticCache(eng(), dim=DIM, capacity=256, clock=SimClock(),
                          index_kind=index_kind, use_device=True, seed=11,
                          emb_dtype=dt)
        c.insert_batch(vecs, cats, [f"q{i}" for i in range(n)],
                       [f"r{i}" for i in range(n)])
        caches[dt] = c
    return caches, vecs, cats


@pytest.mark.parametrize("index_kind", ["hnsw", "flat"])
def test_tau_boundary_decisions_match_fp32_oracle(index_kind):
    """THE acceptance property: queries engineered to score inside the
    margin band on either side of τ (where raw int8 scores CAN cross the
    threshold the wrong way) must produce identical hit/miss decisions
    and identical slots on the int8 path (re-rank tier on) and the fp32
    oracle path. Random unit vectors at d=128 are near-orthogonal, so
    each query's decision is owned by its target entry."""
    rng = np.random.default_rng(99)
    caches, vecs, cats = _build_pair(rng, index_kind=index_kind)
    # Offsets span both sides of the band; the exact tie (offset 0) is
    # excluded — at score == τ two fp32 summation orders legitimately
    # disagree at the 1e-7 level, on ANY implementation pair.
    offsets = [-0.03, -0.012, -0.006, -0.002, -0.0005,
               0.0005, 0.002, 0.006, 0.012, 0.03]
    targets = rng.integers(0, len(vecs), len(offsets))
    q = np.stack([_boundary_query(rng, vecs[t], TAU + off)
                  for t, off in zip(targets, offsets)])
    qcats = [cats[t] for t in targets]
    res32 = caches["float32"].lookup_batch(q, qcats)
    res8 = caches["int8"].lookup_batch(q, qcats)
    for off, a, b in zip(offsets, res32, res8):
        assert a.hit == b.hit, \
            f"decision diverged at τ{off:+.4f}: fp32={a.reason} int8={b.reason}"
        assert a.reason == b.reason
        if a.hit:
            assert a.slot == b.slot
    # the band actually exercised the re-rank tier
    m8 = caches["int8"].metrics
    assert sum(s.reranks for s in m8.per_category.values()) > 0
    assert caches["int8"].last_lookup_stats["emb_dtype"] == "int8"


def test_rerank_corrects_both_directions():
    """Force decisions through the re-rank tier by planting quantized
    scores on the wrong side of τ: a borderline device 'hit' whose exact
    score is below τ demotes to a miss, and a borderline miss whose
    exact score clears τ promotes to a hit — each counted as a flip."""
    rng = np.random.default_rng(5)
    caches, vecs, cats = _build_pair(rng, index_kind="flat")
    c8 = caches["int8"]
    slot = 0
    # Direction 1: exact score just UNDER τ, quantized copy reads HIGH.
    q_under = _boundary_query(rng, vecs[slot], TAU - 0.004)
    c8.index.emb_q[slot], c8.index.emb_scale[slot] = (
        a[0] for a in quantize_rows(vecs[slot][None]))
    c8.index.emb_scale[slot] *= 1.008            # inflate: quant score > τ
    c8.index._dirty.add(slot)
    c8.index._version += 1
    r = c8.lookup_batch(q_under[None], [cats[slot]])[0]
    assert not r.hit and r.reason == "no_match"
    assert r.score < TAU                         # the EXACT score won
    # Direction 2: exact score just OVER τ, quantized copy reads LOW.
    q_over = _boundary_query(rng, vecs[slot], TAU + 0.004)
    c8.index.emb_scale[slot] /= 1.016            # deflate: quant score < τ
    c8.index._dirty.add(slot)
    c8.index._version += 1
    r = c8.lookup_batch(q_over[None], [cats[slot]])[0]
    assert r.hit and r.score >= TAU
    assert r.slot == slot
    st = c8.metrics.cat(cats[slot])
    assert st.rerank_flips >= 2


def test_margin_zero_disables_rerank():
    rng = np.random.default_rng(21)
    caches, vecs, cats = _build_pair(rng, index_kind="flat", margin=0.0)
    q = np.stack([_boundary_query(rng, vecs[0], TAU + 0.001)])
    caches["int8"].lookup_batch(q, [cats[0]])
    m = caches["int8"].metrics
    assert sum(s.reranks for s in m.per_category.values()) == 0


# ------------------------------------------------ storage-side fp32 ground truth
def test_document_embedding_json_roundtrip(rng):
    v = _unit(rng, 1)[0]
    doc = Document(7, "req", "resp", 1.5, "c", {"k": 1}, embedding=v)
    back = Document.from_json(doc.to_json())
    np.testing.assert_allclose(back.embedding_array(), v, rtol=1e-6)
    assert back.nbytes() >= 4 * DIM
    assert Document(8, "r", "s", 0.0).embedding_array() is None


def test_insert_stores_fp32_embedding_next_to_doc(rng):
    eng = PolicyEngine([CategoryConfig("c", threshold=TAU, ttl=1e9,
                                       quota=1.0)])
    cache = SemanticCache(eng, dim=DIM, capacity=64, clock=SimClock(),
                          index_kind="flat", use_device=True,
                          emb_dtype="int8")
    v = _unit(rng, 4)
    slots = cache.insert_batch(v, ["c"] * 4, ["q"] * 4, ["r"] * 4)
    for i, slot in enumerate(slots):
        doc = cache.store.get(int(cache.slot_doc[slot]))
        np.testing.assert_array_equal(doc.embedding_array(), v[i])


def test_docs_carry_embedding_only_under_quantized_residency(rng):
    """The fp32 index is already exact — its documents skip the ~4·dim
    byte duplicate; only quantized caches store the re-rank copy."""
    eng = lambda: PolicyEngine([CategoryConfig("c", threshold=TAU,
                                               ttl=1e9, quota=1.0)])
    v = _unit(rng, 2)
    for dt, want in (("float32", False), ("int8", True)):
        cache = SemanticCache(eng(), dim=DIM, capacity=64, clock=SimClock(),
                              index_kind="flat", use_device=True,
                              emb_dtype=dt)
        slots = cache.insert_batch(v, ["c"] * 2, ["q"] * 2, ["r"] * 2)
        doc = cache.store.get(int(cache.slot_doc[slots[0]]))
        assert (doc.embedding is not None) == want, dt


def test_rerank_promoted_hit_fetches_doc_once(rng):
    """A borderline query that re-ranks to a hit must serve its response
    from the document the re-rank already fetched — one store round trip,
    not two."""
    class CountingStore(InMemoryStore):
        def __init__(self):
            super().__init__()
            self.gets = 0

        def get(self, doc_id):
            self.gets += 1
            return super().get(doc_id)

    eng = PolicyEngine([CategoryConfig("c", threshold=TAU, ttl=1e9,
                                       quota=1.0)])
    store = CountingStore()
    cache = SemanticCache(eng, dim=DIM, capacity=64, clock=SimClock(),
                          index_kind="flat", use_device=True,
                          emb_dtype="int8", store=store)
    v = _unit(rng, 4)
    slots = cache.insert_batch(v, ["c"] * 4, ["q"] * 4, ["r"] * 4)
    q = _boundary_query(rng, v[0], TAU + 0.002)     # inside the band
    store.gets = 0
    r = cache.lookup_batch(q[None], ["c"])[0]
    assert r.hit and r.slot == slots[0] and r.response == "r"
    assert cache.metrics.cat("c").reranks == 1
    assert store.gets == 1


def test_rerank_falls_back_to_host_row_when_store_copy_missing(rng):
    """Crash recovery: if the store lost the embedding, the re-rank tier
    falls back to the index's host fp32 control-plane row — decisions
    still exact, never an exception."""
    eng = PolicyEngine([CategoryConfig("c", threshold=TAU, ttl=1e9,
                                       quota=1.0)])
    cache = SemanticCache(eng, dim=DIM, capacity=64, clock=SimClock(),
                          index_kind="flat", use_device=True,
                          emb_dtype="int8")
    v = _unit(rng, 4)
    slots = cache.insert_batch(v, ["c"] * 4, ["q"] * 4, ["r"] * 4)
    doc = cache.store.get(int(cache.slot_doc[slots[0]]))
    doc.embedding = None                        # store copy lost
    q = _boundary_query(rng, v[0], TAU + 0.002)
    r = cache.lookup_batch(q[None], ["c"])[0]
    assert r.hit and r.slot == slots[0]
    assert cache.metrics.cat("c").reranks >= 1
